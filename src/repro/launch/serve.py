"""Serving driver: batched prefill + greedy decode on reduced configs.

Demonstrates the full serve path (prefill → ring/latent/SSM caches →
decode_step) that the decode-shape dry-runs lower at production scale.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.trainer import build_serve_step
from repro.models import build_model


def run_serving(arch: str, *, batch: int = 4, prompt_len: int = 64,
                gen_tokens: int = 32, cache_len: int = 256, seed: int = 0,
                reduced: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)

    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    batch_in = {"tokens": prompt}
    if cfg.frontend != "none" and not cfg.enc_dec:
        batch_in["frontend"] = 0.02 * jax.random.normal(
            key, (batch, cfg.frontend_seq, cfg.frontend_dim))
    if cfg.enc_dec:
        batch_in["frontend"] = 0.02 * jax.random.normal(
            key, (batch, cfg.enc_seq_len, cfg.frontend_dim))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    serve_step = jax.jit(build_serve_step(model))

    t0 = time.time()
    logits, cache = prefill(params, batch_in)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for _ in range(gen_tokens - 1):
        tok, cache = serve_step(params, cache, tok)
        out_tokens.append(tok)
    gen = jnp.concatenate(out_tokens, axis=1)
    t_decode = time.time() - t0
    print(f"{arch}: prefill({batch}x{prompt_len}) {t_prefill:.2f}s, "
          f"decode {gen_tokens} tokens {t_decode:.2f}s "
          f"({t_decode/max(gen_tokens-1,1)*1e3:.0f} ms/tok)")
    print("sample:", gen[0, :16].tolist())
    return gen


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    args = ap.parse_args()
    run_serving(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen_tokens=args.tokens, cache_len=args.cache_len)


if __name__ == "__main__":
    main()
