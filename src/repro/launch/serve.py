"""Serving driver: batched prefill + greedy decode on reduced configs.

Demonstrates the full serve path (prefill → ring/latent/SSM caches →
decode_step) that the decode-shape dry-runs lower at production scale.
``--trace PATH`` records ``serve/prefill`` / ``serve/decode`` phase spans
and a throughput counter through the same structured event log as the
training flight recorder (DESIGN.md §12).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed.trainer import build_serve_step
from repro.models import build_model
from repro.obs import EventLog, trace_span


def run_serving(arch: str, *, batch: int = 4, prompt_len: int = 64,
                gen_tokens: int = 32, cache_len: int = 256, seed: int = 0,
                reduced: bool = True, trace: str | None = None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)

    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    batch_in = {"tokens": prompt}
    if cfg.frontend != "none" and not cfg.enc_dec:
        batch_in["frontend"] = 0.02 * jax.random.normal(
            key, (batch, cfg.frontend_seq, cfg.frontend_dim))
    if cfg.enc_dec:
        batch_in["frontend"] = 0.02 * jax.random.normal(
            key, (batch, cfg.enc_seq_len, cfg.frontend_dim))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    serve_step = jax.jit(build_serve_step(model))
    elog = EventLog(tool="repro.launch.serve", arch=arch, batch=batch,
                    prompt_len=prompt_len, gen_tokens=gen_tokens,
                    cache_len=cache_len) if trace else None

    t0 = time.time()
    with trace_span("serve/prefill", log=elog, batch=batch,
                    prompt_len=prompt_len):
        logits, cache = prefill(params, batch_in)
        tok = jax.block_until_ready(
            jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32))
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    with trace_span("serve/decode", log=elog, n_tokens=gen_tokens - 1):
        for _ in range(gen_tokens - 1):
            tok, cache = serve_step(params, cache, tok)
            out_tokens.append(tok)
        gen = jax.block_until_ready(jnp.concatenate(out_tokens, axis=1))
    t_decode = time.time() - t0
    ms_tok = t_decode / max(gen_tokens - 1, 1) * 1e3
    print(f"{arch}: prefill({batch}x{prompt_len}) {t_prefill:.2f}s, "
          f"decode {gen_tokens} tokens {t_decode:.2f}s "
          f"({ms_tok:.0f} ms/tok)")
    print("sample:", gen[0, :16].tolist())
    if elog is not None:
        # batch sequences decode in parallel → batch tokens per step
        elog.event("counter", name="serve/throughput",
                   prefill_s=t_prefill, decode_s=t_decode,
                   ms_per_token=ms_tok,
                   tokens_per_s=batch * max(gen_tokens - 1, 1)
                   / max(t_decode, 1e-9))
        elog.write_jsonl(trace)
        print(f"wrote trace {trace}")
    return gen


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write serve phase timings + throughput as "
                         "structured JSONL (DESIGN.md §12)")
    args = ap.parse_args()
    run_serving(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen_tokens=args.tokens, cache_len=args.cache_len,
                trace=args.trace)


if __name__ == "__main__":
    main()
