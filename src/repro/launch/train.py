"""Training driver.

Runs real steps on whatever devices exist (CPU harness: reduced configs;
TPU pod: full configs — identical code path).  Byzantine workers are
simulated on the worker axis; the guard backend, optimizer, data pipeline
and checkpointing are all exercised.

Aggregation is the solver's guard axis (DESIGN.md §9/§10):
``--aggregator byzantine_sgd`` with ``--guard-backend`` one of

* ``dp_exact``  — the distributed exact-mode guard (auto-V online; default)
* ``dp_sketch`` — the CountSketch guard (O(W·k) statistics)
* ``dense`` / ``fused`` — the single-host reference / one-pass Pallas
  pipeline; no auto-V, so pass ``--guard-v`` (the Assumption-2.2 bound)

or any stateless baseline (``mean`` / ``coordinate_median`` /
``trimmed_mean`` / ``krum``) via ``--aggregator``.

The adversary is either a static gradient attack (``--attack``) or a full
Remark-2.3 *scenario* (``--scenario``) built around that attack:

* ``static``    — the plain attack (same as no scenario, via the engine)
* ``lie_low``   — honest until T/2, then strike
* ``churn``     — Byzantine identity rotates every T/2 steps
* ``adaptive``  — multiplicative-weights magnitude driven by filter feedback
* ``coalition`` — half the coalition plays the attack, half inner_product

The step loop is a **chunked ``lax.scan``**: data generation, the attack,
the guard and the optimizer all live inside one jitted scan over
``log_every`` steps, so the host sees one transfer of stacked metrics per
chunk instead of one transfer per metric per step (the historical Python
loop is kept as ``driver="loop"`` — it is the measured baseline in
``BENCH_train.json``, see ``benchmarks/bench_train.py``).

Checkpointing stores the **full** :class:`~repro.distributed.trainer.TrainState`
(params + optimizer moments + guard martingales + anchor + adversary and
feedback memory + step), so ``--resume`` continues bit-for-bit where the
run stopped (resume-equals-uninterrupted is a tier-1 regression test).

PRNG discipline: one ``jax.random.split`` at the top fans the seed into
disjoint init / mask / data / loop streams — the init key can no longer
collide with the Byzantine-mask permutation, and the per-step data and
attack keys live in separate streams.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --workers 8 --steps 100 --alpha 0.25 --attack sign_flip \
        --guard-backend dp_exact --scenario churn
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.solver import SolverConfig, byz_rank
from repro.data.synthetic import SyntheticTokens, make_worker_batch
from repro.distributed.trainer import build_train_step, init_train_state
from repro.models import build_model
from repro.obs import EventLog, TelemetryConfig, trace_span
from repro.optim import adamw, linear_warmup_cosine

GUARD_BACKENDS = ("dp_exact", "dp_sketch", "dense", "fused")
SCENARIOS = ("static", "lie_low", "churn", "adaptive", "coalition")


def _make_scenario_adversary(name: str, attack: str, alpha: float,
                             steps: int, workers: int):
    from repro.scenarios import (
        ScenarioAdversary,
        scenario_adaptive,
        scenario_churn,
        scenario_coalition,
        scenario_lie_low_then_strike,
        scenario_static,
    )

    if name == "static":
        scn = scenario_static(attack)
    elif name == "lie_low":
        scn = scenario_lie_low_then_strike(attack, switch_step=steps // 2)
    elif name == "churn":
        scn = scenario_churn(attack, period=max(steps // 2, 1),
                             stride=max(workers // 8, 1))
    elif name == "adaptive":
        scn = scenario_adaptive(attack, adapt_rate=0.5)
    elif name == "coalition":
        scn = scenario_coalition(attack, "inner_product", 0.5)
    else:
        raise KeyError(f"unknown scenario {name!r}; have {SCENARIOS}")
    return ScenarioAdversary(scenario=scn, alpha=jnp.float32(alpha))


def run_training(
    arch: str, *, reduced: bool = True, workers: int = 8, per_worker_batch: int = 2,
    seq_len: int = 128, steps: int = 100, alpha: float = 0.25,
    attack: str = "sign_flip", aggregator: str = "byzantine_sgd",
    guard_backend: str = "dp_exact", guard_opts: tuple = (),
    stats_dtype: str = "f32",
    guard_v: float = 0.0, scenario: str | None = None, lr: float = 3e-3,
    seed: int = 0, ckpt_dir: str | None = None, resume: bool = False,
    stop_after: int | None = None, log_every: int = 10, d_model: int = 256,
    driver: str = "scan", trace: str | None = None,
    ckpt_every: int | None = None, keep_last: int | None = None,
):
    """Train ``steps`` steps; returns (final TrainState, per-step history).

    ``trace`` (a path) arms the guard flight recorder (DESIGN.md §12):
    per-step filter forensics ride the chunk flush as ``tel/`` metrics and
    are written — together with ``train/chunk`` host spans and the run's
    provenance — as structured JSONL at that path
    (``scripts/render_trace.py`` renders it; ``--perfetto`` converts).

    ``driver="scan"`` (default) runs chunked ``lax.scan`` with on-device
    data generation; ``driver="loop"`` is the historical one-jitted-call-
    per-step Python loop with per-metric host transfers, retained only as
    the wall-clock baseline.

    ``stop_after`` interrupts the run after that many steps while keeping
    every schedule (LR, thresholds, scenario switch points) sized by the
    full ``steps`` — with ``ckpt_dir`` set this checkpoints a resumable
    prefix, which is how the resume-equals-uninterrupted regression test
    simulates a preempted run.

    ``ckpt_every`` (with ``ckpt_dir``) also checkpoints mid-run every that
    many steps at segment boundaries — the periodic saves a SIGKILL-style
    crash resumes from (the chaos harness's kill-resume matrix);
    ``keep_last`` bounds retention to the newest K complete checkpoints.
    A SIGTERM (preemption notice) is caught at the next segment boundary:
    the loop exits early and the normal tail flushes a final checkpoint +
    history within the grace budget (DESIGN.md §15).
    """
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced(max_d_model=d_model)
    model = build_model(cfg)
    stream = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=seq_len, seed=seed)
    opt = adamw(linear_warmup_cosine(lr, warmup=max(steps // 20, 1), total_steps=steps),
                grad_clip=1.0)
    # label_flip poisons the DATA of Byzantine workers (their gradients are
    # honest gradients of corrupted batches) — no gradient-level transform
    grad_attack = "none" if attack == "label_flip" else attack
    if scenario is not None and attack == "label_flip":
        raise ValueError("label_flip is a data attack; scenarios schedule "
                         "gradient attacks — pick one")
    scfg = SolverConfig(
        m=workers, T=steps, eta=lr, alpha=alpha, aggregator=aggregator,
        attack=grad_attack, mean_over_alive=True,
        guard_backend=guard_backend, guard_opts=tuple(guard_opts),
        stats_dtype=stats_dtype,
    )
    adversary = (_make_scenario_adversary(scenario, grad_attack, alpha,
                                          steps, workers)
                 if scenario is not None else None)
    telemetry = TelemetryConfig(enabled=True) if trace else None
    elog = None
    if trace:
        elog = EventLog(
            tool="repro.launch.train", arch=arch, workers=workers,
            steps=steps, alpha=alpha, attack=attack, aggregator=aggregator,
            guard_backend=guard_backend, scenario=scenario, seed=seed,
        )
    train_step = build_train_step(model, opt, scfg, V=guard_v,
                                  adversary=adversary, telemetry=telemetry)

    # PRNG: one split at the top → disjoint init / mask / data / loop streams
    init_key, mask_key, data_key, loop_key = jax.random.split(
        jax.random.PRNGKey(seed), 4
    )
    state = init_train_state(model, opt, scfg, init_key, V=guard_v,
                             adversary=adversary)
    rank = byz_rank(mask_key, workers)
    static_mask = rank < scfg.n_byzantine
    poison = static_mask if attack == "label_flip" else None

    start = 0
    history: list[dict] = []
    if resume and ckpt_dir and latest_step(ckpt_dir) is not None:
        state, start = restore_checkpoint(ckpt_dir, state)
        print(f"resumed from {ckpt_dir} at step {start}")
        hist_path = os.path.join(ckpt_dir, "history.json")
        if os.path.exists(hist_path):
            # keep the pre-resume records so history.json stays complete
            with open(hist_path) as f:
                history = [r for r in json.load(f) if r["step"] < start]
    stop = steps if stop_after is None else min(stop_after, steps)

    def make_batch(i):
        batch = make_worker_batch(stream, workers, per_worker_batch, i,
                                  poison_mask=poison)
        if cfg.frontend != "none":
            fseq = cfg.frontend_seq if not cfg.enc_dec else cfg.enc_seq_len
            batch["frontend"] = 0.02 * jax.random.normal(
                jax.random.fold_in(data_key, i),
                (workers, per_worker_batch, fseq, cfg.frontend_dim),
                jnp.dtype(cfg.activation_dtype),
            )
        return batch

    def one_step(st, i):
        batch = make_batch(i)
        return train_step(st, batch, rank, jax.random.fold_in(loop_key, i))

    t0 = time.time()
    n_prior = len(history)
    run_label = f"train/{arch}"

    # preemption (DESIGN.md §15): SIGTERM flips a flag the drivers check at
    # segment boundaries — the loop exits early and the normal tail below
    # flushes a final checkpoint + history within the grace budget, instead
    # of dying mid-scan with the newest progress only in device memory.
    preempted = {"hit": False}
    prev_sigterm = None
    if ckpt_dir:
        def _on_sigterm(signum, frame):
            preempted["hit"] = True
        try:
            prev_sigterm = signal.signal(signal.SIGTERM, _on_sigterm)
        except ValueError:
            prev_sigterm = None  # not the main thread — no handler, no flush

    def maybe_ckpt(state, lo):
        """Periodic mid-run save at a segment boundary (the restart points
        of the kill-resume chaos matrix)."""
        if ckpt_dir and ckpt_every and lo < stop and lo % ckpt_every == 0:
            save_checkpoint(ckpt_dir, int(jax.device_get(state.step)), state,
                            keep_last=keep_last)

    def flush_recs(ms, lo, hi, stacked=True):
        """Host-side split of one metrics transfer: ``tel/`` forensics
        (per-worker arrays included) go to the event log as guard_step
        events, everything else becomes scalar history records."""
        for j, i in enumerate(range(lo, hi)):
            rec, frame = {}, {}
            for k, v in ms.items():
                vj = v[j] if stacked else v
                if k.startswith("tel/"):
                    frame[k[4:]] = vj
                else:
                    rec[k] = float(vj)
            rec["step"] = i
            history.append(rec)
            if elog is not None and frame:
                elog.guard_step(frame, run=run_label)

    def log(rec):
        print(
            f"step {rec['step']:5d}  loss={rec['loss_good_workers']:.4f}  "
            f"alive={int(rec['n_alive'])}/{workers}  "
            f"byz_alive={int(rec.get('byz_alive', 0))}  "
            f"good_filtered={int(rec.get('good_filtered', 0))}  "
            f"({(time.time()-t0)/max(len(history) - n_prior, 1):.2f}s/step)"
        )

    if driver == "scan":
        # fixed compile set regardless of steps/stop/resume offsets: full
        # log_every chunks go through ONE scan program; ragged head/tail
        # segments (resume from an unaligned step, final remainder) run
        # through the shared per-step program instead of retracing the
        # whole model scan at a new length
        @jax.jit
        def run_chunk(st, idx):
            def body(s, i):
                s, m = one_step(s, i)
                return s, m
            return jax.lax.scan(body, st, idx)

        step_fn = jax.jit(one_step)

        def run_segment(state, lo, hi):
            if hi - lo == log_every:
                with trace_span("train/chunk", log=elog, lo=lo, hi=hi):
                    state, ms = run_chunk(state, jnp.arange(lo, hi))
                    ms = jax.device_get(ms)
                flush_recs(ms, lo, hi)
            else:
                for i in range(lo, hi):
                    with trace_span("train/step", log=elog, i=i):
                        state, m = step_fn(state, jnp.asarray(i))
                        m = jax.device_get(m)
                    flush_recs(m, i, i + 1, stacked=False)
            return state

        lo = start
        head = max(min((log_every - start % log_every) % log_every,
                       stop - start), 0)
        if head:
            state = run_segment(state, lo, lo + head)
            log(history[-1])
            lo += head
            maybe_ckpt(state, lo)
        while lo < stop and not preempted["hit"]:
            hi = min(lo + log_every, stop)
            state = run_segment(state, lo, hi)
            log(history[-1])
            lo = hi
            maybe_ckpt(state, lo)
    elif driver == "loop":
        # historical baseline: one jitted call + one host transfer per
        # metric per step (what the scan driver replaces)
        step_fn = jax.jit(one_step)
        for i in range(start, stop):
            if preempted["hit"]:
                break
            state, metrics = step_fn(state, jnp.asarray(i))
            flush_recs(jax.device_get(metrics), i, i + 1, stacked=False)
            if i % log_every == 0 or i == stop - 1:
                log(history[-1])
            maybe_ckpt(state, i + 1)
    else:
        raise KeyError(f"unknown driver {driver!r}; have scan|loop")

    if preempted["hit"]:
        print(f"SIGTERM: preempted at step {int(jax.device_get(state.step))}"
              " — flushing final checkpoint")
    if ckpt_dir:
        # label with the state's own counter — when a resume starts at or
        # past `stop` no steps ran and the label must not go backwards
        save_checkpoint(ckpt_dir, int(jax.device_get(state.step)), state,
                        keep_last=keep_last)
        with open(f"{ckpt_dir}/history.json", "w") as f:
            json.dump(history, f)
    if prev_sigterm is not None:
        signal.signal(signal.SIGTERM, prev_sigterm)
    if elog is not None:
        elog.add_meta(wall_s=time.time() - t0,
                      steps_run=max(stop - start, 0))
        elog.write_jsonl(trace)
        print(f"wrote trace {trace} ({len(elog.events)} events)")
    return state, history


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--attack", default="sign_flip",
                    choices=["none", "sign_flip", "random_gaussian",
                             "constant_drift", "alie", "inner_product",
                             "hidden_shift", "label_flip"])
    ap.add_argument("--aggregator", default="byzantine_sgd",
                    choices=["byzantine_sgd", "mean", "coordinate_median",
                             "trimmed_mean", "krum"])
    ap.add_argument("--guard-backend", default="dp_exact",
                    choices=list(GUARD_BACKENDS),
                    help="guard realization (DESIGN.md §9); dense/fused "
                         "need --guard-v")
    ap.add_argument("--stats-dtype", default="f32", choices=["f32", "bf16"],
                    help="guard statistics precision (DESIGN.md §5 "
                         "Numerics): bf16 halves the filter pipeline's "
                         "HBM traffic; gradients cast once at ravel")
    ap.add_argument("--guard-v", type=float, default=0.0,
                    help="explicit Assumption-2.2 V (0 = auto-calibrate, "
                         "dp backends only)")
    ap.add_argument("--scenario", default=None, choices=list(SCENARIOS),
                    help="Remark-2.3 scenario adversary built around "
                         "--attack (default: static attack path)")
    ap.add_argument("--driver", default="scan", choices=["scan", "loop"])
    ap.add_argument("--d-model", type=int, default=256,
                    help="reduced-config width cap (CPU harness sizing)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=None, metavar="N",
                    help="also checkpoint every N steps mid-run (at segment "
                         "boundaries) — the restart points a SIGKILL-style "
                         "crash resumes from")
    ap.add_argument("--keep-last", type=int, default=None, metavar="K",
                    help="retain only the newest K complete checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in --ckpt-dir")
    ap.add_argument("--stop-after", type=int, default=None, metavar="N",
                    help="stop after N steps (schedules stay sized by "
                         "--steps) — checkpoints a resumable prefix")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="arm the guard flight recorder (DESIGN.md §12) and "
                         "write the structured JSONL event log here; render "
                         "with scripts/render_trace.py")
    args = ap.parse_args()
    run_training(
        args.arch, reduced=args.reduced, workers=args.workers,
        per_worker_batch=args.per_worker_batch, seq_len=args.seq_len,
        steps=args.steps, alpha=args.alpha, attack=args.attack,
        aggregator=args.aggregator, guard_backend=args.guard_backend,
        stats_dtype=args.stats_dtype,
        guard_v=args.guard_v, scenario=args.scenario, driver=args.driver,
        lr=args.lr, seed=args.seed, ckpt_dir=args.ckpt_dir,
        resume=args.resume, log_every=args.log_every, trace=args.trace,
        ckpt_every=args.ckpt_every, keep_last=args.keep_last,
        stop_after=args.stop_after, d_model=args.d_model,
    )


if __name__ == "__main__":
    main()
