"""Training driver.

Runs real steps on whatever devices exist (CPU harness: reduced configs;
TPU pod: full configs — identical code path).  Byzantine workers are
simulated on the worker axis; the guard, optimizer, data pipeline and
checkpointing are all exercised.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --workers 8 --steps 100 --alpha 0.25 --attack sign_flip
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import SyntheticTokens, make_worker_batch
from repro.distributed.byzantine_dp import DPGuardConfig
from repro.distributed.trainer import build_train_step, init_train_state
from repro.models import build_model
from repro.optim import adamw, linear_warmup_cosine


def run_training(
    arch: str, *, reduced: bool = True, workers: int = 8, per_worker_batch: int = 2,
    seq_len: int = 128, steps: int = 100, alpha: float = 0.25,
    attack: str = "sign_flip", aggregator: str = "byzantine_sgd",
    guard_mode: str = "exact", lr: float = 3e-3, seed: int = 0,
    ckpt_dir: str | None = None, log_every: int = 10, d_model: int = 256,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced(max_d_model=d_model)
    model = build_model(cfg)
    stream = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=seq_len, seed=seed)
    opt = adamw(linear_warmup_cosine(lr, warmup=max(steps // 20, 1), total_steps=steps),
                grad_clip=1.0)
    dp = DPGuardConfig(n_workers=workers, T=steps, mode=guard_mode, auto_v=True)
    # label_flip poisons the DATA of Byzantine workers (their gradients are
    # honest gradients of corrupted batches) — no gradient-level transform
    grad_attack = "none" if attack == "label_flip" else attack
    train_step = jax.jit(build_train_step(model, opt, dp, aggregator=aggregator,
                                          attack=grad_attack))

    key = jax.random.PRNGKey(seed)
    state = init_train_state(model, opt, dp, key)
    n_byz = int(alpha * workers)
    byz_mask = jnp.isin(jnp.arange(workers), jax.random.permutation(key, workers)[:n_byz])

    history = []
    t0 = time.time()
    for i in range(steps):
        poison = byz_mask if attack == "label_flip" else None
        batch = make_worker_batch(stream, workers, per_worker_batch, jnp.asarray(i),
                                  poison_mask=poison)
        if cfg.frontend != "none":
            fseq = cfg.frontend_seq if not cfg.enc_dec else cfg.enc_seq_len
            batch["frontend"] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, i),
                (workers, per_worker_batch, fseq, cfg.frontend_dim),
                jnp.dtype(cfg.activation_dtype),
            )
        g_mask = jnp.zeros_like(byz_mask) if attack == "label_flip" else byz_mask
        state, metrics = train_step(state, batch, g_mask, jax.random.fold_in(key, 10_000 + i))
        rec = {k: float(v) for k, v in metrics.items()}
        rec["step"] = i
        history.append(rec)
        if i % log_every == 0 or i == steps - 1:
            print(
                f"step {i:5d}  loss={rec['loss_good_workers']:.4f}  "
                f"alive={int(rec['n_alive'])}/{workers}  "
                f"byz_alive={int(rec.get('byz_alive', 0))}  "
                f"good_filtered={int(rec.get('good_filtered', 0))}  "
                f"({(time.time()-t0)/(i+1):.2f}s/step)"
            )
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, state.params)
        with open(f"{ckpt_dir}/history.json", "w") as f:
            json.dump(history, f)
    return state, history


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--attack", default="sign_flip",
                    choices=["none", "sign_flip", "noise", "constant_drift",
                             "scaled_copy", "label_flip"])
    ap.add_argument("--aggregator", default="byzantine_sgd",
                    choices=["byzantine_sgd", "mean", "coordinate_median",
                             "trimmed_mean", "krum"])
    ap.add_argument("--guard-mode", default="exact", choices=["exact", "sketch"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    run_training(
        args.arch, reduced=args.reduced, workers=args.workers,
        per_worker_batch=args.per_worker_batch, seq_len=args.seq_len,
        steps=args.steps, alpha=args.alpha, attack=args.attack,
        aggregator=args.aggregator, guard_mode=args.guard_mode,
        lr=args.lr, seed=args.seed, ckpt_dir=args.ckpt_dir,
    )


if __name__ == "__main__":
    main()
