"""repro.launch — production mesh, AOT dry-run, training/serving drivers."""
