import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod AOT dry-run: lower + compile every (arch × input-shape) on the
production mesh, record memory/cost analysis + roofline terms.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) —
the XLA_FLAGS line above executes before any jax import, giving this
process 512 placeholder CPU devices so ``jax.make_mesh`` can build the
production topology. Nothing here allocates device memory: inputs and
states are ShapeDtypeStructs.

Per combination we emit a JSON record under ``--out-dir`` with:
  * memory_analysis (per-device argument/output/temp bytes),
  * cost_analysis (per-device FLOPs / bytes accessed),
  * collective bytes by kind (parsed from partitioned HLO),
  * the three roofline terms + dominant bottleneck (§Roofline).

Shape→step mapping: train_4k → train_step (Byzantine guard included);
prefill_32k → prefill; decode_32k / long_500k → serve_step.
``long_500k`` uses each arch's sub-quadratic path (SSM state, MLA latent
cache, sliding-window ring cache for pure-attention archs — see DESIGN.md).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.core.solver import SolverConfig
from repro.distributed.sharding import (
    LOGICAL_RULES_MULTI_POD,
    LOGICAL_RULES_SINGLE_POD,
    use_logical_rules,
)
from repro.distributed.specs import make_prefill_specs, make_serve_specs, make_train_specs
from repro.distributed.trainer import build_serve_step, build_train_step, init_train_state
from repro.launch.mesh import make_production_mesh, n_workers
from repro.models import build_model
from repro.optim import adamw
from repro.roofline import roofline_from_compiled
from repro.roofline.hw import TPU_V5E

LONG_CONTEXT_WINDOW = 4096   # ring-cache window for pure-attention archs @500k


def arch_variant_for_shape(cfg: ModelConfig, shape: InputShape) -> tuple[ModelConfig, str]:
    """long_500k: keep native sub-quadratic paths (ssm/hybrid/MLA), switch
    pure-GQA archs to a sliding-window ring cache (documented variant)."""
    if shape.name != "long_500k":
        return cfg, "native"
    if cfg.ssm_state > 0 and cfg.attn_period == 0:
        return cfg, "native-ssm"            # mamba2: O(1) state
    if cfg.attn_period > 0:
        return cfg, "native-hybrid"         # jamba: mamba + few attn layers
    if cfg.use_mla:
        return cfg, "native-mla-latent"     # deepseek: (L, kv_lora+r) cache
    if cfg.sliding_window:
        return cfg, "native-swa"            # starcoder2: already windowed
    return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW), "swa-variant"


def rules_for(shape: InputShape, multi_pod: bool, mesh) -> dict:
    rules = dict(LOGICAL_RULES_MULTI_POD if multi_pod else LOGICAL_RULES_SINGLE_POD)
    # FSDP: shard the model-embed weight dim over the data axis (params are
    # otherwise replicated across workers — fatal at 76B+). Activations use
    # 'act_embed', so this touches weights only.
    rules["embed"] = "data"
    if shape.kind == "train":
        # inside the per-worker vmap the activation batch dim is the
        # *per-worker* batch; the worker axis already owns 'data' — sharding
        # both produces conflicting group shardings (XLA SPMD CHECK failure)
        rules["batch"] = None
    if shape.is_decode and shape.global_batch < mesh.shape.get("data", 1):
        # single-request long-context decode: batch can't use the data axis —
        # give it to the KV-cache sequence dim instead (flash-decoding style)
        rules["batch"] = None
        rules["cache_seq"] = ("data", "model")
    return rules


def _kind(shape: InputShape) -> str:
    return {"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]


def lower_one(arch: str, shape_name: str, multi_pod: bool, guard_mode: str = "sketch",
              mesh=None, cfg_map=None, shape_map=None, opts: tuple = ()):
    """Lower + compile one (arch, shape, mesh) combination; returns record dict.

    ``mesh`` / ``cfg_map`` / ``shape_map`` exist for the test suite (tiny
    meshes + reduced configs exercise the identical code path).

    ``opts`` — §Perf levers (EXPERIMENTS.md records each):
      'lp_guard'  — bf16 guard statistics: sets the solver-wide
                    ``SolverConfig.stats_dtype='bf16'`` axis (DESIGN.md §5
                    Numerics) — the dry-run perf lever and the solver
                    config name the same knob (no f32 grad copies, halved
                    all-gather bytes, bf16 B storage)
      'no_sp'     — disable act_seq sequence parallelism for train
      'donate'    — donate the train state (aliased in-place update)
      'kv_quant'  — int8 KV cache for decode shapes (serving lever)
      'exact_guard' — paper-faithful exact-mode guard (vs default sketch):
                    quantifies the sketch's communication savings
      'chunk512' / 'chunk2048' — attention KV-chunk size sweep
    """
    shape = INPUT_SHAPES[shape_name]
    if shape_map is not None:
        shape = shape_map(shape)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_desc = "x".join(str(s) for s in mesh.devices.shape) + ("(2pod)" if multi_pod else "")
    n_chips = mesh.devices.size
    cfg, variant = arch_variant_for_shape(get_config(arch), shape)
    if cfg_map is not None:
        cfg = cfg_map(cfg)
    rules = rules_for(shape, multi_pod, mesh)
    if "no_sp" in opts:
        rules["act_seq"] = None
    if "kv_quant" in opts and shape.is_decode:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if "exact_guard" in opts:
        guard_mode = "exact"
    if "chunk512" in opts:
        cfg = dataclasses.replace(cfg, attn_chunk=512)
    if "chunk2048" in opts:
        cfg = dataclasses.replace(cfg, attn_chunk=2048)
    model = build_model(cfg)
    W = n_workers(mesh)

    t0 = time.time()
    with use_logical_rules(rules, mesh):
        if shape.kind == "train":
            # the guard rides the unified SolverConfig axes (DESIGN.md §10):
            # the historical exact/sketch modes are the dp_exact/dp_sketch
            # guard backends on the tree-harness flat view, and 'lp_guard'
            # is the stats_dtype='bf16' point of the §5 precision axis
            scfg = SolverConfig(
                m=W, T=10_000, eta=1e-4, alpha=0.25,
                aggregator="byzantine_sgd", attack="none",
                mean_over_alive=True,
                guard_backend={"exact": "dp_exact", "sketch": "dp_sketch"}[guard_mode],
                stats_dtype="bf16" if "lp_guard" in opts else "f32",
            )
            optimizer = adamw(1e-4, grad_clip=1.0)
            train_step = build_train_step(model, optimizer, scfg)
            state_sds, batch_sds, rank_sds, rng_sds = make_train_specs(
                model, scfg, "adamw", shape, rules, mesh
            )

            def step_fn(state, batch, rank, rng):
                with use_logical_rules(rules, mesh):
                    return train_step(state, batch, rank, rng)

            donate = (0,) if "donate" in opts else ()
            lowered = jax.jit(step_fn, donate_argnums=donate).lower(
                state_sds, batch_sds, rank_sds, rng_sds)
        elif shape.kind == "prefill":
            params_sds, batch_sds = make_prefill_specs(model, shape, rules, mesh)

            def step_fn(params, batch):
                with use_logical_rules(rules, mesh):
                    return model.prefill(params, batch, cache_len=shape.seq_len)

            lowered = jax.jit(step_fn).lower(params_sds, batch_sds)
        else:  # decode
            serve_step = build_serve_step(model)
            params_sds, cache_sds, token_sds = make_serve_specs(model, shape, rules, mesh)

            def step_fn(params, cache, tok):
                with use_logical_rules(rules, mesh):
                    return serve_step(params, cache, tok)

            lowered = jax.jit(step_fn).lower(params_sds, cache_sds, token_sds)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    report = roofline_from_compiled(
        compiled, arch, shape, mesh_desc, n_chips, cfg, TPU_V5E
    )
    mem = compiled.memory_analysis()
    record = {
        "arch": arch,
        "shape": shape.name,
        "mesh": mesh_desc,
        "multi_pod": multi_pod,
        "variant": variant,
        "n_chips": n_chips,
        "n_workers": W if shape.kind == "train" else None,
        "guard_mode": guard_mode if shape.kind == "train" else None,
        "stats_dtype": (("bf16" if "lp_guard" in opts else "f32")
                        if shape.kind == "train" else None),
        "opts": list(opts),
        "_hlo_text": compiled.as_text(),
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "peak_bytes": report.peak_memory_bytes,
            "fits_hbm_16g": report.fits_hbm,
        },
        "cost": {
            "hlo_flops_per_device": report.hlo_flops,
            "hlo_bytes_per_device": report.hlo_bytes,
        },
        "collectives": {
            "total_bytes_per_device": report.collective_bytes,
            "by_kind": report.collective_by_kind,
        },
        "roofline": {
            "t_compute_s": report.t_compute,
            "t_memory_s": report.t_memory,
            "t_collective_s": report.t_collective,
            "bottleneck": report.bottleneck,
            "model_flops": report.model_flops,
            "useful_ratio": report.useful_ratio,
        },
    }
    return record, report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--guard-mode", default="sketch", choices=["sketch", "exact"])
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true",
                    help="persist gzipped partitioned HLO next to the JSON")
    ap.add_argument("--opt", action="append", default=[],
                    choices=["lp_guard", "no_sp", "donate", "kv_quant",
                             "exact_guard", "chunk512", "chunk2048"],
                    help="§Perf levers; may repeat")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    os.makedirs(args.out_dir, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            opt_tag = ("__opt-" + "-".join(sorted(set(args.opt)))) if args.opt else ""
            tag = f"{arch}__{shape}__{'multipod' if args.multi_pod else 'singlepod'}{opt_tag}"
            out_path = os.path.join(args.out_dir, tag + ".json")
            if args.skip_existing and os.path.exists(out_path):
                print(f"[skip] {tag}")
                continue
            try:
                record, report = lower_one(arch, shape, args.multi_pod, args.guard_mode, opts=tuple(args.opt))
                hlo_text = record.pop("_hlo_text", None)
                with open(out_path, "w") as f:
                    json.dump(record, f, indent=2)
                if args.save_hlo and hlo_text:
                    import gzip
                    with gzip.open(out_path.replace(".json", ".hlo.gz"), "wt") as f:
                        f.write(hlo_text)
                print(f"[ok]   {report.row()}  (compile {record['compile_s']:.0f}s)")
            except Exception as e:
                failures.append((tag, repr(e)))
                with open(out_path + ".failed", "w") as f:
                    f.write(traceback.format_exc())
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
