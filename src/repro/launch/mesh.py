"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.

Topology (TPU v5e):
  * single pod:  (data=16, model=16)       — 256 chips
  * multi-pod:   (pod=2, data=16, model=16) — 512 chips, the 'pod' axis
    crosses the DCN/ICI boundary; the paper's worker axis is (pod, data).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def worker_axes(multi_pod: bool = False) -> tuple[str, ...]:
    """Mesh axes that form the paper's 'm workers' dimension."""
    return ("pod", "data") if multi_pod else ("data",)


def n_workers(mesh) -> int:
    names = mesh.axis_names
    w = 1
    for a in ("pod", "data"):
        if a in names:
            w *= mesh.shape[a]
    return w
