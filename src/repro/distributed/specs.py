"""Sharding-spec builders for whole train/serve states and input batches.

These produce (ShapeDtypeStruct tree, NamedSharding tree) pairs for AOT
lowering — the dry-run never allocates a byte.  Logical→mesh rules come
from :mod:`repro.distributed.sharding`; leaf kinds of caches / guard state
are resolved by field name + rank.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed.byzantine_dp import DPGuardConfig
from repro.distributed.sharding import logical_to_spec, use_logical_rules, param_pspecs
from repro.models.model import LanguageModel

PyTree = Any


def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype, sharding=_ns(mesh, spec))


def _logical(axes, shape, rules, mesh) -> P:
    return logical_to_spec(tuple(axes), tuple(shape), rules, mesh)


# ---------------------------------------------------------------------------
# cache specs (decode/serve)
# ---------------------------------------------------------------------------

_CACHE_FIELD_AXES = {
    # field name → logical axes (leading 'None' = stacked layer axis)
    "k": (None, "batch", "cache_seq", "kv_heads", None),
    "v": (None, "batch", "cache_seq", "kv_heads", None),
    "ckv": (None, "batch", "cache_seq", None),
    "k_rope": (None, "batch", "cache_seq", None),
    "k_scale": (None, "batch", "cache_seq", "kv_heads"),
    "v_scale": (None, "batch", "cache_seq", "kv_heads"),
    "state": (None, "batch", "heads", None, None),
    "conv_x": (None, "batch", None, "mlp"),
    "conv_B": (None, "batch", None, None),
    "conv_C": (None, "batch", None, None),
    "pos": (),
}


def cache_specs(cache_abstract: PyTree, rules: dict, mesh: Mesh) -> PyTree:
    """PartitionSpec tree for an (abstract) decode cache."""

    def spec_for(path, leaf) -> P:
        name = None
        for pp in reversed(path):
            key = getattr(pp, "name", getattr(pp, "key", None))
            if isinstance(key, str):
                name = key
                break
        if name in _CACHE_FIELD_AXES and len(_CACHE_FIELD_AXES[name]) == leaf.ndim:
            return _logical(_CACHE_FIELD_AXES[name], leaf.shape, rules, mesh)
        # memory_kv tuples: (layers, B, Sm, H, hd)
        if leaf.ndim == 5:
            return _logical((None, "batch", None, "kv_heads", None), leaf.shape, rules, mesh)
        if leaf.ndim == 0:
            return P()
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, cache_abstract)


# ---------------------------------------------------------------------------
# train-state specs
# ---------------------------------------------------------------------------

def make_train_specs(
    model: LanguageModel,
    dp_cfg: DPGuardConfig,
    optimizer_kind: str,
    shape: InputShape,
    rules: dict,
    mesh: Mesh,
):
    """(state_sds, batch_sds, byz_sds, rng_sds) ShapeDtypeStruct trees with
    shardings for AOT-lowering ``train_step``."""
    cfg = model.cfg
    pdt = jnp.dtype(cfg.param_dtype)
    W = dp_cfg.n_workers
    assert shape.global_batch % W == 0, (shape.global_batch, W)
    b = shape.global_batch // W

    with use_logical_rules(rules, mesh):
        pspecs = param_pspecs(model.defs, rules, mesh)
    params_sds = jax.tree_util.tree_map(
        lambda d, s: _sds(d.shape, pdt, mesh, s),
        model.defs, pspecs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
    )

    # optimizer state
    if optimizer_kind == "adamw":
        f32 = lambda t: jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32, sharding=x.sharding), t
        )
        opt_sds = {"m": f32(params_sds), "v": f32(params_sds)}
    elif optimizer_kind == "momentum":
        opt_sds = {"m": jax.tree_util.tree_map(lambda x: x, params_sds)}
    else:
        opt_sds = {}

    worker_spec = _logical(("worker",), (W,), rules, mesh)
    if dp_cfg.mode == "sketch":
        b_sds = _sds((W, dp_cfg.sketch_dim), jnp.float32, mesh,
                     _logical(("worker", None), (W, dp_cfg.sketch_dim), rules, mesh))
    else:
        def exact_leaf(d, s):
            spec = _logical(("worker",) + tuple(d.axes), (W, *d.shape), rules, mesh)
            return _sds((W, *d.shape), jnp.float32, mesh, spec)
        b_sds = jax.tree_util.tree_map(
            exact_leaf, model.defs, pspecs,
            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
        )

    guard_sds = dict(
        A=_sds((W,), jnp.float32, mesh, worker_spec),
        B=b_sds,
        alive=_sds((W,), jnp.bool_, mesh, worker_spec),
        k=_sds((), jnp.int32, mesh, P()),
        v_est=_sds((), jnp.float32, mesh, P()),
        # (W, W) is filter-sized, not model-sized — replicate it
        gram_B=_sds((W, W), jnp.float32, mesh, P()),
    )
    from repro.distributed.byzantine_dp import DPGuardState
    from repro.distributed.trainer import TrainState

    state_sds = TrainState(
        params=params_sds,
        opt_state=opt_sds,
        guard=DPGuardState(**guard_sds),
        anchor=params_sds,
        step=_sds((), jnp.int32, mesh, P()),
    )

    batch_spec = _logical(("worker", None, None), (W, b, shape.seq_len), rules, mesh)
    batch_sds = {
        "tokens": _sds((W, b, shape.seq_len), jnp.int32, mesh, batch_spec),
        "labels": _sds((W, b, shape.seq_len), jnp.int32, mesh, batch_spec),
    }
    if cfg.frontend != "none":
        fshape = (W, b, cfg.frontend_seq if not cfg.enc_dec else cfg.enc_seq_len, cfg.frontend_dim)
        batch_sds["frontend"] = _sds(
            fshape, jnp.dtype(cfg.activation_dtype), mesh,
            _logical(("worker", None, None, None), fshape, rules, mesh),
        )
    byz_sds = _sds((W,), jnp.bool_, mesh, worker_spec)
    rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=_ns(mesh, P()))
    return state_sds, batch_sds, byz_sds, rng_sds


# ---------------------------------------------------------------------------
# serve specs
# ---------------------------------------------------------------------------

def make_serve_specs(
    model: LanguageModel, shape: InputShape, rules: dict, mesh: Mesh,
    cache_len: int | None = None,
):
    """(params_sds, cache_sds, token_sds) for AOT-lowering ``serve_step``."""
    cfg = model.cfg
    pdt = jnp.dtype(cfg.param_dtype)
    adt = jnp.dtype(cfg.activation_dtype)
    B = shape.global_batch
    L = cache_len if cache_len is not None else shape.seq_len

    with use_logical_rules(rules, mesh):
        pspecs = param_pspecs(model.defs, rules, mesh)
    params_sds = jax.tree_util.tree_map(
        lambda d, s: _sds(d.shape, pdt, mesh, s),
        model.defs, pspecs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
    )

    cache_abs = jax.eval_shape(lambda: model.init_cache(B, L, adt))
    cspecs = cache_specs(cache_abs, rules, mesh)
    cache_sds = jax.tree_util.tree_map(
        lambda a, s: _sds(a.shape, a.dtype, mesh, s), cache_abs, cspecs
    )
    token_sds = _sds((B, 1), jnp.int32, mesh, _logical(("batch", None), (B, 1), rules, mesh))
    return params_sds, cache_sds, token_sds


def make_prefill_specs(model: LanguageModel, shape: InputShape, rules: dict, mesh: Mesh):
    """(params_sds, batch_sds) for AOT-lowering ``prefill``."""
    cfg = model.cfg
    adt = jnp.dtype(cfg.activation_dtype)
    B, S = shape.global_batch, shape.seq_len
    params_sds, _, _ = make_serve_specs(model, shape, rules, mesh, cache_len=8)
    batch_sds = {
        "tokens": _sds((B, S), jnp.int32, mesh, _logical(("batch", None), (B, S), rules, mesh)),
    }
    if cfg.frontend != "none":
        F = cfg.frontend_seq if not cfg.enc_dec else cfg.enc_seq_len
        fshape = (B, F, cfg.frontend_dim)
        batch_sds["frontend"] = _sds(
            fshape, adt, mesh, _logical(("batch", None, None), fshape, rules, mesh)
        )
    return params_sds, batch_sds
