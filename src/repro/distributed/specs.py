"""Sharding-spec builders for whole train/serve states and input batches.

These produce (ShapeDtypeStruct tree, NamedSharding tree) pairs for AOT
lowering — the dry-run never allocates a byte.  Logical→mesh rules come
from :mod:`repro.distributed.sharding`; leaf kinds of caches / guard state
are resolved by field name + rank.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape
from repro.distributed.sharding import logical_to_spec, use_logical_rules, param_pspecs
from repro.models.model import LanguageModel

PyTree = Any


def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype, sharding=_ns(mesh, spec))


def _logical(axes, shape, rules, mesh) -> P:
    return logical_to_spec(tuple(axes), tuple(shape), rules, mesh)


# ---------------------------------------------------------------------------
# cache specs (decode/serve)
# ---------------------------------------------------------------------------

_CACHE_FIELD_AXES = {
    # field name → logical axes (leading 'None' = stacked layer axis)
    "k": (None, "batch", "cache_seq", "kv_heads", None),
    "v": (None, "batch", "cache_seq", "kv_heads", None),
    "ckv": (None, "batch", "cache_seq", None),
    "k_rope": (None, "batch", "cache_seq", None),
    "k_scale": (None, "batch", "cache_seq", "kv_heads"),
    "v_scale": (None, "batch", "cache_seq", "kv_heads"),
    "state": (None, "batch", "heads", None, None),
    "conv_x": (None, "batch", None, "mlp"),
    "conv_B": (None, "batch", None, None),
    "conv_C": (None, "batch", None, None),
    "pos": (),
}


def cache_specs(cache_abstract: PyTree, rules: dict, mesh: Mesh) -> PyTree:
    """PartitionSpec tree for an (abstract) decode cache."""

    def spec_for(path, leaf) -> P:
        name = None
        for pp in reversed(path):
            key = getattr(pp, "name", getattr(pp, "key", None))
            if isinstance(key, str):
                name = key
                break
        if name in _CACHE_FIELD_AXES and len(_CACHE_FIELD_AXES[name]) == leaf.ndim:
            return _logical(_CACHE_FIELD_AXES[name], leaf.shape, rules, mesh)
        # memory_kv tuples: (layers, B, Sm, H, hd)
        if leaf.ndim == 5:
            return _logical((None, "batch", None, "kv_heads", None), leaf.shape, rules, mesh)
        if leaf.ndim == 0:
            return P()
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, cache_abstract)


# ---------------------------------------------------------------------------
# train-state specs
# ---------------------------------------------------------------------------

def _flat_state_specs(abstract: PyTree, W: int, rules: dict, mesh: Mesh) -> PyTree:
    """ShapeDtypeStructs-with-shardings for a tree-harness-era state pytree
    (guard backends + adversary/feedback leaves, DESIGN.md §10), by shape:

    * (W,)     — per-worker scalars: worker axes ('pod','data') — this is
                 also what the (m,) leaves of a
                 :class:`repro.scenarios.spec.WorkerProfile` resolve to
                 (skew f32, delay int32, p_report f32 all live on the
                 worker axis; DESIGN.md §13)
    * (W, W)   — filter-sized Grams: replicated
    * (W, d)   — the flat B martingale / sketch — and the trainer's
                 stale-gradient buffer: worker × flat_grad('model')
    * (d,)     — flat anchors/feedback vectors: flat_grad('model')
    * ()       — replicated

    Unsigned-integer 1-D leaves are PRNG keys (the bucketing aggregator
    carries a (2,) uint32 key in its state), not flat-gradient vectors —
    they must be replicated, never sharded along 'model' or 'worker'.
    """
    def one(a):
        shape = tuple(a.shape)
        if shape == ():
            spec = P()
        elif len(shape) == 1 and jnp.issubdtype(a.dtype, jnp.unsignedinteger):
            spec = P()
        elif shape == (W,):
            spec = _logical(("worker",), shape, rules, mesh)
        elif shape == (W, W):
            spec = P()
        elif len(shape) == 2 and shape[0] == W:
            spec = _logical(("worker", "flat_grad"), shape, rules, mesh)
        elif len(shape) == 1:
            spec = _logical(("flat_grad",), shape, rules, mesh)
        else:
            spec = P(*([None] * len(shape)))
        return _sds(shape, a.dtype, mesh, spec)

    return jax.tree_util.tree_map(one, abstract)


def make_train_specs(
    model: LanguageModel,
    cfg: "SolverConfig",
    optimizer_kind: str,
    shape: InputShape,
    rules: dict,
    mesh: Mesh,
    V: float = 0.0,
    D: float = 10.0,
    adversary=None,
):
    """(state_sds, batch_sds, rank_sds, rng_sds) ShapeDtypeStruct trees with
    shardings for AOT-lowering ``train_step``.

    ``cfg`` is the trainer's :class:`repro.core.solver.SolverConfig`
    (``guard_backend`` selects the aggregation realization); the guard /
    adversary / feedback leaves of :class:`repro.distributed.trainer.TrainState`
    are derived by ``eval_shape`` over the *same* factories the trainer
    uses, so the specs can never drift from the real state structure.
    """
    from repro.core.solver import make_aggregator
    from repro.core.tree_harness import FlatSpec, params_harness
    from repro.distributed.trainer import TrainState, _grad_dtype

    mcfg = model.cfg
    pdt = jnp.dtype(mcfg.param_dtype)
    W = cfg.m
    assert shape.global_batch % W == 0, (shape.global_batch, W)
    b = shape.global_batch // W

    with use_logical_rules(rules, mesh):
        pspecs = param_pspecs(model.defs, rules, mesh)
    params_sds = jax.tree_util.tree_map(
        lambda d, s: _sds(d.shape, pdt, mesh, s),
        model.defs, pspecs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
    )

    # optimizer state
    if optimizer_kind == "adamw":
        f32 = lambda t: jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32, sharding=x.sharding), t
        )
        opt_sds = {"m": f32(params_sds), "v": f32(params_sds)}
    elif optimizer_kind == "momentum":
        opt_sds = {"m": jax.tree_util.tree_map(lambda x: x, params_sds)}
    else:
        opt_sds = {}

    harness = params_harness(model)
    fspec = FlatSpec(harness.d, V, D)
    guard_abs = jax.eval_shape(lambda: make_aggregator(fspec, cfg)[0])
    guard_sds = _flat_state_specs(guard_abs, W, rules, mesh)
    # adversary memory mirrors init_train_state: AdvState pytree under a
    # scenario adversary, a scalar zero on the static path — derived from
    # the same init so scenario runs lower against matching specs
    adv_abs = jax.eval_shape(
        (lambda: adversary.init_state(W, harness.d)) if adversary is not None
        else (lambda: jnp.zeros(()))
    )
    adv_sds = _flat_state_specs(adv_abs, W, rules, mesh)

    worker_spec = _logical(("worker",), (W,), rules, mesh)
    flat_spec = _logical(("flat_grad",), (harness.d,), rules, mesh)
    # stale-gradient buffer (DESIGN.md §13): present exactly when
    # init_train_state carries one — a (W, d) leaf sharded worker ×
    # flat_grad like the guard's B martingale; the schedule scalars that
    # drive it (cfg.max_delay) are static, nothing to shard
    stale_on = (getattr(adversary, "profile", None) is not None
                and cfg.max_delay > 0)
    grad_buf_sds = (_flat_state_specs(
        jax.ShapeDtypeStruct((W, harness.d), _grad_dtype(cfg, harness)),
        W, rules, mesh,
    ) if stale_on else ())
    state_sds = TrainState(
        params=params_sds,
        opt_state=opt_sds,
        guard=guard_sds,
        anchor=_sds((harness.d,), harness.flat_dtype, mesh, flat_spec),
        step=_sds((), jnp.int32, mesh, P()),
        ever_byz=_sds((W,), jnp.bool_, mesh, worker_spec),
        adv=adv_sds,
        prev_xi=_sds((harness.d,), harness.flat_dtype, mesh, flat_spec),
        prev_alive=_sds((W,), jnp.bool_, mesh, worker_spec),
        prev_n_alive=_sds((), jnp.int32, mesh, P()),
        grad_buf=grad_buf_sds,
    )

    batch_spec = _logical(("worker", None, None), (W, b, shape.seq_len), rules, mesh)
    batch_sds = {
        "tokens": _sds((W, b, shape.seq_len), jnp.int32, mesh, batch_spec),
        "labels": _sds((W, b, shape.seq_len), jnp.int32, mesh, batch_spec),
    }
    if mcfg.frontend != "none":
        fshape = (W, b, mcfg.frontend_seq if not mcfg.enc_dec else mcfg.enc_seq_len, mcfg.frontend_dim)
        batch_sds["frontend"] = _sds(
            fshape, jnp.dtype(mcfg.activation_dtype), mesh,
            _logical(("worker", None, None, None), fshape, rules, mesh),
        )
    rank_sds = _sds((W,), jnp.int32, mesh, worker_spec)
    rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=_ns(mesh, P()))
    return state_sds, batch_sds, rank_sds, rng_sds


# ---------------------------------------------------------------------------
# serve specs
# ---------------------------------------------------------------------------

def make_serve_specs(
    model: LanguageModel, shape: InputShape, rules: dict, mesh: Mesh,
    cache_len: int | None = None,
):
    """(params_sds, cache_sds, token_sds) for AOT-lowering ``serve_step``."""
    cfg = model.cfg
    pdt = jnp.dtype(cfg.param_dtype)
    adt = jnp.dtype(cfg.activation_dtype)
    B = shape.global_batch
    L = cache_len if cache_len is not None else shape.seq_len

    with use_logical_rules(rules, mesh):
        pspecs = param_pspecs(model.defs, rules, mesh)
    params_sds = jax.tree_util.tree_map(
        lambda d, s: _sds(d.shape, pdt, mesh, s),
        model.defs, pspecs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
    )

    cache_abs = jax.eval_shape(lambda: model.init_cache(B, L, adt))
    cspecs = cache_specs(cache_abs, rules, mesh)
    cache_sds = jax.tree_util.tree_map(
        lambda a, s: _sds(a.shape, a.dtype, mesh, s), cache_abs, cspecs
    )
    token_sds = _sds((B, 1), jnp.int32, mesh, _logical(("batch", None), (B, 1), rules, mesh))
    return params_sds, cache_sds, token_sds


def make_prefill_specs(model: LanguageModel, shape: InputShape, rules: dict, mesh: Mesh):
    """(params_sds, batch_sds) for AOT-lowering ``prefill``."""
    cfg = model.cfg
    adt = jnp.dtype(cfg.activation_dtype)
    B, S = shape.global_batch, shape.seq_len
    params_sds, _, _ = make_serve_specs(model, shape, rules, mesh, cache_len=8)
    batch_sds = {
        "tokens": _sds((B, S), jnp.int32, mesh, _logical(("batch", None), (B, S), rules, mesh)),
    }
    if cfg.frontend != "none":
        F = cfg.frontend_seq if not cfg.enc_dec else cfg.enc_seq_len
        fshape = (B, F, cfg.frontend_dim)
        batch_sds["frontend"] = _sds(
            fshape, adt, mesh, _logical(("batch", None, None), fshape, rules, mesh)
        )
    return params_sds, batch_sds
