"""Logical-axis sharding rules (t5x/MaxText style).

Model code annotates tensors with *logical* axis names ("heads", "mlp",
"experts", "vocab", "act_seq", …). A rules table maps logical names to mesh
axes; :func:`shard_act` applies ``with_sharding_constraint`` when a mesh is
active and is a no-op otherwise (so the same model code runs in unit tests
on one CPU device and under the 512-device dry-run).

Divisibility-aware: a logical axis is sharded only if the tensor dimension
is divisible by the mesh-axis size — otherwise it silently replicates.
This is what lets one rules table serve GQA models with kv_heads ∈
{2, 8, 16, 32} on a model axis of 16.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis → mesh axis (or None = replicate).
# Worker/data axes: the worker dimension of global batches shards over
# ("pod", "data"); per-worker batch/seq/embed stay unsharded across data.
LOGICAL_RULES_SINGLE_POD: dict[str, Any] = {
    "worker": ("data",),
    "batch": "data",          # used by non-byzantine paths / serving
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "embed": None,
    "embed_table": None,      # never FSDP'd: scatter-add gradient (see model.py)
    "act_seq": "model",       # sequence parallelism for the residual stream
    "act_embed": None,
    # the tree-harness flat parameter axis (DESIGN.md §10): ravelled (W, d)
    # guard state / anchors shard d over the model axis (d is lane-padded,
    # so divisibility holds whenever the model axis divides 128)
    "flat_grad": "model",
    "cache_seq": "model",     # decode KV caches shard over seq when batch is small
    "conv": None,
    "state": None,
}

LOGICAL_RULES_MULTI_POD: dict[str, Any] = dict(
    LOGICAL_RULES_SINGLE_POD,
    worker=("pod", "data"),
    batch=("pod", "data"),
)


class _RulesCtx(threading.local):
    def __init__(self):
        self.rules: Optional[dict] = None
        self.mesh: Optional[Mesh] = None


_CTX = _RulesCtx()


@contextlib.contextmanager
def use_logical_rules(rules: dict, mesh: Optional[Mesh] = None):
    """Activate a logical→mesh rules table (and optionally a mesh) for model
    tracing. ``shard_act``/``logical_to_spec`` read from this context."""
    prev_rules, prev_mesh = _CTX.rules, _CTX.mesh
    _CTX.rules, _CTX.mesh = rules, mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev_rules, prev_mesh


def _axis_size(mesh: Mesh, mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    size = 1
    for a in mesh_axes:
        size *= mesh.shape[a]
    return size


def logical_to_spec(
    logical_axes: tuple, shape: tuple | None = None,
    rules: dict | None = None, mesh: Mesh | None = None,
) -> P:
    """Map a tuple of logical axis names to a PartitionSpec under the active
    rules; drops shardings that don't divide the dimension (when ``shape``
    is provided and a mesh is active)."""
    rules = rules if rules is not None else _CTX.rules
    mesh = mesh if mesh is not None else _CTX.mesh
    if rules is None:
        return P(*([None] * len(logical_axes)))
    out = []
    used: set[str] = set()
    for i, name in enumerate(logical_axes):
        mesh_axes = rules.get(name) if name is not None else None
        if mesh_axes is not None and mesh is not None and shape is not None:
            if shape[i] % _axis_size(mesh, mesh_axes) != 0:
                mesh_axes = None
        # a mesh axis may appear at most once in a PartitionSpec: earlier
        # (higher-priority) logical dims win, later ones replicate
        if mesh_axes is not None:
            axes_tuple = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
            if any(a in used for a in axes_tuple):
                mesh_axes = None
            else:
                used.update(axes_tuple)
        out.append(mesh_axes)
    return P(*out)


def shard_act(x: jax.Array, *logical_axes) -> jax.Array:
    """Annotate an activation with logical axes (no-op without rules/mesh)."""
    if _CTX.rules is None or _CTX.mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = logical_to_spec(tuple(logical_axes), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def param_pspecs(defs, rules: dict, mesh: Mesh):
    """Tree of PartitionSpec for a tree of ParamDef (see models.common)."""
    from repro.models.common import ParamDef  # local import to avoid cycle

    def one(d: ParamDef):
        return logical_to_spec(d.axes, d.shape, rules, mesh)

    return jax.tree_util.tree_map(
        one, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


def named_sharding_tree(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
