"""Distributed training step: per-worker grads → Byzantine guard → optimizer.

``build_train_step`` returns a pure function suitable for ``jax.jit`` with
mesh shardings:

    state' , metrics = train_step(state, batch, byz_mask, rng)

* ``batch`` leaves are (W, per_worker_batch, ...) with W sharded over the
  mesh's worker axes ('pod','data').
* per-worker gradients come from vmap-of-grad: XLA partitions the vmap over
  the data axis, so each data slice computes exactly its own worker's
  gradient (params replicated over data, tensor-sharded over model).
* ``byz_mask`` marks simulated Byzantine workers; ``attack`` corrupts their
  gradient trees *after* honest computation (Remark 2.3 adversary).
* aggregation is pluggable: the paper's guard (stateful) or any stateless
  baseline (mean / coordinate median / trimmed mean / Krum) applied across
  the worker axis — the Table-1 comparison at LM scale.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.byzantine_dp import (
    DPGuardConfig,
    DPGuardState,
    apply_tree_attack,
    guard_step,
    init_guard_state,
    worker_cross_gram,
)
from repro.models.model import LanguageModel
from repro.optim.optimizers import Optimizer
from repro.utils import tree_add

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    guard: DPGuardState
    anchor: PyTree            # x_1 for the A-statistic
    step: jax.Array


def init_train_state(
    model: LanguageModel, optimizer: Optimizer, dp_cfg: DPGuardConfig, key: jax.Array,
) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        guard=init_guard_state(dp_cfg, params),
        anchor=jax.tree_util.tree_map(jnp.copy, params),
        step=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# stateless baselines across the worker axis
# ---------------------------------------------------------------------------

def aggregate_baseline(name: str, grads_w: PyTree, n_byzantine: int) -> PyTree:
    if name == "mean":
        return jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0), grads_w)
    if name == "coordinate_median":
        return jax.tree_util.tree_map(lambda g: jnp.median(g, axis=0), grads_w)
    if name == "trimmed_mean":
        def one(g):
            W = g.shape[0]
            b = max(min(n_byzantine, (W - 1) // 2), 0)
            s = jnp.sort(g, axis=0)
            return jnp.mean(s[b : W - b], axis=0)
        return jax.tree_util.tree_map(one, grads_w)
    if name == "krum":
        gram = worker_cross_gram(grads_w)
        diag = jnp.diagonal(gram)
        d2 = jnp.maximum(diag[:, None] + diag[None, :] - 2 * gram, 0.0)
        W = d2.shape[0]
        d2 = d2.at[jnp.arange(W), jnp.arange(W)].set(jnp.inf)
        n_near = max(W - n_byzantine - 2, 1)
        scores = jnp.sum(jnp.sort(d2, axis=1)[:, :n_near], axis=1)
        idx = jnp.argmin(scores)
        return jax.tree_util.tree_map(lambda g: g[idx], grads_w)
    raise KeyError(f"unknown aggregator {name!r}")


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def build_train_step(
    model: LanguageModel,
    optimizer: Optimizer,
    dp_cfg: DPGuardConfig,
    aggregator: str = "byzantine_sgd",
    attack: str = "none",
    attack_scale: float = 3.0,
) -> Callable:
    """Returns train_step(state, batch, byz_mask, rng) → (state', metrics)."""

    def loss_one(params, tb):
        loss, metrics = model.loss_fn(params, tb)
        return loss, metrics

    def train_step(state: TrainState, batch: dict, byz_mask: jax.Array, rng: jax.Array):
        grad_fn = jax.value_and_grad(loss_one, has_aux=True)

        def per_worker(tb):
            (loss, metrics), g = grad_fn(state.params, tb)
            return loss, g

        losses_w, grads_w = jax.vmap(per_worker)(batch)
        grads_w = apply_tree_attack(attack, rng, grads_w, byz_mask, scale=attack_scale)

        if aggregator == "byzantine_sgd":
            guard, xi, diag = guard_step(
                dp_cfg, state.guard, grads_w, state.params, state.anchor
            )
            n_alive = diag["n_alive"]
            alive = guard.alive
        else:
            xi = aggregate_baseline(aggregator, grads_w, int(dp_cfg.n_workers // 4))
            guard = state.guard
            n_alive = jnp.asarray(dp_cfg.n_workers)
            alive = jnp.ones((dp_cfg.n_workers,), bool)
            diag = {}

        updates, opt_state = optimizer.update(xi, state.opt_state, state.params, state.step)
        params = tree_add(state.params, updates)

        good = (~byz_mask).astype(jnp.float32)
        metrics = {
            "loss_good_workers": jnp.sum(losses_w * good) / jnp.maximum(jnp.sum(good), 1),
            "loss_all_workers": jnp.mean(losses_w),
            "n_alive": n_alive,
            "good_filtered": jnp.sum((~alive) & (~byz_mask)),
            "byz_alive": jnp.sum(alive & byz_mask),
        }
        if "v_est" in diag:
            metrics["v_est"] = diag["v_est"]
        new_state = TrainState(
            params=params, opt_state=opt_state, guard=guard,
            anchor=state.anchor, step=state.step + 1,
        )
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serve step (decode shapes)
# ---------------------------------------------------------------------------

def build_serve_step(model: LanguageModel) -> Callable:
    """serve_step(params, cache, tokens (B,1)) → (next_tokens (B,1), cache')."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step
