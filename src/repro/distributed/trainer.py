"""Distributed training step: per-worker grads → guard backend → optimizer
(DESIGN.md §10).

``build_train_step`` returns a pure function suitable for ``jax.jit`` with
mesh shardings:

    state', metrics = train_step(state, batch, byz_rank, key)

* ``batch`` leaves are (W, per_worker_batch, ...) with W sharded over the
  mesh's worker axes ('pod','data').
* per-worker gradients come from vmap-of-grad: XLA partitions the vmap over
  the data axis, so each data slice computes exactly its own worker's
  gradient (params replicated over data, tensor-sharded over model).
* the gradient pytree is presented to the aggregation layer through the
  **tree harness** (:mod:`repro.core.tree_harness`): ravelled to the flat
  ``(W, d)`` stacked view every guard backend, attack, and scenario
  adversary of the convex harness already consumes, with ξ unravelled back
  into a parameter-shaped update.  There is no trainer-specific guard
  implementation — ``SolverConfig.guard_backend`` selects ``dense`` /
  ``fused`` / ``dp_exact`` / ``dp_sketch`` exactly as ``run_sgd`` does, and
  stateless baselines (mean / coordinate median / trimmed mean / Krum /
  geometric median) come from the same :func:`repro.core.solver.make_aggregator`
  with Krum's f sized by the shared ⌈αm⌉ convention
  (:func:`repro.core.solver.ceil_byzantine_count`).
* ``byz_rank`` is the (W,) int32 per-worker rank (worker w is Byzantine iff
  its rank is below the realized count — :func:`repro.core.solver.byz_rank`);
  scenario adversaries re-derive a *per-step* mask from it (churn, late
  join), static attacks evaluate it once.
* the adversary is either the static ``cfg.attack`` from the flat zoo or a
  :class:`repro.scenarios.adversary.ScenarioAdversary` (duck-typed — any
  object with ``mask_at`` / ``init_state`` / ``attack`` / ``update_state``),
  whose ``AdvState`` is carried in :class:`TrainState` next to the guard
  state, with the Remark-2.3 feedback (previous ξ, alive, n_alive) fed to
  every attack's ``ctx``.

Training-specific ``ctx`` semantics (the solver knows the true gradient;
the trainer cannot): ``ctx["true_grad"]`` is the omniscient adversary's
best estimate — the mean of the *honest* rows of the current flat gradient
matrix — and ``ctx["V"]`` is the explicit ``V`` when given, else an
instantaneous estimate from the pre-attack (all-honest) gradient spread
(half the 25th-percentile pairwise distance, the dp guards' auto-V
convention) — computed for *every* aggregator, so V-scaled attacks hit
stateless baselines too, not only the calibrating guards.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import attacks as attack_lib
from repro.core.byzantine_sgd import resolve_stats_dtype
from repro.core.solver import SolverConfig, make_aggregator
from repro.core.tree_harness import FlatSpec, params_harness
from repro.distributed.byzantine_dp import v_from_gram
from repro.models.model import LanguageModel
from repro.obs.telemetry import telemetry_on
from repro.optim.optimizers import Optimizer
from repro.utils import tree_add

PyTree = Any


class TrainState(NamedTuple):
    """Everything one training run carries across steps — and everything a
    checkpoint must round-trip for resume-equals-uninterrupted (params AND
    optimizer moments AND guard martingales AND the anchor AND the
    adversary/feedback memory)."""

    params: PyTree
    opt_state: PyTree
    guard: PyTree             # backend-specific aggregator state (scan-carried)
    anchor: jax.Array         # (d,) flat x₁ — the A-statistic reference point
    step: jax.Array           # () int32
    ever_byz: jax.Array       # (W,) bool — workers that were *ever* Byzantine
    adv: PyTree               # adversary memory (scalar zero when static)
    prev_xi: jax.Array        # (d,) ξ_{k-1} — Remark-2.3 feedback
    prev_alive: jax.Array     # (W,) bool — good_{k-1}
    prev_n_alive: jax.Array   # () int32
    grad_buf: PyTree = ()     # (W, d) stale-gradient buffer when the run
    #                           carries a WorkerProfile delay schedule with
    #                           cfg.max_delay > 0 (DESIGN.md §13); the empty
    #                           tuple otherwise (the `adv` scalar-zero
    #                           convention: no leaves, no trace change)


def rank_from_mask(mask: jax.Array) -> jax.Array:
    """(W,) int32 rank with the mask's Byzantine workers ranked first, so
    ``rank < sum(mask)`` reproduces ``mask`` — the bridge from the
    historical bool-mask API to the rank convention."""
    return jnp.argsort(jnp.argsort(~mask)).astype(jnp.int32)


def _estimate_v(flat: jax.Array) -> jax.Array:
    """Instantaneous Assumption-2.2 scale from the *pre-attack* (all-honest)
    gradient rows — the guards' own :func:`v_from_gram` convention, so it is
    computable for every aggregator (the omniscient Remark-2.3 adversary can
    always measure the honest spread itself) and can never diverge from the
    radius the auto-V guards enforce.  Gram in f32 regardless of the flat
    view's storage dtype — the V scale must not wobble with stats_dtype."""
    f32 = flat.astype(jnp.float32)
    return jnp.maximum(v_from_gram(f32 @ f32.T), 1e-12)


def _grad_dtype(cfg: SolverConfig, harness) -> jnp.dtype:
    """Storage dtype of the (W, d) flat gradient view — the cast-once-at-
    ravel rule (DESIGN.md §5 Numerics): the guard's statistics dtype when
    the precision axis is lowered, else the harness dtype."""
    stats_jdt = resolve_stats_dtype(cfg.stats_dtype)
    return (stats_jdt if stats_jdt != jnp.dtype(jnp.float32)
            else harness.flat_dtype)


def _validate(cfg: SolverConfig, V: float) -> None:
    if (cfg.aggregator == "byzantine_sgd"
            and cfg.guard_backend in ("dense", "fused") and V <= 0):
        raise ValueError(
            f"guard backend {cfg.guard_backend!r} has no online auto-V; "
            "pass an explicit V (Assumption-2.2 deviation bound) or select "
            "an auto-V-capable backend (dp_exact / dp_sketch)"
        )


def init_train_state(
    model: LanguageModel,
    optimizer: Optimizer,
    cfg: SolverConfig,
    key: jax.Array,
    *,
    V: float = 0.0,
    D: float = 10.0,
    adversary=None,
) -> TrainState:
    _validate(cfg, V)
    harness = params_harness(model)
    params = model.init(key)
    guard0, _ = make_aggregator(FlatSpec(harness.d, V, D), cfg)
    adv0 = (adversary.init_state(cfg.m, harness.d) if adversary is not None
            else jnp.zeros(()))
    # stale-gradient buffer (DESIGN.md §13): carried only when the run's
    # adversary holds a WorkerProfile delay schedule and cfg.max_delay arms
    # it — every schedule refreshes at step 0, so the zeros are never
    # consumed.  Same dtype as the flat gradient view (_grad_dtype).
    stale_on = (getattr(adversary, "profile", None) is not None
                and cfg.max_delay > 0)
    grad_buf0 = (jnp.zeros((cfg.m, harness.d), _grad_dtype(cfg, harness))
                 if stale_on else ())
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        guard=guard0,
        anchor=harness.ravel(params),
        step=jnp.zeros((), jnp.int32),
        ever_byz=jnp.zeros((cfg.m,), bool),
        adv=adv0,
        prev_xi=jnp.zeros((harness.d,), harness.flat_dtype),
        prev_alive=jnp.ones((cfg.m,), bool),
        prev_n_alive=jnp.asarray(cfg.m, jnp.int32),
        grad_buf=grad_buf0,
    )


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def build_train_step(
    model: LanguageModel,
    optimizer: Optimizer,
    cfg: SolverConfig,
    *,
    V: float = 0.0,
    D: float = 10.0,
    adversary=None,
    telemetry=None,
) -> Callable:
    """Returns train_step(state, batch, byz_rank, key) → (state', metrics).

    ``cfg`` is the *same* :class:`~repro.core.solver.SolverConfig` the flat
    harness uses: ``aggregator`` / ``guard_backend`` / ``guard_opts`` select
    the aggregation path, ``attack`` / ``attack_kwargs`` the static
    adversary (ignored when ``adversary`` is given), ``alpha`` the realized
    Byzantine fraction (floor — whole workers), and ``m`` / ``T`` /
    ``threshold_mode`` / ``mean_over_alive`` / ``delta`` the filter.
    ``cfg.eta`` is unused — the optimizer owns the learning rate.

    ``key`` is the per-step attack/adversary key (callers derive it from a
    dedicated stream, e.g. ``fold_in(loop_key, step)`` — see
    ``repro.launch.train``).  ``adversary`` may close over traced leaves, so
    a whole (scenario × α × seed) grid of *training runs* vmaps into one jit
    (:func:`repro.scenarios.train_campaign.run_train_campaign`).

    ``telemetry`` (:class:`repro.obs.TelemetryConfig`, DESIGN.md §12) arms
    the flight recorder: the aggregator runs in probed form and the frame
    joins ``metrics`` under ``tel/``-prefixed keys (per-worker arrays
    included), riding the trainer's existing stacked-metrics flush — no
    ring buffer needed, the chunked scan driver already transfers metrics
    once per ``log_every`` chunk.  Off (the default) leaves the metrics
    schema and trace untouched.
    """
    _validate(cfg, V)
    harness = params_harness(model)
    spec = FlatSpec(harness.d, V, D)
    tel_on = telemetry_on(telemetry)
    _, agg_step = make_aggregator(spec, cfg, telemetry)
    # cast-once-at-ravel (DESIGN.md §5 Numerics): gradient trees ravel
    # straight into the guard's statistics dtype — natively-bf16 LM grads
    # skip the f32 inflation pass entirely under stats_dtype='bf16'.
    # Params/anchor keep the harness dtype: positions feed the optimizer,
    # only the *statistics* ride the precision axis (the guard rounds its
    # own view of delta internally).
    grad_dtype = _grad_dtype(cfg, harness)
    # per-worker-state gates (DESIGN.md §13) — static Python decisions,
    # mirroring run_sgd: no profile (or machinery axis off) lowers to the
    # pre-profile trace, which is the trainer half of the degenerate-
    # WorkerProfile bit-identity guarantee.  The data-skew leg lives in the
    # batch pipeline (make_worker_batch's `skew`), not here.
    profile = getattr(adversary, "profile", None)
    stale_on = profile is not None and cfg.max_delay > 0
    part_on = profile is not None and cfg.partial_participation
    if adversary is None:
        attack_fn = attack_lib.get_attack(cfg.attack)
        attack_kwargs = dict(cfg.attack_kwargs)

    def loss_one(params, tb):
        loss, metrics = model.loss_fn(params, tb)
        return loss, metrics

    def train_step(state: TrainState, batch: dict, byz_rank: jax.Array,
                   key: jax.Array):
        k = state.step
        grad_fn = jax.value_and_grad(loss_one, has_aux=True)

        def per_worker(tb):
            (loss, _), g = grad_fn(state.params, tb)
            return loss, g

        losses_w, grads_w = jax.vmap(per_worker)(batch)
        flat = harness.ravel_workers(grads_w, dtype=grad_dtype)  # (W, d) view
        x = harness.ravel(state.params)

        grad_buf = state.grad_buf
        if stale_on:
            # periodic-refresh staleness (run_sgd's model): a straggler's
            # row recomputes only when its schedule fires; between
            # refreshes the carried stale row (a gradient of older params)
            # is what reaches the attack and the aggregation layer
            refresh = adversary.refresh_at(k, cfg.max_delay)
            grad_buf = jnp.where(refresh[:, None], flat, grad_buf)
            flat = grad_buf

        if adversary is None:
            mask_k = byz_rank < cfg.n_byzantine
        else:
            mask_k = adversary.mask_at(byz_rank, k)
        good_w = (~mask_k).astype(flat.dtype)[:, None]
        honest_mean = (jnp.sum(flat * good_w, axis=0)
                       / jnp.maximum(jnp.sum(good_w), 1.0))
        v_ctx = (jnp.asarray(V, jnp.float32) if V > 0
                 else _estimate_v(flat))   # flat is pre-attack: all honest
        ctx = {
            "true_grad": honest_mean, "V": v_ctx, "step": k,
            "alive": state.prev_alive, "n_alive": state.prev_n_alive,
            "prev_xi": state.prev_xi,
        }
        if adversary is None:
            flat = attack_fn(key, flat, mask_k, ctx, **attack_kwargs)
        else:
            flat = adversary.attack(key, flat, mask_k, ctx, state.adv)

        if part_on:
            # reporting mask ≠ Byzantine mask: honest workers skip steps
            # per p_report, Byzantine workers always deliver (worst case).
            # fold_in leaves the attack's own key stream untouched, so
            # armed machinery with p_report ≡ 1 stays on-trajectory.
            pkey = jax.random.fold_in(key, 7919)
            report = adversary.report_at(pkey, mask_k)
            n_rep = jnp.sum(report).astype(jnp.int32)
        else:
            report = None

        if tel_on:
            guard, xi_flat, n_alive, alive, frame = agg_step(
                state.guard, flat, x, state.anchor, report
            )
        else:
            guard, xi_flat, n_alive, alive = agg_step(
                state.guard, flat, x, state.anchor, report
            )
        adv = state.adv
        if adversary is not None:
            adv = adversary.update_state(
                state.adv, mask_k, flat, xi_flat, alive, n_alive, ctx
            )

        xi_tree = harness.unravel(xi_flat)
        updates, opt_state = optimizer.update(
            xi_tree, state.opt_state, state.params, k
        )
        params = tree_add(state.params, updates)

        ever_byz = state.ever_byz | mask_k
        good = (~mask_k).astype(jnp.float32)
        metrics = {
            "loss_good_workers": jnp.sum(losses_w * good)
            / jnp.maximum(jnp.sum(good), 1),
            "loss_all_workers": jnp.mean(losses_w),
            "n_alive": jnp.asarray(n_alive, jnp.int32),
            "good_filtered": jnp.sum((~alive) & (~ever_byz)),
            "byz_alive": jnp.sum(alive & mask_k),
            "n_byz": jnp.sum(mask_k),
            # uniform schema across every aggregator/backend: auto-V-less
            # paths report NaN instead of dropping the key, so stacked
            # campaign metrics and log records never go ragged
            "v_est": (guard.v_est if hasattr(guard, "v_est")
                      else jnp.full((), jnp.nan, jnp.float32)),
            # per-worker-state axis (DESIGN.md §13), same NaN-uniform rule
            "n_reporting": (n_rep.astype(jnp.float32) if part_on
                            else jnp.full((), jnp.nan, jnp.float32)),
        }
        if tel_on:
            # complete the frame with trainer-level signals (the solver's
            # run_sgd convention: 1-based step, ‖ξ‖, adversary feedback)
            frame["step"] = (k + 1).astype(jnp.float32)
            frame["xi_norm"] = jnp.linalg.norm(
                xi_flat.astype(jnp.float32))
            scale = getattr(adv, "adapt_scale", None)
            if scale is not None:
                frame["adapt_scale"] = jnp.asarray(scale, jnp.float32)
            if part_on:
                frame["n_reporting"] = n_rep.astype(jnp.float32)
            if stale_on:
                frame["staleness"] = jnp.mean(
                    adversary.staleness_at(k, cfg.max_delay)
                    .astype(jnp.float32)
                )
            metrics.update({f"tel/{key}": val for key, val in frame.items()})
        new_state = TrainState(
            params=params, opt_state=opt_state, guard=guard,
            anchor=state.anchor, step=k + 1, ever_byz=ever_byz, adv=adv,
            prev_xi=xi_flat.astype(state.prev_xi.dtype), prev_alive=alive,
            prev_n_alive=jnp.asarray(n_alive, jnp.int32),
            grad_buf=grad_buf,
        )
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serve step (decode shapes)
# ---------------------------------------------------------------------------

def build_serve_step(model: LanguageModel) -> Callable:
    """serve_step(params, cache, tokens (B,1)) → (next_tokens (B,1), cache')."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step
