"""ByzantineSGD as a first-class data-parallel gradient aggregation feature.

Each data-parallel slice of the mesh is one of the paper's m "worker
machines".  ``train_step`` computes per-worker gradients (vmap-of-grad with
the worker axis sharded over ('pod','data')), maintains the Algorithm-1
martingales per worker, filters, and replaces the usual psum-mean with a
masked filtered mean.  The filter itself is the *same* ``filter_update``
used by the single-host reference in :mod:`repro.core.byzantine_sgd` — only
the Gram matrices are produced differently.

Two guard modes (DESIGN.md §3):

* ``exact`` — paper-faithful.  The B_i martingale is a full parameter-sized
  pytree per worker (leading worker axis sharded over data, so each device
  stores exactly one worker's model-shard — the same footprint as one extra
  optimizer moment).  Gram matrices are leaf-wise ``einsum('w...,v...->wv')``
  contractions; XLA realizes the required all-gather of gradient shards
  over the data axis (the same order of communication mini-batch SGD's
  all-reduce already pays).  With ``incremental_gram`` (default) the
  B-Gram is carried in state and rank-updated from the gradient
  all-gather (DESIGN.md §5), so B shards themselves never travel.

* ``sketch`` — beyond-paper scalable variant.  Per-worker gradients are
  CountSketched (feature hashing: s_j = Σ_{h(i)=j} σ(i)·g_i, computed
  leaf-wise with an iota hash — no projection matrix is ever materialized)
  into k ≪ d dims.  Cross-worker inner products use the sketches (unbiased,
  variance ‖g_i‖‖g_j‖/√k); diagonal norms stay exact (free, local).  The
  data-axis communication drops from O(|params|) to O(W·k) and the B-state
  from |params| to k floats per worker.  Thresholds get a configurable
  slack factor to absorb sketch noise.

V (the Assumption-2.2 deviation bound) is rarely known for neural nets;
``auto_v`` calibrates it online as an EMA of the **25th percentile** of
pairwise distances between fresh worker gradients (DESIGN.md §3): good–good
pairs are a (1 − α)² ≥ 25% fraction of all pairs whenever α < 1/2, so the
25th percentile is always witnessed by an honest pair across the paper's
*entire* α < 1/2 regime.  The plain median is not: attacker-involved pairs
outnumber honest ones once 1 − (1 − α)² > 1/2, i.e. α > 1 − 1/√2 ≈ 0.29 —
safe at α = 0.25, inflatable well before the breakdown point.  Good
workers concentrate, so the chosen quantile ≈ 2·(typical deviation) and
halving it estimates V.

Both guard modes also run on the *flat* (m, d) convex harness as guard
backends ``dp_exact`` / ``dp_sketch`` (:mod:`repro.core.guard_backends`,
DESIGN.md §9): a stacked gradient array is a one-leaf worker pytree and
the iterate/anchor stand in for params, so the same ``guard_step`` is
sweepable under the scenario campaigns with no adaptation layer.  Since
the §10 unification the **trainer drives the same flat view**: LM
training ravels its gradient pytrees through
:mod:`repro.core.tree_harness` and selects these modes as the
``dp_exact`` / ``dp_sketch`` backends of ``SolverConfig.guard_backend``
(the pytree ``guard_step`` path below remains the mesh-sharded
realization the leaf-wise contractions were written for).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.byzantine_sgd import (
    GuardConfig,
    counting_median_index,
    filter_update,
    pairwise_sq_dists_from_gram,
    resolve_stats_dtype,
)

PyTree = Any


class DPGuardConfig(NamedTuple):
    n_workers: int
    T: int                       # planned total steps (enters C)
    V: float = 0.0               # 0 + auto_v → calibrated online
    D: float = 10.0              # trust-region diameter for the A-statistic
    delta: float = 1e-3
    mode: str = "sketch"         # 'exact' | 'sketch'
    sketch_dim: int = 4096
    sketch_slack: float = 1.5    # threshold multiplier absorbing sketch noise
    threshold_mode: str = "anytime"
    mean_over_alive: bool = True
    auto_v: bool = True
    v_ema: float = 0.9
    grad_radius_mult: float = 4.0
    # §Perf lever: False (default) materializes f32 copies of per-worker
    # gradients for every statistic (simple, paper-faithful numerics);
    # True keeps gradients in their native dtype and accumulates in f32
    # inside the contractions (preferred_element_type) — no param-sized
    # f32 temporaries, halved all-gather bytes.  The config axis
    # ``SolverConfig.stats_dtype='bf16'`` sets this implicitly (the two
    # knobs named the same lever before the axis existed).
    low_precision_stats: bool = False
    # Storage dtype of the B martingale ('f32' | 'bf16' — the stats-
    # precision axis of DESIGN.md §5 Numerics).  bf16 halves the guard's
    # resident state and the bytes every B-side pass moves; the per-step
    # rounding it introduces is bounded by gram_resync_every below.
    stats_dtype: str = "f32"
    # Incremental B-Gram (exact mode; DESIGN.md §5): maintain ⟨B_i, B_j⟩
    # across steps via G_B += B gᵀ + g Bᵀ + g gᵀ instead of re-contracting
    # the full B pytree.  The cross term reuses the gradient all-gather the
    # ∇-Gram already pays, so the per-step collective volume of the exact
    # guard halves (B shards never move).  False re-derives G_B from B
    # every step — the drift oracle.
    incremental_gram: bool = True
    # Every N steps re-derive gram_B from B (one full contraction), zeroing
    # the accumulated rounding of the incremental path — essential under
    # low_precision_stats, where each cross term rounds the local B shard
    # to bf16.  0 disables resync.
    gram_resync_every: int = 64

    def guard_config(self, v_eff) -> GuardConfig:
        # jnp scalar V is fine: GuardConfig.thresholds only multiplies by it
        return GuardConfig(
            m=self.n_workers, T=self.T, V=v_eff, D=self.D, delta=self.delta,
            threshold_mode=self.threshold_mode, mean_over_alive=self.mean_over_alive,
            grad_radius_mult=self.grad_radius_mult,
        )


class DPGuardState(NamedTuple):
    A: jax.Array                 # (W,)
    B: PyTree                    # sketch: (W, k); exact: pytree, leaves
    #                              (W, *leaf) — stored in cfg.stats_dtype
    alive: jax.Array             # (W,) bool
    k: jax.Array                 # () int32
    v_est: jax.Array             # () f32 — calibrated V (EMA)
    gram_B: jax.Array            # (W, W) ⟨B_i, B_j⟩ — incremental (DESIGN.md §5)


# ---------------------------------------------------------------------------
# tree ↔ worker-axis algebra
# ---------------------------------------------------------------------------

def _leaf_f32(x):
    return x.astype(jnp.float32)


def worker_vdot(ga: PyTree, gb: PyTree, low_precision: bool = False) -> jax.Array:
    """⟨g_i, h_i⟩ per worker. Leaves of ga have leading W; gb may either
    share the leading W or be unbatched (broadcast). With ``low_precision``
    inputs stay in native dtype and only the contraction accumulates f32
    (no param-sized f32 temporaries)."""
    def one(a, b):
        if not low_precision:
            a, b = _leaf_f32(a), _leaf_f32(b)
        elif a.dtype != b.dtype:
            # dot_general needs one dtype; round the broadcast operand
            # (delta) down to the gradient dtype — the same rounding the
            # dense stats path applies to its delta view
            b = b.astype(a.dtype)
        if b.ndim == a.ndim - 1:
            b = b[None]
        W = a.shape[0]
        return jax.lax.dot_general(
            a.reshape(W, -1), jnp.broadcast_to(b, a.shape).reshape(W, -1),
            (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
    parts = jax.tree_util.tree_map(one, ga, gb)
    return functools.reduce(jnp.add, jax.tree_util.tree_leaves(parts))


def worker_sq_norms(g: PyTree, low_precision: bool = False) -> jax.Array:
    return worker_vdot(g, g, low_precision)


def worker_cross_gram(g: PyTree, low_precision: bool = False) -> jax.Array:
    """Full (W, W) Gram — exact mode. The self-pair case of
    :func:`worker_pair_gram`; XLA inserts the data-axis all-gather of
    gradient shards."""
    return worker_pair_gram(g, g, low_precision)


def worker_pair_gram(ga: PyTree, gb: PyTree, low_precision: bool = False) -> jax.Array:
    """(W, W) cross-Gram ⟨a_i, b_j⟩ between two worker-stacked pytrees —
    the ``B gᵀ`` term of the incremental Gram update.  Only ``gb`` (the
    fresh gradients) needs gathering across the worker axis; ``ga`` (the
    B martingale) is consumed at its home shard, so the exact guard's
    B-sized all-gather disappears.  With ``low_precision`` the gradient
    operand stays in its native dtype — reusing the same half-width
    gather ``gram_g`` already pays — and the *local* B shard is rounded
    down to match (dot_general needs one dtype; rounding the ungathered
    side keeps the wire bytes halved), accumulating in f32 as usual."""
    def one(a, b):
        if low_precision:
            a = a.astype(b.dtype)
        else:
            a, b = _leaf_f32(a), _leaf_f32(b)
        a2 = a.reshape(a.shape[0], -1)
        b2 = b.reshape(b.shape[0], -1)
        return jax.lax.dot_general(
            a2, b2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    parts = jax.tree_util.tree_map(one, ga, gb)
    return functools.reduce(jnp.add, jax.tree_util.tree_leaves(parts))


# ---------------------------------------------------------------------------
# CountSketch (sketch mode)
# ---------------------------------------------------------------------------

def _sign_iota(n: int, salt: int) -> jax.Array:
    """Deterministic ±1 per coordinate via a Knuth multiplicative hash of the
    flat index — generated on the fly, nothing stored."""
    idx = jax.lax.iota(jnp.uint32, n)
    h = (idx + jnp.uint32((salt * 0x9E3779B9 + 1) & 0xFFFFFFFF)) * jnp.uint32(2654435761)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    return 1.0 - 2.0 * (h & 1).astype(jnp.float32)


def sketch_tree(g: PyTree, k: int, low_precision: bool = False) -> jax.Array:
    """CountSketch each worker's gradient into (W, k).

    Bucketing is the *stride* pattern (coordinate i → bucket i mod k), which
    with independent random signs is still an unbiased CountSketch
    (E⟨s_i, s_j⟩ = ⟨g_i, g_j⟩ holds for any fixed bucketing; only the signs
    must be random).  The stride form is a pad+reshape+reduce — no scatter —
    which both maps onto TPU reductions and avoids XLA SPMD's scatter
    partitioner on multi-axis-sharded operands.

    ``low_precision``: sign-flip in the gradient's native dtype (±1 is
    exact in bf16) and accumulate the fold in f32 — avoids an f32 copy of
    the whole gradient."""
    leaves = jax.tree_util.tree_leaves(g)
    out = jnp.zeros((leaves[0].shape[0], k), jnp.float32)
    for salt, leaf in enumerate(leaves):
        W = leaf.shape[0]
        flat = (leaf if low_precision else _leaf_f32(leaf)).reshape(W, -1)
        n = flat.shape[1]
        sign = _sign_iota(n, salt).astype(flat.dtype)
        signed = flat * sign[None, :]
        pad = (-n) % k
        if pad:
            signed = jnp.pad(signed, ((0, 0), (0, pad)))
        out = out + jnp.sum(signed.reshape(W, -1, k), axis=1, dtype=jnp.float32)
    return out


def sketch_gram(s: jax.Array, sq_norms: jax.Array) -> jax.Array:
    """Gram from sketches with the exact diagonal patched in (norms are
    local/free; only cross terms need the sketch estimate)."""
    G = s @ s.T
    W = s.shape[0]
    return G.at[jnp.arange(W), jnp.arange(W)].set(sq_norms)


# ---------------------------------------------------------------------------
# the guard
# ---------------------------------------------------------------------------

def init_guard_state(cfg: DPGuardConfig, params_like: PyTree) -> DPGuardState:
    W = cfg.n_workers
    sdt = resolve_stats_dtype(cfg.stats_dtype)
    if cfg.mode == "sketch":
        B = jnp.zeros((W, cfg.sketch_dim), sdt)
    else:
        B = jax.tree_util.tree_map(
            lambda x: jnp.zeros((W, *x.shape), sdt), params_like
        )
    return DPGuardState(
        A=jnp.zeros((W,), jnp.float32),
        B=B,
        alive=jnp.ones((W,), bool),
        k=jnp.zeros((), jnp.int32),
        v_est=jnp.zeros((), jnp.float32),
        gram_B=jnp.zeros((W, W), jnp.float32),
    )


def _masked_quantile(x: jax.Array, mask: jax.Array, q: float) -> jax.Array:
    """``jnp.quantile(x[mask], q)`` with a traced mask and static shapes —
    the same linear-interpolation formula, so an all-True mask is
    bit-identical to the unmasked quantile."""
    n = jnp.sum(mask)
    s = jnp.sort(jnp.where(mask, x, jnp.inf))
    index = q * jnp.maximum(n - 1, 0).astype(jnp.float32)
    low = jnp.floor(index)
    high = jnp.ceil(index)
    low_val = s[low.astype(jnp.int32)]
    high_val = s[high.astype(jnp.int32)]
    w = index - low
    return low_val * (1.0 - w) + high_val * w


def v_from_gram(gram_g: jax.Array, report: jax.Array | None = None) -> jax.Array:
    """The Assumption-2.2 scale convention: half the 25th-percentile
    pairwise distance from a fresh-gradient Gram.

    Invariant behind the 0.25 quantile (NOT the median): for α < 1/2,
    good-good pairs are a (1-α)² > (1/2)² = 25% fraction of all pairs, so
    the 25th percentile is always witnessed by an honest pair — a
    Byzantine-proof estimate of the honest deviation scale over the whole
    α < 1/2 regime.  The median only survives attacker-pair fractions
    below 1/2, which fails once α > 1−1/√2 ≈ 0.29 (e.g. at α=0.375 with
    m=8, 18 of 28 pairs involve an attacker and the median is theirs).

    Single source of the convention: the guards' auto-V calibration below
    and the trainer's adversary ``ctx["V"]`` estimate (DESIGN.md §10) both
    call this, so the attack magnitudes always probe the same radius the
    filter enforces.

    ``report`` (optional (m,) bool reporting mask, DESIGN.md §13) restricts
    the quantile to pairs where *both* endpoints reported — a zero-masked
    non-reporter row would otherwise inject spurious ‖g_j‖-sized distances
    into the estimate.
    """
    d2 = pairwise_sq_dists_from_gram(gram_g)
    W = d2.shape[0]
    iu, ju = jnp.triu_indices(W, k=1)
    off = d2[iu, ju]
    if report is None:
        q = jnp.quantile(off, 0.25)
    else:
        q = _masked_quantile(off, report[iu] & report[ju], 0.25)
    return jnp.sqrt(q) * 0.5


def _calibrate_v(
    cfg: DPGuardConfig, gram_g: jax.Array, v_prev: jax.Array,
    report: jax.Array | None = None,
) -> jax.Array:
    if not cfg.auto_v:
        return jnp.asarray(cfg.V, jnp.float32)
    v_now = v_from_gram(gram_g, report)
    v_new = jnp.where(v_prev > 0, cfg.v_ema * v_prev + (1 - cfg.v_ema) * v_now, v_now)
    return jnp.maximum(v_new, 1e-12)


def guard_step(
    cfg: DPGuardConfig,
    state: DPGuardState,
    grads_w: PyTree,          # leaves (W, ...) — worker axis sharded over data
    params: PyTree,
    anchor: PyTree,           # x_1 — the A-statistic reference point
    report: jax.Array | None = None,  # (W,) bool — who reported this step
) -> tuple[DPGuardState, PyTree, dict]:
    """One filter + aggregation step. Returns (state', ξ (params-like), diag)."""
    W = cfg.n_workers
    k_new = state.k + 1
    lp = cfg.low_precision_stats
    sdt = resolve_stats_dtype(cfg.stats_dtype)
    if sdt != jnp.dtype(jnp.float32):
        # the single entry rounding of the stats axis (same convention as
        # the dense/fused guards): every statistic below — A, both Grams,
        # the cross term, B, ξ — consumes the *rounded* gradients, so the
        # incremental Gram tracks the same martingale the bf16 B storage
        # actually accumulates (a no-op when the trainer already ravelled
        # to bf16; f32 flat-harness inputs are rounded here)
        grads_w = jax.tree_util.tree_map(lambda g: g.astype(sdt), grads_w)

    def _mask_workers(g: PyTree) -> PyTree:
        # entry masking for partial participation (DESIGN.md §13): zero
        # rows freeze A/B for non-reporters and keep the incremental-Gram
        # identity exact, so every contraction below runs unchanged
        return jax.tree_util.tree_map(
            lambda x: jnp.where(
                report.reshape((-1,) + (1,) * (x.ndim - 1)), x,
                jnp.zeros((), x.dtype)),
            g,
        )

    if report is not None:
        grads_w = _mask_workers(grads_w)

    # --- martingale updates -------------------------------------------------
    if lp:
        delta = jax.tree_util.tree_map(
            lambda p, a: (p.astype(jnp.float32) - a.astype(jnp.float32)).astype(p.dtype),
            params, anchor,
        )
    else:
        delta = jax.tree_util.tree_map(
            lambda p, a: _leaf_f32(p) - _leaf_f32(a), params, anchor
        )
    A = state.A + worker_vdot(grads_w, delta, lp)

    sq_g = worker_sq_norms(grads_w, lp)
    if cfg.mode == "sketch":
        # Center before sketching: pairwise distances are invariant under a
        # common shift, but sketch noise scales with the norms of what is
        # sketched — ‖g_i − ḡ‖ (the deviation scale, what the filter
        # measures) instead of ‖g_i‖ (huge and common-mode). One extra
        # mean-reduce of the gradients, orders less than exact mode's
        # all-gather.
        if report is None:
            n_mean = None
        else:
            # reporter-count mean: masked rows are already zero, so the sum
            # runs over reporters — only the divisor changes
            n_mean = jnp.maximum(jnp.sum(report), 1).astype(jnp.float32)
        if lp:
            g_mean = jax.tree_util.tree_map(
                lambda g: (jnp.mean(g, axis=0, keepdims=True, dtype=jnp.float32)
                           if n_mean is None else
                           jnp.sum(g, axis=0, keepdims=True, dtype=jnp.float32)
                           / n_mean).astype(g.dtype), grads_w
            )
            g_cent = jax.tree_util.tree_map(lambda g, c: g - c, grads_w, g_mean)
        else:
            g_mean = jax.tree_util.tree_map(
                lambda g: (jnp.mean(_leaf_f32(g), axis=0, keepdims=True)
                           if n_mean is None else
                           jnp.sum(_leaf_f32(g), axis=0, keepdims=True) / n_mean),
                grads_w,
            )
            g_cent = jax.tree_util.tree_map(
                lambda g, c: _leaf_f32(g) - c, grads_w, g_mean
            )
        if report is not None:
            # re-mask after centering: a non-reporter's centered row would
            # be −ḡ (not 0) and leak into its frozen B sketch
            g_cent = _mask_workers(g_cent)
        sq_cent = worker_sq_norms(g_cent, lp)
        s_g = sketch_tree(g_cent, cfg.sketch_dim, lp)
        # (W, k) sketch state: stored in the stats dtype, accumulated and
        # contracted in f32 (the sketch is tiny — the cast is free)
        B = (state.B.astype(jnp.float32) + s_g).astype(sdt)
        gram_g = sketch_gram(s_g, sq_cent)
        B32 = B.astype(jnp.float32)
        gram_B = sketch_gram(B32, jnp.sum(B32 * B32, axis=-1))
    else:
        if lp:
            # no param-sized f32 temporaries: native-dtype add, stored in
            # the stats dtype (the one new rounding of the bf16 axis)
            B = jax.tree_util.tree_map(
                lambda b, g: (b + g.astype(b.dtype)).astype(sdt),
                state.B, grads_w,
            )
        else:
            B = jax.tree_util.tree_map(
                lambda b, g: (_leaf_f32(b) + _leaf_f32(g)).astype(sdt),
                state.B, grads_w,
            )
        gram_g = worker_cross_gram(grads_w, lp)
        if cfg.incremental_gram:
            def _incremental():
                # G_B^k = G_B^{k-1} + B gᵀ + g Bᵀ + g gᵀ — no contraction
                # over (and no all-gather of) the accumulated B pytree
                cross = worker_pair_gram(state.B, grads_w, lp)
                return state.gram_B + cross + cross.T + gram_g

            if cfg.gram_resync_every > 0:
                # zero the accumulated rounding (bf16 cross terms under lp)
                # with a from-scratch contraction every N-th step; both
                # alternatives live inside the cond so only one is paid
                gram_B = jax.lax.cond(
                    k_new % cfg.gram_resync_every == 0,
                    lambda: worker_cross_gram(B, lp),
                    _incremental,
                )
            else:
                gram_B = _incremental()
        else:
            gram_B = worker_cross_gram(B, lp)

    # --- V calibration + filter --------------------------------------------
    # guard/filter named scope (DESIGN.md §12 span convention): the dp
    # backends share the dense/fused phase names so one XLA profile query
    # attributes filter time across all four realizations
    with jax.named_scope("guard/filter"):
        v_eff = _calibrate_v(cfg, gram_g, state.v_est, report)
        slack = cfg.sketch_slack if cfg.mode == "sketch" else 1.0
        gcfg = cfg.guard_config(v_eff * slack)
        good_k, diag = filter_update(
            A, gram_B, gram_g, state.alive, k_new, gcfg, report
        )

    # --- filtered mean (the paper's ξ_k) -------------------------------------
    # ξ averages the gradients that actually arrived: good ∩ reporting
    contrib = good_k if report is None else good_k & report
    denom = jnp.where(
        cfg.mean_over_alive, jnp.maximum(jnp.sum(contrib), 1), W
    ).astype(jnp.float32)
    w = contrib.astype(jnp.float32) / denom
    with jax.named_scope("guard/aggregate"):
        if lp:
            # fused mask-and-reduce in native dtype, f32 accumulation — this
            # is what the filtered_mean Pallas kernel computes on TPU
            xi = jax.tree_util.tree_map(
                lambda g: jnp.einsum(
                    "w,w...->...", w.astype(g.dtype), g,
                    preferred_element_type=jnp.float32,
                ).astype(g.dtype),
                grads_w,
            )
        else:
            xi = jax.tree_util.tree_map(
                lambda g: jnp.einsum("w,w...->...", w, _leaf_f32(g)).astype(g.dtype),
                grads_w,
            )

    diag = dict(diag, v_est=v_eff, sq_norm_mean=jnp.mean(sq_g))
    new_state = DPGuardState(A=A, B=B, alive=good_k, k=k_new, v_est=v_eff,
                             gram_B=gram_B)
    return new_state, xi, diag


# ---------------------------------------------------------------------------
# gradient-level attack simulation on the worker axis
# ---------------------------------------------------------------------------

def apply_tree_attack(
    name: str, key: jax.Array, grads_w: PyTree, byz_mask: jax.Array, scale: float = 3.0,
) -> PyTree:
    """Overwrite Byzantine workers' gradient trees. ``byz_mask``: (W,) bool."""
    def mask_like(leaf):
        return byz_mask.reshape((-1,) + (1,) * (leaf.ndim - 1))

    if name == "none":
        return grads_w
    if name == "sign_flip":
        return jax.tree_util.tree_map(
            lambda g: jnp.where(mask_like(g), -scale * g, g), grads_w
        )
    if name == "noise":
        leaves, treedef = jax.tree_util.tree_flatten(grads_w)
        keys = jax.random.split(key, len(leaves))
        noisy = [
            jnp.where(mask_like(g), scale * jax.random.normal(kk, g.shape, g.dtype), g)
            for kk, g in zip(keys, leaves)
        ]
        return jax.tree_util.tree_unflatten(treedef, noisy)
    if name == "constant_drift":
        return jax.tree_util.tree_map(
            lambda g: jnp.where(mask_like(g), jnp.full_like(g, scale / jnp.sqrt(jnp.float32(g[0].size))), g),
            grads_w,
        )
    if name == "scaled_copy":
        # colluders send mean-of-good × scale — inflates the step magnitude
        def one(g):
            mu = jnp.mean(jnp.where(mask_like(g), 0, g), axis=0, keepdims=True)
            n_good = jnp.maximum(jnp.sum(~byz_mask), 1)
            mu = mu * (byz_mask.shape[0] / n_good)
            return jnp.where(mask_like(g), scale * mu, g)
        return jax.tree_util.tree_map(one, grads_w)
    raise KeyError(f"unknown tree attack {name!r}")
