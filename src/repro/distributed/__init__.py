"""repro.distributed — mesh/sharding substrate + the paper's technique as a
data-parallel gradient-aggregation feature (see byzantine_dp.py)."""
from repro.distributed.sharding import (
    LOGICAL_RULES_SINGLE_POD,
    LOGICAL_RULES_MULTI_POD,
    logical_to_spec,
    shard_act,
    use_logical_rules,
    param_pspecs,
)

__all__ = [
    "LOGICAL_RULES_SINGLE_POD",
    "LOGICAL_RULES_MULTI_POD",
    "logical_to_spec",
    "shard_act",
    "use_logical_rules",
    "param_pspecs",
]
