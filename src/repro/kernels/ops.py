"""Public jit'd wrappers for the aggregation kernels.

On TPU these dispatch to the compiled Pallas kernels; on CPU (this harness)
they run the identical kernel bodies in ``interpret=True`` mode, so every
test exercises the real kernel code path.  ``use_kernels=False`` falls back
to the jnp oracles — the switch the distributed guard uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.countsketch import countsketch_pallas
from repro.kernels.fused_guard import (
    fused_guard_gen_pallas,
    fused_guard_pallas,
    gen_xi_pallas,
)
from repro.kernels.pairdist import gram_pallas
from repro.kernels.robust_reduce import (
    coordinate_median_pallas,
    filtered_mean_pallas,
    trimmed_mean_pallas,
)


def interpret_mode() -> bool:
    """True when kernels run the Pallas interpreter (any non-TPU backend).
    Public so benchmarks/records can report the execution mode without
    re-deriving the predicate."""
    return jax.default_backend() != "tpu"


def default_d_block(d: int) -> int:
    """Smallest lane-aligned strip covering ``d``, capped at the kernels'
    VMEM-sized default — callers at tiny d (campaign problems, Krum on the
    flat harness) should not pad every strip to 2048."""
    return max(128, min(2048, -(-d // 128) * 128))


def gram(x: jax.Array, d_block: int = 2048) -> jax.Array:
    """(m, d) → (m, m) worker Gram matrix (see pairdist.py)."""
    return gram_pallas(x, d_block=d_block, interpret=interpret_mode())


def coordinate_median(x: jax.Array, d_block: int = 4096) -> jax.Array:
    return coordinate_median_pallas(x, d_block=d_block, interpret=interpret_mode())


def trimmed_mean(x: jax.Array, n_trim: int, d_block: int = 4096) -> jax.Array:
    return trimmed_mean_pallas(x, n_trim, d_block=d_block, interpret=interpret_mode())


def filtered_mean(x: jax.Array, mask: jax.Array, denom: float, d_block: int = 4096,
                  sanitize: bool = False) -> jax.Array:
    return filtered_mean_pallas(x, mask, denom, d_block=d_block,
                                interpret=interpret_mode(), sanitize=sanitize)


def countsketch(x: jax.Array, k: int, salt: int = 0, d_block: int = 8192) -> jax.Array:
    return countsketch_pallas(x, k, salt=salt, d_block=d_block, interpret=interpret_mode())


def fused_guard(grads: jax.Array, B: jax.Array, delta: jax.Array,
                d_block: int = 2048, sanitize: bool = False):
    """(m, d), (m, d), (d,) → (gram_g, cross, a_inc, B_new) in one HBM
    sweep (see fused_guard.py); the streaming ByzantineGuard path.
    Strips stream in their storage dtype (bf16 halves the sweep's bytes —
    the ``stats_dtype`` axis); B_new comes back in ``B.dtype``, Grams and
    A-increments always f32.  ``sanitize=True`` (DESIGN.md §15) zeroes
    non-finite entries in-pass and appends a per-row non-finite count
    ``nf`` as a fifth output."""
    return fused_guard_pallas(grads, B, delta, d_block=d_block,
                              interpret=interpret_mode(), sanitize=sanitize)


def fused_guard_gen(B, delta, x, h, x_star, het_dir,
                    keys, skewsign, slot, params, d_block: int = 2048):
    """Generating variant of :func:`fused_guard` (DESIGN.md §14): the
    gradient strips are regenerated in-kernel from (key, coordinate)
    counters — the (m, d) batch never lands in HBM, so the sweep's traffic
    is the two B strips only (2·m·d·e bytes)."""
    return fused_guard_gen_pallas(B, delta, x, h, x_star, het_dir,
                                  keys, skewsign, slot, params,
                                  d_block=d_block, interpret=interpret_mode())


def gen_xi(w_xi, w_byz, x, h, x_star, het_dir,
           keys, skewsign, slot, params,
           d_block: int = 2048, stats_dtype: str = "float32"):
    """Generating filtered-mean + Byzantine row-sum pass (see
    fused_guard.py) — the ξ/feedback consumer of the generated strips."""
    return gen_xi_pallas(w_xi, w_byz, x, h, x_star, het_dir,
                         keys, skewsign, slot, params,
                         d_block=d_block, interpret=interpret_mode(),
                         stats_dtype=stats_dtype)


ORACLES = {
    "gram": ref.gram_ref,
    "coordinate_median": ref.coordinate_median_ref,
    "trimmed_mean": ref.trimmed_mean_ref,
    "filtered_mean": ref.filtered_mean_ref,
    "filtered_mean_sanitize": ref.filtered_mean_sanitize_ref,
    "countsketch": ref.countsketch_ref,
    "fused_guard": ref.fused_guard_ref,
    "fused_guard_sanitize": ref.fused_guard_sanitize_ref,
    "fused_guard_gen": ref.fused_guard_gen_ref,
    "gen_xi": ref.gen_xi_ref,
}
