"""Pallas TPU kernel: tiled worker-Gram matrix.

The master-side hot spot of ByzantineSGD (and of Krum, which the paper's
Table 1 costs at O(m²d)): G = X Xᵀ for X = (m, d) stacked worker vectors,
with d = |params| ≫ VMEM.  One MXU matmul per streamed strip, accumulated
into the resident (m, m) output — the shared layout of DESIGN.md §4.
Standalone form of the Gram terms; the guard's step-loop uses the fused
variant in :mod:`repro.kernels.fused_guard` instead (DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("d_block", "interpret"))
def gram_pallas(x: jax.Array, d_block: int = 2048, interpret: bool = False) -> jax.Array:
    """(m, d) → (m, m) f32 Gram via the tiled kernel.

    The wrapper pads m up to the 8-sublane multiple and d up to d_block
    (zero padding is exact for a Gram matrix).
    """
    m, d = x.shape
    m_pad = (-m) % 8
    d_pad = (-d) % d_block
    if m_pad or d_pad:
        x = jnp.pad(x, ((0, m_pad), (0, d_pad)))
    mp, dp = x.shape

    out = pl.pallas_call(
        _gram_kernel,
        grid=(dp // d_block,),
        in_specs=[pl.BlockSpec((mp, d_block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((mp, mp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, mp), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:m, :m]
