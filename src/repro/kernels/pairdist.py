"""Pallas TPU kernel: tiled worker-Gram matrix.

The master-side hot spot of ByzantineSGD (and of Krum, which the paper's
Table 1 costs at O(m²d)): G = X Xᵀ for X = (m, d) stacked worker vectors,
with d = |params| ≫ VMEM.  We tile over d: each grid step loads an
(m, d_blk) strip into VMEM, runs one MXU matmul (m padded to the 128 MXU
lane width by the wrapper), and accumulates into the (m, m) output block
that stays resident across the whole grid.

Grid:    (d // d_blk,)
x strip: BlockSpec((m, d_blk), lambda i: (0, i))  — streams HBM→VMEM
out:     BlockSpec((m, m),     lambda i: (0, 0))  — revisited, accumulated

VMEM per step = m·d_blk·4 + m²·4 bytes; with m=128 (padded), d_blk=2048
that is ~1.1 MB — well inside the ~16 MB/core budget, leaving room for the
double-buffered pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("d_block", "interpret"))
def gram_pallas(x: jax.Array, d_block: int = 2048, interpret: bool = False) -> jax.Array:
    """(m, d) → (m, m) f32 Gram via the tiled kernel.

    The wrapper pads m up to the 8-sublane multiple and d up to d_block
    (zero padding is exact for a Gram matrix).
    """
    m, d = x.shape
    m_pad = (-m) % 8
    d_pad = (-d) % d_block
    if m_pad or d_pad:
        x = jnp.pad(x, ((0, m_pad), (0, d_pad)))
    mp, dp = x.shape

    out = pl.pallas_call(
        _gram_kernel,
        grid=(dp // d_block,),
        in_specs=[pl.BlockSpec((mp, d_block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((mp, mp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, mp), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:m, :m]
