"""Pallas TPU kernel: one-pass fused guard-statistics pipeline.

Algorithm 1's per-iteration filter needs four quantities that each touch
the full (m, d) worker data: the fresh-gradient Gram ``g gᵀ``, the cross
Gram ``B gᵀ`` (feeding the incremental B-martingale Gram, see DESIGN.md
§5), the A-martingale increments ``g · (x_k − x_1)``, and the updated
martingale matrix ``B + g``.  Computed separately (as the dense reference
in :mod:`repro.core.byzantine_sgd` does) that is three independent sweeps
over HBM; this kernel produces all four in a *single* grid pass — every
(m, d_blk) strip of ``grads`` and ``B`` is read exactly once and the new
``B`` strip is written in place of a separate accumulation pass.

Layout is the shared strip convention of :mod:`repro.kernels` (m padded
to the next 8-sublane multiple) with two resident (m, m) accumulators
and one resident (m,) accumulator alongside the streamed ``B`` output
strip.  With ``e = element bytes`` of the streamed strips, VMEM per step
= 2·m·d_blk·e (g + B in) + m·d_blk·e (B out) + 2·m²·4 + m·4 bytes
≈ 0.8 MB at m=32, d_blk=2048, e=4 — comfortably inside the
double-buffered ~16 MB/core budget (and half that under bf16 strips).

Roofline (DESIGN.md §5): HBM traffic drops from 6·m·d·e bytes per guard
step (dense: g read 3×, B read 2×, B written 1×) to 3·m·d·e (g read 1×,
B read 1×, B written 1×) — a 2× reduction by the pass-count model in
``repro.roofline.guard_cost``, recorded alongside measured wall-clock by
``benchmarks/bench_filtering.py``.

**Mixed-precision statistics** (``SolverConfig.stats_dtype``): the
streamed strips may be bf16 — ``grads``/``B`` are read in their storage
dtype and the new ``B`` strip is written back in ``B.dtype``, halving
``e`` and therefore the whole sweep's HBM traffic.  Every accumulator
(both Grams, the A-increments) stays f32: inputs are upcast *in VMEM*
(bf16 → f32 is exact), so the contraction numerics are identical to an
f32 sweep over the same bf16-rounded values and the only rounding the
dtype axis introduces is the per-step ``B_new`` store.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_guard_kernel(g_ref, b_ref, delta_ref,
                        gram_g_ref, cross_ref, a_inc_ref, b_new_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gram_g_ref[...] = jnp.zeros_like(gram_g_ref)
        cross_ref[...] = jnp.zeros_like(cross_ref)
        a_inc_ref[...] = jnp.zeros_like(a_inc_ref)

    g = g_ref[...].astype(jnp.float32)        # (m, d_blk)
    b = b_ref[...].astype(jnp.float32)        # (m, d_blk)
    dlt = delta_ref[...].astype(jnp.float32)  # (d_blk,)

    contract = (((1,), (1,)), ((), ()))
    gram_g_ref[...] += jax.lax.dot_general(
        g, g, contract, preferred_element_type=jnp.float32
    )
    cross_ref[...] += jax.lax.dot_general(     # ⟨B_i, g_j⟩ — pre-update B
        b, g, contract, preferred_element_type=jnp.float32
    )
    a_inc_ref[...] += jnp.sum(g * dlt[None, :], axis=1)
    # f32 add, rounded once on the store when the B strips are bf16
    b_new_ref[...] = (b + g).astype(b_new_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_block", "interpret"))
def fused_guard_pallas(
    grads: jax.Array,   # (m, d) fresh per-worker gradients
    B: jax.Array,       # (m, d) martingale matrix B_{k-1}
    delta: jax.Array,   # (d,)   x_k − x_1
    d_block: int = 2048,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-pass guard statistics: ``(gram_g, cross, a_inc, B_new)`` with

    * ``gram_g[i, j] = ⟨∇_i, ∇_j⟩``            (m, m) f32
    * ``cross[i, j]  = ⟨B_{k-1,i}, ∇_j⟩``      (m, m) f32
    * ``a_inc[i]     = ⟨∇_i, x_k − x_1⟩``      (m,)   f32
    * ``B_new        = B_{k-1} + ∇``           (m, d) in ``B.dtype``

    matching :func:`repro.kernels.ref.fused_guard_ref`.  ``B.dtype`` is
    the statistics storage dtype (f32 or bf16 — the ``stats_dtype`` axis);
    the f32 sum is rounded once on the ``B_new`` store.  The caller folds
    ``cross`` into the incremental Gram ``G_B^k = G_B^{k-1} + cross +
    crossᵀ + gram_g``.  Padding (m → ×8, d → ×d_block) is with zeros,
    which is exact for all four outputs.
    """
    m, d = grads.shape
    if B.shape != (m, d):
        raise ValueError(f"B shape {B.shape} != grads shape {(m, d)}")
    m_pad = (-m) % 8
    d_pad = (-d) % d_block
    if m_pad or d_pad:
        grads = jnp.pad(grads, ((0, m_pad), (0, d_pad)))
        B = jnp.pad(B, ((0, m_pad), (0, d_pad)))
    if d_pad:
        delta = jnp.pad(delta, (0, d_pad))
    mp, dp = grads.shape

    # named scope (DESIGN.md §12 span convention): XLA profiles attribute
    # the sweep's device time to guard/pallas_fused_guard instead of an
    # anonymous custom-call — metadata only, no ops
    with jax.named_scope("guard/pallas_fused_guard"):
        gram_g, cross, a_inc, b_new = pl.pallas_call(
            _fused_guard_kernel,
            grid=(dp // d_block,),
            in_specs=[
                pl.BlockSpec((mp, d_block), lambda i: (0, i)),
                pl.BlockSpec((mp, d_block), lambda i: (0, i)),
                pl.BlockSpec((d_block,), lambda i: (i,)),
            ],
            out_specs=[
                pl.BlockSpec((mp, mp), lambda i: (0, 0)),
                pl.BlockSpec((mp, mp), lambda i: (0, 0)),
                pl.BlockSpec((mp,), lambda i: (0,)),
                pl.BlockSpec((mp, d_block), lambda i: (0, i)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((mp, mp), jnp.float32),
                jax.ShapeDtypeStruct((mp, mp), jnp.float32),
                jax.ShapeDtypeStruct((mp,), jnp.float32),
                jax.ShapeDtypeStruct((mp, dp), B.dtype),
            ],
            interpret=interpret,
        )(grads, B, delta)
    return gram_g[:m, :m], cross[:m, :m], a_inc[:m], b_new[:m, :d]
