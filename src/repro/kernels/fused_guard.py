"""Pallas TPU kernel: one-pass fused guard-statistics pipeline.

Algorithm 1's per-iteration filter needs four quantities that each touch
the full (m, d) worker data: the fresh-gradient Gram ``g gᵀ``, the cross
Gram ``B gᵀ`` (feeding the incremental B-martingale Gram, see DESIGN.md
§5), the A-martingale increments ``g · (x_k − x_1)``, and the updated
martingale matrix ``B + g``.  Computed separately (as the dense reference
in :mod:`repro.core.byzantine_sgd` does) that is three independent sweeps
over HBM; this kernel produces all four in a *single* grid pass — every
(m, d_blk) strip of ``grads`` and ``B`` is read exactly once and the new
``B`` strip is written in place of a separate accumulation pass.

Layout is the shared strip convention of :mod:`repro.kernels` (m padded
to the next 8-sublane multiple) with two resident (m, m) accumulators
and one resident (m,) accumulator alongside the streamed ``B`` output
strip.  With ``e = element bytes`` of the streamed strips, VMEM per step
= 2·m·d_blk·e (g + B in) + m·d_blk·e (B out) + 2·m²·4 + m·4 bytes
≈ 0.8 MB at m=32, d_blk=2048, e=4 — comfortably inside the
double-buffered ~16 MB/core budget (and half that under bf16 strips).

Roofline (DESIGN.md §5): HBM traffic drops from 6·m·d·e bytes per guard
step (dense: g read 3×, B read 2×, B written 1×) to 3·m·d·e (g read 1×,
B read 1×, B written 1×) — a 2× reduction by the pass-count model in
``repro.roofline.guard_cost``, recorded alongside measured wall-clock by
``benchmarks/bench_filtering.py``.

**Mixed-precision statistics** (``SolverConfig.stats_dtype``): the
streamed strips may be bf16 — ``grads``/``B`` are read in their storage
dtype and the new ``B`` strip is written back in ``B.dtype``, halving
``e`` and therefore the whole sweep's HBM traffic.  Every accumulator
(both Grams, the A-increments) stays f32: inputs are upcast *in VMEM*
(bf16 → f32 is exact), so the contraction numerics are identical to an
f32 sweep over the same bf16-rounded values and the only rounding the
dtype axis introduces is the per-step ``B_new`` store.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gradgen import GEN_NPARAMS, gen_worker_rows


def _fused_guard_kernel(g_ref, b_ref, delta_ref,
                        gram_g_ref, cross_ref, a_inc_ref, b_new_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gram_g_ref[...] = jnp.zeros_like(gram_g_ref)
        cross_ref[...] = jnp.zeros_like(cross_ref)
        a_inc_ref[...] = jnp.zeros_like(a_inc_ref)

    g = g_ref[...].astype(jnp.float32)        # (m, d_blk)
    b = b_ref[...].astype(jnp.float32)        # (m, d_blk)
    dlt = delta_ref[...].astype(jnp.float32)  # (d_blk,)

    contract = (((1,), (1,)), ((), ()))
    gram_g_ref[...] += jax.lax.dot_general(
        g, g, contract, preferred_element_type=jnp.float32
    )
    cross_ref[...] += jax.lax.dot_general(     # ⟨B_i, g_j⟩ — pre-update B
        b, g, contract, preferred_element_type=jnp.float32
    )
    a_inc_ref[...] += jnp.sum(g * dlt[None, :], axis=1)
    # f32 add, rounded once on the store when the B strips are bf16
    b_new_ref[...] = (b + g).astype(b_new_ref.dtype)


def _fused_guard_sanitize_kernel(g_ref, b_ref, delta_ref,
                                 gram_g_ref, cross_ref, a_inc_ref, nf_ref,
                                 b_new_ref):
    """Sanitizing variant (DESIGN.md §15): identical products, but NaN/Inf
    gradient entries are zeroed *in VMEM* before any contraction and the
    per-row non-finite count accumulates across strips — the non-finite
    check rides the one HBM sweep instead of costing its own (m, d) pass.
    A separate kernel body (not a flag on the base kernel) so the off-state
    pallas_call is byte-identical to the pre-sanitize build."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gram_g_ref[...] = jnp.zeros_like(gram_g_ref)
        cross_ref[...] = jnp.zeros_like(cross_ref)
        a_inc_ref[...] = jnp.zeros_like(a_inc_ref)
        nf_ref[...] = jnp.zeros_like(nf_ref)

    g = g_ref[...].astype(jnp.float32)        # (m, d_blk)
    fin = jnp.isfinite(g)
    nf_ref[...] += jnp.sum((~fin).astype(jnp.int32), axis=1)
    g = jnp.where(fin, g, 0.0)
    b = b_ref[...].astype(jnp.float32)        # (m, d_blk)
    dlt = delta_ref[...].astype(jnp.float32)  # (d_blk,)

    contract = (((1,), (1,)), ((), ()))
    gram_g_ref[...] += jax.lax.dot_general(
        g, g, contract, preferred_element_type=jnp.float32
    )
    cross_ref[...] += jax.lax.dot_general(
        b, g, contract, preferred_element_type=jnp.float32
    )
    a_inc_ref[...] += jnp.sum(g * dlt[None, :], axis=1)
    # B accumulates the *sanitized* gradient: the martingale stays finite
    # forever (one NaN entry would otherwise poison B_i for the whole run)
    b_new_ref[...] = (b + g).astype(b_new_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_block", "interpret", "sanitize"))
def fused_guard_pallas(
    grads: jax.Array,   # (m, d) fresh per-worker gradients
    B: jax.Array,       # (m, d) martingale matrix B_{k-1}
    delta: jax.Array,   # (d,)   x_k − x_1
    d_block: int = 2048,
    interpret: bool = False,
    sanitize: bool = False,
) -> tuple[jax.Array, ...]:
    """One-pass guard statistics: ``(gram_g, cross, a_inc, B_new)`` with

    * ``gram_g[i, j] = ⟨∇_i, ∇_j⟩``            (m, m) f32
    * ``cross[i, j]  = ⟨B_{k-1,i}, ∇_j⟩``      (m, m) f32
    * ``a_inc[i]     = ⟨∇_i, x_k − x_1⟩``      (m,)   f32
    * ``B_new        = B_{k-1} + ∇``           (m, d) in ``B.dtype``

    matching :func:`repro.kernels.ref.fused_guard_ref`.  ``B.dtype`` is
    the statistics storage dtype (f32 or bf16 — the ``stats_dtype`` axis);
    the f32 sum is rounded once on the ``B_new`` store.  The caller folds
    ``cross`` into the incremental Gram ``G_B^k = G_B^{k-1} + cross +
    crossᵀ + gram_g``.  Padding (m → ×8, d → ×d_block) is with zeros,
    which is exact for all four outputs.

    ``sanitize=True`` (static, DESIGN.md §15) zeroes NaN/Inf gradient
    entries in VMEM before every product and appends a fifth output
    ``nf`` — the (m,) int32 per-row non-finite entry count — so the
    quarantine decision costs no extra HBM pass; matches
    :func:`repro.kernels.ref.fused_guard_sanitize_ref`.
    """
    m, d = grads.shape
    if B.shape != (m, d):
        raise ValueError(f"B shape {B.shape} != grads shape {(m, d)}")
    m_pad = (-m) % 8
    d_pad = (-d) % d_block
    if m_pad or d_pad:
        grads = jnp.pad(grads, ((0, m_pad), (0, d_pad)))
        B = jnp.pad(B, ((0, m_pad), (0, d_pad)))
    if d_pad:
        delta = jnp.pad(delta, (0, d_pad))
    mp, dp = grads.shape

    out_specs = [
        pl.BlockSpec((mp, mp), lambda i: (0, 0)),
        pl.BlockSpec((mp, mp), lambda i: (0, 0)),
        pl.BlockSpec((mp,), lambda i: (0,)),
        pl.BlockSpec((mp, d_block), lambda i: (0, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((mp, mp), jnp.float32),
        jax.ShapeDtypeStruct((mp, mp), jnp.float32),
        jax.ShapeDtypeStruct((mp,), jnp.float32),
        jax.ShapeDtypeStruct((mp, dp), B.dtype),
    ]
    kernel = _fused_guard_kernel
    if sanitize:
        kernel = _fused_guard_sanitize_kernel
        # nf accumulator sits before the streamed B strip so the resident
        # accumulators stay contiguous in the output list
        out_specs.insert(3, pl.BlockSpec((mp,), lambda i: (0,)))
        out_shape.insert(3, jax.ShapeDtypeStruct((mp,), jnp.int32))

    # named scope (DESIGN.md §12 span convention): XLA profiles attribute
    # the sweep's device time to guard/pallas_fused_guard instead of an
    # anonymous custom-call — metadata only, no ops
    with jax.named_scope("guard/pallas_fused_guard"):
        outs = pl.pallas_call(
            kernel,
            grid=(dp // d_block,),
            in_specs=[
                pl.BlockSpec((mp, d_block), lambda i: (0, i)),
                pl.BlockSpec((mp, d_block), lambda i: (0, i)),
                pl.BlockSpec((d_block,), lambda i: (i,)),
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(grads, B, delta)
    if sanitize:
        gram_g, cross, a_inc, nf, b_new = outs
        return (gram_g[:m, :m], cross[:m, :m], a_inc[:m], b_new[:m, :d],
                nf[:m])
    gram_g, cross, a_inc, b_new = outs
    return gram_g[:m, :m], cross[:m, :m], a_inc[:m], b_new[:m, :d]


# ---------------------------------------------------------------------------
# generating variants (DESIGN.md §14): the gradient strips are regenerated
# in-kernel from (key, coordinate) counters instead of being read from HBM
# ---------------------------------------------------------------------------


def _gen_strip(x_ref, h_ref, xs_ref, hd_ref, keys_ref, skew_ref, slot_ref,
               params_ref, *, d_block, d):
    """Shared kernel prologue: regenerate this grid step's attacked worker
    strip (mp, d_blk) f32 via :func:`repro.kernels.gradgen.gen_worker_rows`."""
    i = pl.program_id(0)
    # TPU iota must be rank ≥ 2: a (1, d_blk) row of global coordinates
    j = (i * d_block + jax.lax.broadcasted_iota(jnp.int32, (1, d_block), 1)
         ).astype(jnp.uint32)
    return gen_worker_rows(
        x_ref[...].astype(jnp.float32),
        h_ref[...].astype(jnp.float32),
        xs_ref[...].astype(jnp.float32),
        hd_ref[...].astype(jnp.float32),
        keys_ref[...],
        skew_ref[...].astype(jnp.float32),
        slot_ref[...],
        params_ref[...].astype(jnp.float32),
        j, d,
    )


def _fused_guard_gen_kernel(b_ref, delta_ref, x_ref, h_ref, xs_ref, hd_ref,
                            keys_ref, skew_ref, slot_ref, params_ref,
                            gram_g_ref, cross_ref, a_inc_ref, b_new_ref,
                            *, d_block, d):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gram_g_ref[...] = jnp.zeros_like(gram_g_ref)
        cross_ref[...] = jnp.zeros_like(cross_ref)
        a_inc_ref[...] = jnp.zeros_like(a_inc_ref)

    rows = _gen_strip(x_ref, h_ref, xs_ref, hd_ref, keys_ref, skew_ref,
                      slot_ref, params_ref, d_block=d_block, d=d)
    # mirror the materializing path's storage rounding: the host casts the
    # attacked grads to stats_dtype before the sweep, which then upcasts —
    # round-trip through B's dtype so bf16 statistics stay pinned to it
    g = rows.astype(b_new_ref.dtype).astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    dlt = delta_ref[...].astype(jnp.float32)

    contract = (((1,), (1,)), ((), ()))
    gram_g_ref[...] += jax.lax.dot_general(
        g, g, contract, preferred_element_type=jnp.float32
    )
    cross_ref[...] += jax.lax.dot_general(
        b, g, contract, preferred_element_type=jnp.float32
    )
    a_inc_ref[...] += jnp.sum(g * dlt[None, :], axis=1)
    b_new_ref[...] = (b + g).astype(b_new_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_block", "interpret"))
def fused_guard_gen_pallas(
    B: jax.Array,          # (m, d) martingale matrix B_{k-1}
    delta: jax.Array,      # (d,)   x_k − x_1
    x: jax.Array,          # (d,)   current iterate
    h: jax.Array,          # (d,)   diagonal curvature
    x_star: jax.Array,     # (d,)   optimum
    het_dir: jax.Array,    # (d,)   rank-1 skew direction (zeros if iid)
    keys: jax.Array,       # (m, 2) uint32 worker key words
    skewsign: jax.Array,   # (m,)   f32 skew·sign per worker
    slot: jax.Array,       # (m,)   int32 attack slot per worker
    params: jax.Array,     # (GEN_NPARAMS,) f32 attack parameters
    d_block: int = 2048,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """:func:`fused_guard_pallas` with the ``grads`` operand *generated*
    in-kernel — same four outputs, but the (m, d) gradient batch never
    exists in HBM, so the sweep reads/writes only the two B strips:
    2·m·d·e bytes vs the materializing kernel's 3·m·d·e (plus the batch's
    own producer traffic).  Padded worker rows carry ``slot = −1`` and
    padded coordinates are masked against the static true ``d`` inside the
    generator, since generated values (unlike zero-padded inputs) are
    nonzero in the padding."""
    m, d = B.shape
    if keys.shape != (m, 2):
        raise ValueError(f"keys shape {keys.shape} != {(m, 2)}")
    if params.shape != (GEN_NPARAMS,):
        raise ValueError(f"params shape {params.shape} != {(GEN_NPARAMS,)}")
    m_pad = (-m) % 8
    d_pad = (-d) % d_block
    if m_pad:
        B = jnp.pad(B, ((0, m_pad), (0, 0)))
        keys = jnp.pad(keys, ((0, m_pad), (0, 0)))
        skewsign = jnp.pad(skewsign, (0, m_pad))
        slot = jnp.pad(slot, (0, m_pad), constant_values=-1)
    if d_pad:
        B = jnp.pad(B, ((0, 0), (0, d_pad)))
        delta = jnp.pad(delta, (0, d_pad))
        x = jnp.pad(x, (0, d_pad))
        h = jnp.pad(h, (0, d_pad))
        x_star = jnp.pad(x_star, (0, d_pad))
        het_dir = jnp.pad(het_dir, (0, d_pad))
    mp, dp = B.shape

    kernel = functools.partial(_fused_guard_gen_kernel, d_block=d_block, d=d)
    with jax.named_scope("guard/pallas_fused_guard_gen"):
        gram_g, cross, a_inc, b_new = pl.pallas_call(
            kernel,
            grid=(dp // d_block,),
            in_specs=[
                pl.BlockSpec((mp, d_block), lambda i: (0, i)),   # B
                pl.BlockSpec((d_block,), lambda i: (i,)),        # delta
                pl.BlockSpec((d_block,), lambda i: (i,)),        # x
                pl.BlockSpec((d_block,), lambda i: (i,)),        # h
                pl.BlockSpec((d_block,), lambda i: (i,)),        # x_star
                pl.BlockSpec((d_block,), lambda i: (i,)),        # het_dir
                pl.BlockSpec((mp, 2), lambda i: (0, 0)),         # keys
                pl.BlockSpec((mp,), lambda i: (0,)),             # skewsign
                pl.BlockSpec((mp,), lambda i: (0,)),             # slot
                pl.BlockSpec((GEN_NPARAMS,), lambda i: (0,)),    # params
            ],
            out_specs=[
                pl.BlockSpec((mp, mp), lambda i: (0, 0)),
                pl.BlockSpec((mp, mp), lambda i: (0, 0)),
                pl.BlockSpec((mp,), lambda i: (0,)),
                pl.BlockSpec((mp, d_block), lambda i: (0, i)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((mp, mp), jnp.float32),
                jax.ShapeDtypeStruct((mp, mp), jnp.float32),
                jax.ShapeDtypeStruct((mp,), jnp.float32),
                jax.ShapeDtypeStruct((mp, dp), B.dtype),
            ],
            interpret=interpret,
        )(B, delta, x, h, x_star, het_dir, keys, skewsign, slot, params)
    return gram_g[:m, :m], cross[:m, :m], a_inc[:m], b_new[:m, :d]


def _gen_xi_kernel(wxi_ref, wbyz_ref, x_ref, h_ref, xs_ref, hd_ref,
                   keys_ref, skew_ref, slot_ref, params_ref,
                   xi_ref, byz_ref, *, d_block, d, stats_dtype):
    rows = _gen_strip(x_ref, h_ref, xs_ref, hd_ref, keys_ref, skew_ref,
                      slot_ref, params_ref, d_block=d_block, d=d)
    # ξ consumes the stats-rounded strips (what the materializing guard's
    # filtered_mean sees); the adversary's byz-row feedback consumes the
    # raw f32 rows (what the host adversary.update_state sees)
    gs = rows.astype(stats_dtype).astype(jnp.float32)
    w = wxi_ref[...].astype(jnp.float32)
    xi_ref[...] = jnp.einsum("m,md->d", w, gs)
    byz_ref[...] = jnp.sum(rows * wbyz_ref[...][:, None], axis=0)


@functools.partial(jax.jit,
                   static_argnames=("d_block", "interpret", "stats_dtype"))
def gen_xi_pallas(
    w_xi: jax.Array,       # (m,) f32 aggregation weights (contrib / denom)
    w_byz: jax.Array,      # (m,) f32 Byzantine mask weights
    x: jax.Array,          # (d,)
    h: jax.Array,          # (d,)
    x_star: jax.Array,     # (d,)
    het_dir: jax.Array,    # (d,)
    keys: jax.Array,       # (m, 2) uint32
    skewsign: jax.Array,   # (m,) f32
    slot: jax.Array,       # (m,) int32
    params: jax.Array,     # (GEN_NPARAMS,) f32
    d_block: int = 2048,
    interpret: bool = False,
    stats_dtype: str = "float32",
) -> tuple[jax.Array, jax.Array]:
    """Second generating pass: the filtered mean ξ = Σᵢ w_xi[i]·∇ᵢ and the
    Byzantine row-sum Σᵢ w_byz[i]·∇ᵢ (the adversary's feedback signal),
    both regenerated from the same counters as the sweep so nothing (m, d)
    is ever stored.  ``stats_dtype`` reproduces the materializing path's
    storage rounding for ξ; the byz sum uses raw f32 rows exactly as the
    host hands ``adversary.update_state`` the un-rounded attack output."""
    m = keys.shape[0]
    d = x.shape[0]
    m_pad = (-m) % 8
    d_pad = (-d) % d_block
    if m_pad:
        w_xi = jnp.pad(w_xi, (0, m_pad))
        w_byz = jnp.pad(w_byz, (0, m_pad))
        keys = jnp.pad(keys, ((0, m_pad), (0, 0)))
        skewsign = jnp.pad(skewsign, (0, m_pad))
        slot = jnp.pad(slot, (0, m_pad), constant_values=-1)
    if d_pad:
        x = jnp.pad(x, (0, d_pad))
        h = jnp.pad(h, (0, d_pad))
        x_star = jnp.pad(x_star, (0, d_pad))
        het_dir = jnp.pad(het_dir, (0, d_pad))
    mp = keys.shape[0]
    dp = x.shape[0]

    kernel = functools.partial(_gen_xi_kernel, d_block=d_block, d=d,
                               stats_dtype=jnp.dtype(stats_dtype))
    with jax.named_scope("guard/pallas_gen_xi"):
        xi, byz = pl.pallas_call(
            kernel,
            grid=(dp // d_block,),
            in_specs=[
                pl.BlockSpec((mp,), lambda i: (0,)),             # w_xi
                pl.BlockSpec((mp,), lambda i: (0,)),             # w_byz
                pl.BlockSpec((d_block,), lambda i: (i,)),        # x
                pl.BlockSpec((d_block,), lambda i: (i,)),        # h
                pl.BlockSpec((d_block,), lambda i: (i,)),        # x_star
                pl.BlockSpec((d_block,), lambda i: (i,)),        # het_dir
                pl.BlockSpec((mp, 2), lambda i: (0, 0)),         # keys
                pl.BlockSpec((mp,), lambda i: (0,)),             # skewsign
                pl.BlockSpec((mp,), lambda i: (0,)),             # slot
                pl.BlockSpec((GEN_NPARAMS,), lambda i: (0,)),    # params
            ],
            out_specs=[
                pl.BlockSpec((d_block,), lambda i: (i,)),
                pl.BlockSpec((d_block,), lambda i: (i,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((dp,), jnp.float32),
                jax.ShapeDtypeStruct((dp,), jnp.float32),
            ],
            interpret=interpret,
        )(w_xi, w_byz, x, h, x_star, het_dir, keys, skewsign, slot, params)
    return xi[:d], byz[:d]
