"""Pallas TPU kernel: fused sign-flip + strided-fold CountSketch.

The sketch-mode guard (DESIGN.md §3) compresses each worker's (huge)
gradient into k buckets: s_c = Σ_{i ≡ c (mod k)} σ(i)·x_i with hashed
signs.  Layout is the shared strip convention of DESIGN.md §4 (with
d_blk constrained to a multiple of k and an (m, k) resident output); the
twist is that the sign pattern is *generated inside the kernel* from the
global coordinate index (iota + block offset → multiplicative hash) —
zero bytes of hash state ever touch HBM, so the stream runs at pure read
bandwidth.  Strips stream in their storage dtype and are upcast to f32
in VMEM (exact for bf16), so bf16 inputs — the ``stats_dtype`` axis of
DESIGN.md §5 — halve the read traffic; the (m, k) sketch accumulates
and returns f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sign_hash(idx: jax.Array, salt: int) -> jax.Array:
    h = (idx + jnp.uint32((salt * 0x9E3779B9 + 1) & 0xFFFFFFFF)) * jnp.uint32(2654435761)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    return 1.0 - 2.0 * (h & 1).astype(jnp.float32)


def _countsketch_kernel(x_ref, out_ref, *, k: int, d_block: int, salt: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = x_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)                     # (m, d_blk)
    base = (i * d_block).astype(jnp.uint32) if hasattr(i, "astype") else jnp.uint32(i * d_block)
    idx = jax.lax.iota(jnp.uint32, d_block) + base         # global coordinate ids
    sign = _sign_hash(idx, salt)
    folded = (x * sign[None, :]).reshape(m, d_block // k, k)
    out_ref[...] += jnp.sum(folded, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "salt", "d_block", "interpret"))
def countsketch_pallas(
    x: jax.Array, k: int, salt: int = 0, d_block: int = 8192, interpret: bool = False,
) -> jax.Array:
    """(m, d) → (m, k) strided-fold CountSketch, matching
    :func:`repro.kernels.ref.countsketch_ref` bit-for-bit in f32."""
    m, d = x.shape
    d_block = max(k, (d_block // k) * k)
    d_pad = (-d) % d_block
    if d_pad:
        x = jnp.pad(x, ((0, 0), (0, d_pad)))
    dp = x.shape[1]
    return pl.pallas_call(
        functools.partial(_countsketch_kernel, k=k, d_block=d_block, salt=salt),
        grid=(dp // d_block,),
        in_specs=[pl.BlockSpec((m, d_block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
    )(x)
