"""Pure-jnp oracles for the master-side aggregation kernels.

These define the exact semantics the Pallas kernels must reproduce
(tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(x: jax.Array) -> jax.Array:
    """(m, d) → (m, m) Gram matrix G_ij = ⟨x_i, x_j⟩ in f32."""
    x32 = x.astype(jnp.float32)
    return x32 @ x32.T


def coordinate_median_ref(x: jax.Array) -> jax.Array:
    """(m, d) → (d,) coordinate-wise median (Yin et al. Median-GD rule).
    Even m averages the two central order statistics (jnp.median)."""
    return jnp.median(x.astype(jnp.float32), axis=0)


def trimmed_mean_ref(x: jax.Array, n_trim: int) -> jax.Array:
    """(m, d) → (d,): drop the n_trim largest and smallest per coordinate."""
    m = x.shape[0]
    assert 2 * n_trim < m
    s = jnp.sort(x.astype(jnp.float32), axis=0)
    return jnp.mean(s[n_trim : m - n_trim], axis=0)


def filtered_mean_ref(x: jax.Array, mask: jax.Array, denom: float) -> jax.Array:
    """(m, d), (m,) bool → (d,): Σ_{i∈mask} x_i / denom — the paper's ξ_k."""
    w = mask.astype(jnp.float32) / denom
    return w @ x.astype(jnp.float32)


def filtered_mean_sanitize_ref(x: jax.Array, mask: jax.Array,
                               denom: float) -> jax.Array:
    """Sanitizing variant of :func:`filtered_mean_ref` (DESIGN.md §15):
    non-finite entries are treated as zero, so a quarantined (zero-weight)
    NaN/Inf row contributes nothing instead of poisoning the dot."""
    x32 = x.astype(jnp.float32)
    x32 = jnp.where(jnp.isfinite(x32), x32, 0.0)
    w = mask.astype(jnp.float32) / denom
    return w @ x32


def fused_guard_ref(
    grads: jax.Array, B: jax.Array, delta: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Dense oracle for the one-pass guard pipeline: ``(gram_g, cross,
    a_inc, B_new)`` = (g gᵀ, B gᵀ, g·Δ, B + g); all accumulators f32,
    ``B_new`` rounded once to ``B.dtype`` (the statistics storage dtype —
    f32 today, bf16 under ``stats_dtype="bf16"``).  ``cross`` uses the
    *pre-update* B — the incremental-Gram identity is
    G_B^k = G_B^{k-1} + cross + crossᵀ + gram_g."""
    g = grads.astype(jnp.float32)
    b = B.astype(jnp.float32)
    dlt = delta.astype(jnp.float32)
    return g @ g.T, b @ g.T, g @ dlt, (b + g).astype(B.dtype)


def fused_guard_sanitize_ref(
    grads: jax.Array, B: jax.Array, delta: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sanitizing variant of :func:`fused_guard_ref` (DESIGN.md §15):
    non-finite gradient entries are zeroed before every product and the
    fifth output ``nf[i]`` counts them per row, so the caller can
    quarantine poisoned workers (``nf > 0``) while every accumulator —
    including ``B_new`` — stays finite."""
    g = grads.astype(jnp.float32)
    fin = jnp.isfinite(g)
    nf = jnp.sum(~fin, axis=1).astype(jnp.int32)
    g = jnp.where(fin, g, 0.0)
    b = B.astype(jnp.float32)
    dlt = delta.astype(jnp.float32)
    return g @ g.T, b @ g.T, g @ dlt, (b + g).astype(B.dtype), nf


def gen_rows_ref(x, h, x_star, het_dir, keys, skewsign, slot, params):
    """Host oracle for the in-kernel gradient generator: materialize the
    full (m, d) attacked batch via the *same*
    :func:`repro.kernels.gradgen.gen_worker_rows` body the Pallas kernels
    call per strip — one invocation with ``j = arange(d)``."""
    from repro.kernels import gradgen

    d = x.shape[0]
    j = jnp.arange(d, dtype=jnp.uint32)
    return gradgen.gen_worker_rows(
        x.astype(jnp.float32), h.astype(jnp.float32),
        x_star.astype(jnp.float32), het_dir.astype(jnp.float32),
        keys, skewsign.astype(jnp.float32), slot,
        params.astype(jnp.float32), j, d)


def fused_guard_gen_ref(B, delta, x, h, x_star, het_dir,
                        keys, skewsign, slot, params):
    """Materialize-then-sweep oracle for the generating guard kernel:
    regenerate the batch, round it through the statistics storage dtype
    (``B.dtype``) exactly as the materializing path does, and hand it to
    :func:`fused_guard_ref`."""
    rows = gen_rows_ref(x, h, x_star, het_dir, keys, skewsign, slot, params)
    return fused_guard_ref(rows.astype(B.dtype), B, delta)


def gen_xi_ref(w_xi, w_byz, x, h, x_star, het_dir,
               keys, skewsign, slot, params, stats_dtype=jnp.float32):
    """Oracle for the generating ξ pass: ``(Σ w_xi[i]·∇ᵢ, Σ w_byz[i]·∇ᵢ)``
    — ξ over the stats-rounded rows (what the guard's filtered mean sees),
    the Byzantine row-sum over the raw f32 rows (what the adversary's
    feedback update sees)."""
    rows = gen_rows_ref(x, h, x_star, het_dir, keys, skewsign, slot, params)
    gs = rows.astype(stats_dtype).astype(jnp.float32)
    xi = jnp.einsum("m,md->d", w_xi.astype(jnp.float32), gs)
    byz = jnp.sum(rows * w_byz.astype(jnp.float32)[:, None], axis=0)
    return xi, byz


def sketch_sign(n: int, salt: int) -> jax.Array:
    """±1 per flat coordinate — the hash shared with repro.distributed."""
    idx = jax.lax.iota(jnp.uint32, n)
    h = (idx + jnp.uint32((salt * 0x9E3779B9 + 1) & 0xFFFFFFFF)) * jnp.uint32(2654435761)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    return 1.0 - 2.0 * (h & 1).astype(jnp.float32)


def countsketch_ref(x: jax.Array, k: int, salt: int = 0) -> jax.Array:
    """(m, d) → (m, k) strided-fold CountSketch (bucket = i mod k, hashed
    signs) — the sketch used by the distributed guard."""
    m, d = x.shape
    sign = sketch_sign(d, salt)
    signed = x.astype(jnp.float32) * sign[None, :]
    pad = (-d) % k
    if pad:
        signed = jnp.pad(signed, ((0, 0), (0, pad)))
    return jnp.sum(signed.reshape(m, -1, k), axis=1)
