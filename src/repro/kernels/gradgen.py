"""Counter-based gradient generation — the producer side of the
on-device campaign story (DESIGN.md §14).

Campaign scale used to be bounded by HBM: every run of the one-jit grid
materialized its (m, d) stochastic-gradient batch per step.  This module
holds the *shared* generation math — a pure-``jnp`` Threefry-2x32
implementation plus the mean/noise/heterogeneity terms — so the exact
same expressions run in two places:

* on the host, as ``Problem.stoch_grad`` / ``Problem.het_grad`` of a
  :func:`repro.data.problems.make_generated_problem` problem, and
* inside the fused guard sweep (``kernels/fused_guard.py``), which
  regenerates each worker's strip from ``(key, coordinate)`` counters and
  streams it straight through the Gram/A/B update without ever writing
  the (m, d) batch to HBM.

Because both sides call the *same functions* in the same order, in-kernel
strips are bit-exact against the host generator by construction — the
differential oracle in ``tests/test_gradgen.py`` pins this, not a
tolerance band.

Key-chain contract
------------------
``run_sgd`` derives ``worker_keys = jax.random.split(gkey, m)`` exactly as
the materializing path does; the generated problem consumes only the
raw ``uint32[2]`` key data of each worker key.  The noise bits for
coordinate ``j`` are ``threefry2x32(k0, k1, 0, j)[0]`` — keyed on
(worker, coordinate), with the (run, step) dependence carried entirely by
the key chain (``gkey`` differs per run row and per step).  Bits map to a
centered uniform via the standard 23-bit mantissa ladder, and the noise
scale ``V/sqrt(d)`` keeps ``‖noise‖ ≤ V`` almost surely (Assumption 2.2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Threefry-2x32 rotation schedule (Salmon et al. 2011), 20 rounds in five
# groups of four; even groups rotate by R_A, odd groups by R_B.
_R_A = (13, 15, 26, 6)
_R_B = (17, 29, 16, 24)
_PARITY = 0x1BD11BDA


def _rotl(x: jax.Array, r: int) -> jax.Array:
    return (x << r) | (x >> (32 - r))


def threefry2x32(k0, k1, c0, c1):
    """Threefry-2x32, 20 rounds — pure ``jnp`` uint32 ops, so the same
    function body runs on host arrays and inside Pallas kernel strips.
    All four operands broadcast; returns the two output words."""
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    ks2 = k0 ^ k1 ^ jnp.uint32(_PARITY)
    x0 = jnp.asarray(c0, jnp.uint32) + k0
    x1 = jnp.asarray(c1, jnp.uint32) + k1
    # key-injection schedule after each 4-round group
    inject = ((k1, ks2, 1), (ks2, k0, 2), (k0, k1, 3),
              (k1, ks2, 4), (ks2, k0, 5))
    for g, (ka, kb, inc) in enumerate(inject):
        rots = _R_A if g % 2 == 0 else _R_B
        for r in rots:
            x0 = x0 + x1
            x1 = _rotl(x1, r) ^ x0
        x0 = x0 + ka
        x1 = x1 + kb + jnp.uint32(inc)
    return x0, x1


def centered_uniform(bits: jax.Array) -> jax.Array:
    """uint32 bits → f32 uniform in (−1, 1): the top 23 bits land on the
    open-interval lattice ((b >> 9) + 0.5)·2⁻²³ ∈ (0, 1), then center."""
    u = ((bits >> 9).astype(jnp.float32) + 0.5) * jnp.float32(2.0 ** -23)
    return 2.0 * u - 1.0


def key_bits(key: jax.Array) -> jax.Array:
    """Raw ``uint32[..., 2]`` words of a PRNG key — accepts both legacy
    uint32 keys and new-style typed keys."""
    if jnp.issubdtype(key.dtype, jnp.integer):
        return key.astype(jnp.uint32)
    return jax.random.key_data(key).astype(jnp.uint32)


def noise_bits(k0, k1, j: jax.Array) -> jax.Array:
    """Noise bits for coordinate counter ``j`` under worker key words
    (k0, k1): word 0 of ``threefry2x32(k0, k1, 0, j)``.  ``j`` is the
    *global* coordinate index — kernel strips pass the block-offset iota
    so every block reproduces the host's full-length stream."""
    return threefry2x32(k0, k1, jnp.zeros_like(j), j)[0]


def mean_grad(h: jax.Array, x: jax.Array, x_star: jax.Array) -> jax.Array:
    """∇f(x) of the diagonal quadratic f(x) = ½ Σ hⱼ (xⱼ − x*ⱼ)² —
    coordinate-local, so a kernel strip computes its slice exactly."""
    return h * (x - x_star)


def noise_row(kd: jax.Array, j: jax.Array, noise_scale) -> jax.Array:
    """One worker's noise slice at global coordinates ``j`` (uint32):
    ``noise_scale · centered_uniform(bits)``.  ``kd`` is the worker's
    ``uint32[2]`` key data."""
    return noise_scale * centered_uniform(noise_bits(kd[0], kd[1], j))


class GenSpec(NamedTuple):
    """Everything a kernel needs to regenerate one worker-strip.

    Coordinate-wise problem data (``h``, ``x_star``) streams through the
    same BlockSpecs as the gradient strips; ``het_dir`` is the rank-1
    heterogeneity direction (zeros for a homogeneous fleet) whose
    per-worker sign/scale rides in as the O(m) ``skewsign`` vector.
    ``het_sign`` is the per-worker ±1 of that rank-1 factorization
    (``None`` until :func:`repro.data.problems.heterogenize_generated`
    sets it) — the solver multiplies it into the profile's skew to form
    ``skewsign``; a problem heterogenized through the *dense* wrapper has
    no such factorization and is rejected by the gen gate.
    """

    h: jax.Array            # (d,) diagonal curvature
    x_star: jax.Array       # (d,) optimum
    noise_scale: jax.Array  # () f32 — V/sqrt(d), ‖noise‖ ≤ V a.s.
    het_dir: jax.Array      # (d,) rank-1 skew direction; zeros if iid
    het_sign: jax.Array | None = None  # (m,) ±1 f32; None until heterogenized


# ---------------------------------------------------------------------------
# in-kernel attack parameterization
# ---------------------------------------------------------------------------
#
# The scenario engine's per-row attack dispatch (repro.scenarios.adversary,
# a lax.switch over (m, d) arrays) collapses, for the generated-problem
# family, to an O(1)-per-worker parameter vector: every supported attack's
# Byzantine row is an affine function of quantities a strip can compute
# locally (the honest mean/std of the strip, the true-gradient strip, a
# per-worker constant).  ``GEN_PARAMS`` entries — slots a/b are the
# scenario's two coalition phases:
#
#   id    — effective ATTACK_TABLE id (retreat_on_filter is remapped to
#           inner_product/none on its scalar coalition-intact condition
#           before the kernel sees it)
#   sf    — sign_flip row factor:        row = sf · g          (sf = −3·scale)
#   z     — alie/alie_update deviation:  row = μ ∓ z·σ         (z = z_scale·z_max)
#   const — constant_drift / hidden_shift per-coordinate constant
#           (knob·V/√d; drift row = const, hidden row = t + const)
#   ipc   — inner_product pull:          row = t − ipc·t/‖t‖   (ipc = (1+s)·V)
#
# plus the two shared scalars ``tg_nrm`` (max(‖∇f(x)‖, 1e-12), the
# inner-product normalizer — O(d) on the host, not per-strip) and the
# problem's ``noise_scale``.  Unsupported in-kernel: random_gaussian (id 2,
# consumes a PRNG key per step) and mirror (needs a second problem).
GEN_NPARAMS = 12
(P_ID_A, P_SF_A, P_Z_A, P_CONST_A, P_IPC_A,
 P_ID_B, P_SF_B, P_Z_B, P_CONST_B, P_IPC_B,
 P_TGNRM, P_NSCALE) = range(GEN_NPARAMS)

# ATTACK_TABLE ids the generated path supports (repro.scenarios.adversary
# pins the table order; tests assert the two stay in sync)
GEN_SUPPORTED_IDS = (0, 1, 3, 4, 5, 6, 7, 8)


class GenStepCtx(NamedTuple):
    """Per-step adversary/worker inputs of the generating guard sweep —
    everything O(m) or O(1); the (m, d) batch it stands in for is never
    materialized.  Built by ``ScenarioAdversary.gen_attack_ctx`` + the
    solver's key chain each scan step."""

    worker_keys: jax.Array  # (m, 2) uint32 — key_bits of split(gkey, m)
    skewsign: jax.Array     # (m,) f32 — profile.skew · het_sign (0 = iid)
    slot: jax.Array         # (m,) int32 — 0 honest, 1 phase-a, 2 phase-b
    params: jax.Array       # (GEN_NPARAMS,) f32 — see above
    w_byz: jax.Array        # (m,) f32 — mask_k, for the feedback byz-row sum


def gen_worker_rows(x, h, x_star, het_dir, keys, skewsign, slot, params, j, d):
    """Regenerate + attack all worker rows for one coordinate strip.

    Pure ``jnp`` — the *same* function body is the Pallas kernel core
    (called per (m, d_blk) strip) and the host oracle (called once with
    ``j = arange(d)``), which is what makes kernel-vs-host parity exact by
    construction rather than by tolerance.

    Args:
      x, h, x_star, het_dir: (blk,) coordinate strips (f32).
      keys: (mp, 2) uint32 worker key words (padded rows arbitrary).
      skewsign: (mp,) f32 per-worker skew·sign (0 disables the het term).
      slot: (mp,) int32 — 0 honest, 1 attack-a, 2 attack-b, −1 padding.
      params: (GEN_NPARAMS,) f32 — see module comment.
      j: (blk,) or (1, blk) uint32 *global* coordinate indices.
      d: static true dimension — coords ≥ d are zero-masked (generated
         noise is nonzero in padded lanes, unlike zero-padded inputs).

    Returns (mp, blk) f32 attacked rows; invalid rows/coords are zeroed,
    mirroring the materializing path's zero padding.
    """
    p = params
    jm = j.reshape(1, -1)
    t = mean_grad(h, x, x_star)                              # true-grad strip
    bits = threefry2x32(keys[:, 0][:, None], keys[:, 1][:, None],
                        jnp.zeros_like(jm), jm)[0]           # (mp, blk)
    g = t[None, :] + p[P_NSCALE] * centered_uniform(bits)
    g = jnp.where(skewsign[:, None] != 0.0,
                  g + skewsign[:, None] * het_dir[None, :], g)

    # honest strip moments — the expressions of attacks._good_row_stats
    # (population moments over honest rows; coordinate-local, so the strip
    # slice equals the full-width computation)
    w = (slot == 0).astype(jnp.float32)[:, None]
    n_good = jnp.maximum(jnp.sum(w), 1.0)
    mu = jnp.sum(g * w, axis=0) / n_good
    var = jnp.sum(w * (g - mu[None, :]) ** 2, axis=0) / n_good
    sig = jnp.sqrt(var + 1e-12)
    gn = t / p[P_TGNRM]

    use_b = slot == 2
    aid = jnp.where(use_b, p[P_ID_B], p[P_ID_A])
    sf = jnp.where(use_b, p[P_SF_B], p[P_SF_A])
    zf = jnp.where(use_b, p[P_Z_B], p[P_Z_A])
    cst = jnp.where(use_b, p[P_CONST_B], p[P_CONST_A])
    ipc = jnp.where(use_b, p[P_IPC_B], p[P_IPC_A])

    # where-chain instead of lax.switch: ids are per-*worker* here, and
    # every branch is a cheap affine row — ids 0/2 (none / the unsupported
    # random_gaussian) fall through to the honest row
    row = g
    row = jnp.where((aid == 1.0)[:, None], sf[:, None] * g, row)
    row = jnp.where((aid == 3.0)[:, None], cst[:, None] + jnp.zeros_like(g), row)
    row = jnp.where((aid == 4.0)[:, None],
                    mu[None, :] - zf[:, None] * sig[None, :], row)
    row = jnp.where((aid == 8.0)[:, None],
                    mu[None, :] + zf[:, None] * sig[None, :], row)
    row = jnp.where((aid == 5.0)[:, None],
                    t[None, :] - ipc[:, None] * gn[None, :], row)
    row = jnp.where((aid == 6.0)[:, None], t[None, :] + cst[:, None], row)
    out = jnp.where((slot > 0)[:, None], row, g)

    keep = (slot >= 0)[:, None] & (jm < jnp.uint32(d))
    return jnp.where(keep, out, 0.0)
