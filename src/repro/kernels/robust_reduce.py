"""Pallas TPU kernels: coordinate-wise robust reductions over the worker axis.

The Yin et al. baseline (Median-GD / trimmed-mean-GD) and the paper's
filtered mean are all (m, d) → (d,) reductions: the strip-streaming
layout of DESIGN.md §4 with a (d_blk,) output strip per grid step.  The
reduction over m is a sorting network (odd-even min/max rounds) for the
order statistics and a masked dot for the filtered mean — no
(m, d)-sized temporaries (which the naive ``jnp.sort(axis=0)`` would
materialize), so the stream runs at HBM bandwidth.

Input strips stream in their storage dtype and are upcast to f32 in
VMEM (exact for bf16), so feeding bf16 worker data — the guard's
``stats_dtype`` axis, DESIGN.md §5 — halves the read traffic while the
reduction itself always accumulates and returns f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sorted_over_workers(x: jax.Array) -> jax.Array:
    """Bitonic-style full sort over axis 0 (m is small and static): odd-even
    transposition network with m rounds of elementwise min/max — vectorizes
    over the d_blk lane dimension, no data-dependent control flow."""
    m = x.shape[0]
    rows = [x[i] for i in range(m)]
    for rnd in range(m):
        start = rnd % 2
        for i in range(start, m - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    return jnp.stack(rows, axis=0)


def _median_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    s = _sorted_over_workers(x)
    m = x.shape[0]
    if m % 2:
        out_ref[...] = s[m // 2]
    else:
        out_ref[...] = 0.5 * (s[m // 2 - 1] + s[m // 2])


def _trimmed_mean_kernel(x_ref, out_ref, *, n_trim: int):
    x = x_ref[...].astype(jnp.float32)
    s = _sorted_over_workers(x)
    m = x.shape[0]
    out_ref[...] = jnp.mean(s[n_trim : m - n_trim], axis=0)


def _filtered_mean_kernel(x_ref, mask_ref, out_ref, *, denom: float,
                          sanitize: bool = False):
    x = x_ref[...].astype(jnp.float32)
    if sanitize:
        # static gate (DESIGN.md §15): zeroed-weight rows must not poison
        # the dot — 0 × Inf = NaN — so quarantined rows are zeroed in VMEM
        # before the reduction; off-state kernel body is unchanged
        x = jnp.where(jnp.isfinite(x), x, 0.0)
    w = mask_ref[...].astype(jnp.float32) / denom
    out_ref[...] = jnp.einsum("m,md->d", w, x)


def _reduce_call(kernel, x, extra_inputs=(), extra_specs=(), d_block=4096,
                 interpret=False):
    m, d = x.shape
    d_pad = (-d) % d_block
    if d_pad:
        x = jnp.pad(x, ((0, 0), (0, d_pad)))
    dp = x.shape[1]
    out = pl.pallas_call(
        kernel,
        grid=(dp // d_block,),
        in_specs=[pl.BlockSpec((m, d_block), lambda i: (0, i)), *extra_specs],
        out_specs=pl.BlockSpec((d_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(x, *extra_inputs)
    return out[:d]


@functools.partial(jax.jit, static_argnames=("d_block", "interpret"))
def coordinate_median_pallas(x: jax.Array, d_block: int = 4096,
                             interpret: bool = False) -> jax.Array:
    """(m, d) → (d,) coordinate-wise median."""
    return _reduce_call(_median_kernel, x, d_block=d_block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_trim", "d_block", "interpret"))
def trimmed_mean_pallas(x: jax.Array, n_trim: int, d_block: int = 4096,
                        interpret: bool = False) -> jax.Array:
    """(m, d) → (d,) coordinate-wise n_trim-trimmed mean."""
    if 2 * n_trim >= x.shape[0]:
        raise ValueError("trim exceeds worker count")
    return _reduce_call(
        functools.partial(_trimmed_mean_kernel, n_trim=n_trim),
        x, d_block=d_block, interpret=interpret,
    )


@functools.partial(jax.jit,
                   static_argnames=("denom", "d_block", "interpret", "sanitize"))
def filtered_mean_pallas(x: jax.Array, mask: jax.Array, denom: float,
                         d_block: int = 4096, interpret: bool = False,
                         sanitize: bool = False) -> jax.Array:
    """(m, d), (m,) → (d,): the paper's ξ_k = Σ_{i∈good_k} x_i / denom,
    fused mask-and-reduce (never materializes the masked copy).
    ``sanitize=True`` zeroes non-finite entries in VMEM first, so a
    quarantined (zero-weight) NaN/Inf row cannot poison the dot."""
    m = x.shape[0]
    mask_spec = pl.BlockSpec((m,), lambda i: (0,))
    return _reduce_call(
        functools.partial(_filtered_mean_kernel, denom=denom, sanitize=sanitize),
        x, extra_inputs=(mask.astype(jnp.float32),), extra_specs=(mask_spec,),
        d_block=d_block, interpret=interpret,
    )
