"""repro.kernels — Pallas TPU kernels for the master-side aggregation hot
spots (the O(m²d) / O(md) per-iteration work the paper's Table 1 accounts):

* ``pairdist``      — tiled worker-Gram matrix (feeds B_med/∇_med/Krum)
* ``robust_reduce`` — coordinate median / trimmed mean (Yin et al.
                      baseline) and the fused filtered mean ξ_k
* ``countsketch``   — fused sign-hash + strided-fold gradient sketch
                      (the scalable guard's compression)
* ``fused_guard``   — one-pass guard-statistics pipeline: both Gram
                      terms + A-increments + the B update in a single
                      HBM sweep (DESIGN.md §5)

All kernels share one grid/BlockSpec layout — grid ``(d // d_blk,)``,
``(m, d_blk)`` strips streamed HBM→VMEM, small ``(m, m)``/``(m,)``
outputs resident and accumulated across the grid, zero-initialized
under ``pl.when(i == 0)``.  Wrappers zero-pad d up to d_blk (exact for
every kernel) and slice it back off; the Gram-producing kernels
(``pairdist``, ``fused_guard``) additionally pad m to the 8-sublane
multiple — exact for Grams/sums, which is why the order-statistic
kernels in ``robust_reduce`` deliberately do NOT pad the worker axis
(zero rows would corrupt a median).  See DESIGN.md §4 for the full
convention, including VMEM budgets.

Kernels are validated on CPU in interpret mode against the ``ref.py``
jnp oracles; ``ops.py`` is the dispatch layer that selects interpret
mode automatically off-TPU.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
