"""repro.kernels — Pallas TPU kernels for the master-side aggregation hot
spots (the O(m²d) / O(md) per-iteration work the paper's Table 1 accounts):

* ``pairdist``      — tiled worker-Gram matrix (feeds B_med/∇_med/Krum)
* ``robust_reduce`` — coordinate median / trimmed mean (Yin et al.
                      baseline) and the fused filtered mean ξ_k
* ``countsketch``   — fused sign-hash + strided-fold gradient sketch
                      (the scalable guard's compression)

Kernels are written with explicit BlockSpec VMEM tiling for TPU and
validated on CPU in interpret mode against ``ref.py`` jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
