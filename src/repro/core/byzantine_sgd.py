"""ByzantineSGD — Algorithm 1 of Alistarh, Allen-Zhu & Li (NeurIPS 2018).

The algorithm keeps, per worker i ∈ [m]:

* ``A_i = Σ_{t≤k} ⟨∇_{t,i}, x_t − x_1⟩``  (scalar martingale),
* ``B_i = Σ_{t≤k} ∇_{t,i}``               (vector martingale),

and per iteration filters workers against three robust centers:

* the scalar median ``A_med`` of ``{A_i}``          (|A_i − A_med| ≤ 𝔗_A),
* a counting vector-median ``B_med``                (‖B_i − B_med‖ ≤ 𝔗_B),
* a counting vector-median ``∇_med`` of the fresh
  gradients                                          (‖∇_i − ∇_med‖ ≤ 4V),

where ``𝔗_A = 4DV√(kC)``, ``𝔗_B = 4V√(kC)``, ``C = log(16mT/δ)``
(Section 3.1/3.2 — the Lemma 3.6 *anytime* form; the fixed-T form from the
Algorithm 1 header is available via ``threshold_mode='fixed'``).  The update
direction is the filtered mean ``ξ_k = (1/m) Σ_{i∈good_k} ∇_{k,i}``.

TPU adaptation (see DESIGN.md §3): every distance computation is expressed
through Gram matrices so that the *distributed* realization never has to
materialize an ``(m, d)`` gradient matrix on one device — ``‖v_i − v_j‖² =
G_ii + G_jj − 2 G_ij``.  The dense single-host form below is the reference
implementation (and the oracle for the Pallas kernels); the mesh form in
``repro.distributed`` reuses ``filter_update`` verbatim on psum'd Grams.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


# ---------------------------------------------------------------------------
# configuration / state
# ---------------------------------------------------------------------------

# the statistics-precision axis (DESIGN.md §5 Numerics): storage dtype of
# the streamed guard statistics (g strips, the B martingale).  All filter
# *accumulation* (Grams, A, ξ) stays f32 regardless — bf16 only halves the
# bytes each (m, d) pass moves, it never changes what is accumulated in.
STATS_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def resolve_stats_dtype(name: str) -> jnp.dtype:
    """``'f32' | 'bf16'`` → jnp dtype; typos fail loudly (config axis)."""
    try:
        return jnp.dtype(STATS_DTYPES[name])
    except KeyError:
        raise KeyError(
            f"unknown stats_dtype {name!r}; have {sorted(STATS_DTYPES)}"
        ) from None

class GuardConfig(NamedTuple):
    """Static parameters of the filter.

    Attributes:
      m: number of workers.
      T: planned number of iterations (enters C = log(16mT/δ) and the
         fixed-threshold mode).
      V: the paper's 𝒱 — a.s. bound on ‖∇f_s(x) − ∇f(x)‖ (Assumption 2.2).
      D: diameter bound ‖x_1 − x*‖ ≤ D.
      delta: failure probability.
      threshold_mode: 'anytime' → 𝔗(k) ∝ √(kC) (Lemma 3.6 form, default);
                      'fixed'   → 𝔗 ∝ √(TC)   (Algorithm 1 header form).
      mean_over_alive: False (paper: divide ξ by m) or True (divide by
                      |good_k|; a practical variant — unbiased when filters
                      fire, used by the LM training examples).
      grad_radius_mult: the "4V" of the per-iteration gradient check.
      median_radius_mult: the "2V" counting radius for ∇_med.
    """

    m: int
    T: int
    V: float
    D: float
    delta: float = 1e-3
    threshold_mode: str = "anytime"
    mean_over_alive: bool = False
    grad_radius_mult: float = 4.0
    median_radius_mult: float = 2.0

    @property
    def C(self) -> float:
        return math.log(16.0 * self.m * max(self.T, 1) / self.delta)

    def thresholds(self, k: jax.Array):
        """(𝔗_A, 𝔗_B) at iteration k (1-based)."""
        if self.threshold_mode == "fixed":
            t = jnp.asarray(float(self.T), jnp.float32)
        else:
            t = jnp.maximum(k.astype(jnp.float32), 1.0)
        root = jnp.sqrt(t * self.C)
        return 4.0 * self.D * self.V * root, 4.0 * self.V * root


class GuardState(NamedTuple):
    """Per-worker filter state (a pytree; leaves have leading dim m).

    ``gram_B`` carries ⟨B_i, B_j⟩ across iterations so the streaming path
    never recomputes B Bᵀ from scratch: the rank-style identity
    ``G_B^k = G_B^{k-1} + B gᵀ + g Bᵀ + g gᵀ`` (DESIGN.md §5) updates it
    from quantities the fused kernel already produces.  The dense path
    recomputes it each step (and so doubles as the drift oracle)."""

    A: jax.Array        # (m,)  scalar martingales (always f32)
    B: jax.Array        # (m, d) gradient-sum martingales, stored in the
    #                     guard's stats dtype (f32 | bf16 — DESIGN.md §5)
    alive: jax.Array    # (m,) bool — good_{k-1}
    k: jax.Array        # () int32 — iterations done
    gram_B: jax.Array   # (m, m) ⟨B_i, B_j⟩ — maintained incrementally


# ---------------------------------------------------------------------------
# geometry helpers (pure; reused by the distributed layer + kernels)
# ---------------------------------------------------------------------------

def pairwise_sq_dists_from_gram(gram: jax.Array) -> jax.Array:
    """‖v_i − v_j‖² from the Gram matrix G_ij = ⟨v_i, v_j⟩."""
    diag = jnp.diagonal(gram)
    d2 = diag[:, None] + diag[None, :] - 2.0 * gram
    return jnp.maximum(d2, 0.0)  # clamp numerical negatives


def counting_median_index(
    sq_dists: jax.Array, radius: jax.Array, report: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """The paper's counting vector-median, from pairwise squared distances.

    Returns ``(index, found)`` where ``index`` selects any point with more
    than m/2 points within ``radius`` (the paper proves every good worker
    qualifies w.h.p.).  Deterministic tie-break: among valid points, the one
    with the smallest total distance (a medoid refinement); if *no* point is
    valid — possible off the high-probability event or under extreme attacks
    — we fall back to the global medoid, which is the standard robust choice
    and keeps the algorithm total.

    ``report`` (optional (m,) bool) restricts the median to workers that
    reported this step: counts run over reporting columns, validity requires
    > n_reporting/2 of them, and only reporting rows may be elected (the
    fallback medoid is likewise reporter-restricted).  ``report=None``
    keeps the original all-workers trace (no extra ops in the jaxpr).
    """
    m = sq_dists.shape[0]
    within = sq_dists <= radius * radius
    inf = jnp.float32(jnp.inf)
    score = jnp.sum(jnp.sqrt(sq_dists), axis=1)  # total distance (medoid score)
    if report is None:
        counts = jnp.sum(within, axis=1)
        valid = counts * 2 > m
        fallback = score
    else:
        counts = jnp.sum(within & report[None, :], axis=1)
        n_r = jnp.sum(report)
        valid = (counts * 2 > n_r) & report
        score = jnp.sum(jnp.where(report[None, :], jnp.sqrt(sq_dists), 0.0),
                        axis=1)
        fallback = jnp.where(report, score, inf)
    masked_score = jnp.where(valid, score, inf)
    found = jnp.any(valid)
    idx = jnp.where(found, jnp.argmin(masked_score), jnp.argmin(fallback))
    return idx, found


def scalar_median(x: jax.Array) -> jax.Array:
    return jnp.median(x)


def masked_median(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Median of ``x[mask]`` with a traced boolean mask and static shapes.

    Reproduces ``jnp.median``'s linear-interpolation quantile exactly —
    when ``mask`` is all-True the result is bit-identical to
    ``jnp.median(x)`` (pinned by test), which is what lets the armed
    partial-participation machinery stay on the pre-PR trajectory for a
    fully-participating fleet.  Masked-out entries sort to +inf and the
    interpolation index is computed from the traced reporter count.
    """
    n = jnp.sum(mask)
    sorted_x = jnp.sort(jnp.where(mask, x, jnp.inf))
    index = 0.5 * jnp.maximum(n - 1, 0).astype(jnp.float32)
    low = jnp.floor(index)
    high = jnp.ceil(index)
    low_val = sorted_x[low.astype(jnp.int32)]
    high_val = sorted_x[high.astype(jnp.int32)]
    high_weight = index - low
    return low_val * (1.0 - high_weight) + high_val * high_weight


# ---------------------------------------------------------------------------
# the filter itself (Algorithm 1 lines 7–10), Gram form
# ---------------------------------------------------------------------------

def filter_update(
    A: jax.Array,          # (m,)   A_i^{(k)}
    gram_B: jax.Array,     # (m, m) ⟨B_i, B_j⟩
    gram_g: jax.Array,     # (m, m) ⟨∇_{k,i}, ∇_{k,j}⟩
    alive: jax.Array,      # (m,)   good_{k-1}
    k: jax.Array,          # ()     iteration (1-based)
    cfg: GuardConfig,
    report: jax.Array | None = None,  # (m,) bool — who reported this step
) -> tuple[jax.Array, dict]:
    """One application of the Algorithm-1 filter; returns (good_k, diag).

    Medians are taken over all m workers — Algorithm 1 computes A_med /
    B_med / ∇_med over [m], not over good_{k-1}; only the *intersection*
    uses good_{k-1}.

    ``report`` (DESIGN.md §13) is the per-step *reporting* mask, distinct
    from the Byzantine alive mask: medians are computed over reporting
    workers only, and a worker that did not report is never scored (its
    good_{k-1} status passes through unchanged).  The caller must have
    zero-masked non-reporting rows out of the streamed statistics so A/B
    are frozen for them; this function only controls who is *scored*.
    ``report=None`` is the static everyone-reports gate — the jaxpr is
    identical to the pre-profile build.
    """
    t_a, t_b = cfg.thresholds(k)

    # line 7: scalar median of A (over reporters)
    a_med = scalar_median(A) if report is None else masked_median(A, report)
    dev_a = jnp.abs(A - a_med)
    ok_a = dev_a <= t_a

    # line 8: counting median of B at radius 𝔗_B
    d2_b = pairwise_sq_dists_from_gram(gram_B)
    idx_b, found_b = counting_median_index(d2_b, t_b, report)
    dist_b = jnp.sqrt(d2_b[idx_b])
    ok_b = dist_b <= t_b

    # line 9: counting median of fresh gradients at radius 2V, filter at 4V
    d2_g = pairwise_sq_dists_from_gram(gram_g)
    idx_g, found_g = counting_median_index(
        d2_g, cfg.median_radius_mult * cfg.V, report
    )
    dist_g = jnp.sqrt(d2_g[idx_g])
    t_g = cfg.grad_radius_mult * cfg.V
    ok_g = dist_g <= t_g

    # line 10: good_k = good_{k-1} ∩ {A ok} ∩ {B ok} ∩ {∇ ok}; workers that
    # did not report are not scored — their status passes through
    if report is None:
        good_k = alive & ok_a & ok_b & ok_g
    else:
        good_k = alive & (ok_a | ~report) & (ok_b | ~report) & (ok_g | ~report)
    # the per-worker deviation series (dev_a / dist_b / dist_g vs their
    # thresholds) double as the flight recorder's event schema — they are
    # the Algorithm-1 forensics the telemetry layer streams (DESIGN.md §12)
    # and are dead code (freely eliminated) whenever nothing consumes them
    diag = {
        "n_alive": jnp.sum(good_k),
        "a_med": a_med,
        "b_med_index": idx_b,
        "b_med_found": found_b,
        "grad_med_index": idx_g,
        "grad_med_found": found_g,
        "threshold_A": t_a,
        "threshold_B": t_b,
        "threshold_grad": jnp.asarray(t_g, jnp.float32),
        "dev_a": dev_a,
        "dist_b": dist_b,
        "dist_g": dist_g,
        "n_fail_A": jnp.sum(~ok_a),
        "n_fail_B": jnp.sum(~ok_b),
        "n_fail_grad": jnp.sum(~ok_g),
    }
    return good_k, diag


# ---------------------------------------------------------------------------
# dense reference guard over stacked (m, d) gradients
# ---------------------------------------------------------------------------

class ByzantineGuard:
    """Single-host reference form of ByzantineSGD's filter + aggregation.

    Usage::

        guard = ByzantineGuard(cfg)
        state = guard.init(d)
        state, xi, diag = guard.step(state, grads, x_k, x_1)   # jit-able

    ``grads`` is the stacked (m, d) matrix of per-worker gradients at x_k.
    ``xi`` is the paper's ξ_k = (1/m) Σ_{i∈good_k} ∇_{k,i}.

    ``use_fused=True`` routes the O(m·d) / O(m²·d) work through the
    one-pass Pallas pipeline (:mod:`repro.kernels.fused_guard` + the
    fused filtered-mean): each step reads ``grads`` and ``B`` once,
    updates ``gram_B`` incrementally, and never re-forms B Bᵀ — halving
    HBM traffic per guard step (DESIGN.md §5).  The default dense form
    is the correctness oracle the fused path is tested against.

    The two forms are the ``dense`` / ``fused`` guard *backends* of the
    solver and campaign runner (:mod:`repro.core.guard_backends`,
    DESIGN.md §9) — select via ``SolverConfig.guard_backend`` instead of
    constructing a guard directly when driving ``run_sgd``.

    ``stats_dtype`` (``'f32'`` | ``'bf16'``, DESIGN.md §5 Numerics) is the
    *storage* dtype of the streamed statistics: gradients are rounded to
    it once on entry, ``B`` lives in it across iterations, and the fused
    kernel streams both as half-width strips — halving the step's HBM
    traffic.  Accumulation (Grams, A, ξ) is always f32, so under bf16 the
    only new rounding is the per-step input/``B``-store rounding; the
    dense form re-derives ``gram_B`` from the stored ``B`` every step
    (making it the bf16 drift oracle), while the fused form rank-updates
    and re-derives every ``gram_resync_every`` steps to bound the
    accumulated divergence between the incremental Gram and the rounded
    ``B`` actually in memory.
    """

    def __init__(self, cfg: GuardConfig, use_fused: bool = False,
                 d_block: int = 2048, gram_resync_every: int = 64,
                 stats_dtype: str = "f32", gen_spec=None,
                 sanitize: bool = False):
        self.cfg = cfg
        self.use_fused = use_fused
        self.d_block = d_block
        # non-finite hygiene (DESIGN.md §15): when armed, NaN/Inf entries
        # are zeroed before any statistic (keeping A/B/Gram finite forever)
        # and rows containing them are removed from good_k — permanently,
        # via the carried alive mask.  The dense path checks explicitly;
        # the fused path folds the check into the one HBM sweep (the
        # kernel zeroes in VMEM and emits per-row non-finite counts).
        self.sanitize = bool(sanitize)
        # on-device generation (DESIGN.md §14): when a GenSpec rides along,
        # gen_step regenerates the gradient strips inside the sweep instead
        # of step reading a materialized (m, d) batch
        self.gen_spec = gen_spec
        # fused path: every N-th step re-derive gram_B from B instead of
        # rank-updating, zeroing accumulated f32 rounding (0 disables);
        # amortized cost is one extra B read per N steps.  Under bf16
        # stats the re-derivation also re-anchors the Gram to the rounded
        # B in storage (the quantity the dense oracle uses).
        self.gram_resync_every = gram_resync_every
        self.stats_dtype = resolve_stats_dtype(stats_dtype)

    def init(self, d: int) -> GuardState:
        m = self.cfg.m
        return GuardState(
            A=jnp.zeros((m,), jnp.float32),
            B=jnp.zeros((m, d), self.stats_dtype),
            alive=jnp.ones((m,), bool),
            k=jnp.zeros((), jnp.int32),
            gram_B=jnp.zeros((m, m), jnp.float32),
        )

    def step(
        self,
        state: GuardState,
        grads: jax.Array,   # (m, d)
        x_k: jax.Array,     # (d,)
        x_1: jax.Array,     # (d,)
        report: jax.Array | None = None,  # (m,) bool reporting mask
    ) -> tuple[GuardState, jax.Array, dict]:
        cfg = self.cfg
        m = cfg.m
        # the single entry rounding of the stats axis: everything streamed
        # below (Grams, A, B update, ξ) reads these strips.  A no-op cast
        # at f32; the one place bf16 precision is actually lost.
        grads = grads.astype(self.stats_dtype)
        if report is not None:
            # entry masking is all the streaming paths need for partial
            # participation: a zero row contributes 0 to the A increment,
            # freezes B_i, and keeps the incremental-Gram identity exact —
            # so the fused kernel and both Gram forms run unchanged and
            # only the filter itself is reporter-aware (DESIGN.md §13)
            grads = jnp.where(report[:, None], grads,
                              jnp.zeros((), self.stats_dtype))
        k = state.k + 1
        delta = (x_k - x_1).astype(self.stats_dtype)

        finite = None  # sanitize-off: no finite mask in the trace
        if self.sanitize and not self.use_fused:
            # dense sanitize: explicit elementwise zeroing ahead of every
            # statistic; the fused path does the same inside its sweep
            fin = jnp.isfinite(grads)
            finite = jnp.all(fin, axis=1)
            grads = jnp.where(fin, grads, jnp.zeros((), self.stats_dtype))

        if self.use_fused:
            # one HBM sweep: both Grams' raw terms + A-increments + B
            # (strips stream in stats dtype, accumulators f32)
            with jax.named_scope("guard/stats_sweep"):
                if self.sanitize:
                    gram_g, cross, a_inc, B, nf = ops.fused_guard(
                        grads, state.B, delta, d_block=self.d_block,
                        sanitize=True,
                    )
                    finite = nf == 0
                else:
                    gram_g, cross, a_inc, B = ops.fused_guard(
                        grads, state.B, delta, d_block=self.d_block
                    )
                A = state.A + a_inc
                gram_b = state.gram_B + cross + cross.T + gram_g
            if self.gram_resync_every > 0:
                with jax.named_scope("guard/resync"):
                    is_resync = k % self.gram_resync_every == 0
                    derived = jax.lax.cond(
                        is_resync,
                        lambda: _gram32(B),
                        lambda: gram_b,
                    )
                    # resync drift: how far the rank-updated Gram had
                    # wandered from B Bᵀ when re-anchored — observable at
                    # resync steps (`derived` is the from-scratch Gram
                    # there), NaN between them.  O(m²), dead code unless
                    # the flight recorder consumes it.
                    gram_drift = jnp.where(
                        is_resync,
                        jnp.linalg.norm(derived - gram_b),
                        jnp.float32(jnp.nan),
                    )
                    gram_b = derived
            else:
                gram_drift = jnp.full((), jnp.nan, jnp.float32)
        else:
            # f32 views of the stored/rounded values — exact upcasts, so
            # the dense path is the numerics oracle at either stats dtype
            with jax.named_scope("guard/stats_sweep"):
                g32 = grads.astype(jnp.float32)
                # line 5: accumulate the two martingales (A in f32; B stored
                # back in the stats dtype, rounded once like the fused kernel)
                A = state.A + g32 @ delta.astype(jnp.float32)
                B = (state.B.astype(jnp.float32) + g32).astype(self.stats_dtype)
                # Gram matrices (the three independent O(m·d)/O(m²·d) passes
                # the fused pipeline replaces)
                gram_b = _gram32(B)
                gram_g = g32 @ g32.T
            # the dense path re-derives gram_B every step — drift is zero
            # by construction (that is what makes it the drift oracle)
            gram_drift = jnp.zeros((), jnp.float32)

        # quarantine (DESIGN.md §15): a non-finite row must not be *scored*
        # (its zeroed statistics are not the worker's report — feeding them
        # to the medians would be scoring fabricated data), and it must not
        # survive.  Routing `finite` through the reporting mask gets the
        # not-scored half for free; the explicit &-kill closes the
        # pass-through that mask grants non-reporters.
        report_eff = report
        if self.sanitize:
            report_eff = finite if report is None else report & finite
        with jax.named_scope("guard/filter"):
            good_k, diag = filter_update(
                A, gram_b, gram_g, state.alive, k, cfg, report_eff
            )
        if self.sanitize:
            good_k = good_k & finite
            diag["n_alive"] = jnp.sum(good_k)
            diag["n_nonfinite"] = jnp.sum(~finite)
        diag["gram_drift"] = gram_drift

        # ξ averages the gradients that actually arrived: good ∩ reporting
        # (rows of non-reporters were zeroed at entry anyway, but the
        # mean_over_alive denominator must count contributors, not good_k)
        contrib = good_k if report is None else good_k & report
        denom = jnp.where(
            cfg.mean_over_alive, jnp.maximum(jnp.sum(contrib), 1), m
        ).astype(jnp.float32)
        with jax.named_scope("guard/aggregate"):
            if self.use_fused:
                xi = ops.filtered_mean(
                    grads, contrib.astype(jnp.float32) / denom, 1.0,
                    d_block=self.d_block, sanitize=self.sanitize,
                )
            else:
                xi = (contrib.astype(jnp.float32) @ grads.astype(jnp.float32)) / denom

        new_state = GuardState(A=A, B=B, alive=good_k, k=k, gram_B=gram_b)
        return new_state, xi, diag

    def gen_step(
        self,
        state: GuardState,
        genctx,             # repro.kernels.gradgen.GenStepCtx
        x_k: jax.Array,     # (d,)
        x_1: jax.Array,     # (d,)
    ) -> tuple[GuardState, jax.Array, jax.Array, dict]:
        """:meth:`step` with the gradient batch *generated in-kernel*
        (DESIGN.md §14): the worker strips are rebuilt from the GenSpec +
        per-step :class:`~repro.kernels.gradgen.GenStepCtx` inside the
        fused sweep and the ξ pass, so no (m, d) array crosses HBM — the
        guard's per-step traffic is the two B strips.  Returns
        ``(state, ξ, byz_sum, diag)`` where ``byz_sum = Σᵢ w_byz[i]·∇ᵢ``
        is the adversary's feedback row-sum (the one consumer of the
        attacked batch outside the guard).  Filter numerics mirror the
        fused path: strips round through the stats dtype before the
        accumulators, the incremental Gram re-anchors every
        ``gram_resync_every`` steps.
        """
        if self.gen_spec is None:
            raise ValueError("gen_step needs a GenSpec (pass gen_spec=...)")
        cfg = self.cfg
        m = cfg.m
        gen = self.gen_spec
        k = state.k + 1
        delta = (x_k - x_1).astype(self.stats_dtype)

        with jax.named_scope("guard/stats_sweep"):
            gram_g, cross, a_inc, B = ops.fused_guard_gen(
                state.B, delta, x_k, gen.h, gen.x_star, gen.het_dir,
                genctx.worker_keys, genctx.skewsign, genctx.slot,
                genctx.params, d_block=self.d_block,
            )
            A = state.A + a_inc
            gram_b = state.gram_B + cross + cross.T + gram_g
        if self.gram_resync_every > 0:
            with jax.named_scope("guard/resync"):
                is_resync = k % self.gram_resync_every == 0
                derived = jax.lax.cond(
                    is_resync,
                    lambda: _gram32(B),
                    lambda: gram_b,
                )
                gram_drift = jnp.where(
                    is_resync,
                    jnp.linalg.norm(derived - gram_b),
                    jnp.float32(jnp.nan),
                )
                gram_b = derived
        else:
            gram_drift = jnp.full((), jnp.nan, jnp.float32)

        with jax.named_scope("guard/filter"):
            good_k, diag = filter_update(
                A, gram_b, gram_g, state.alive, k, cfg, None
            )
        diag["gram_drift"] = gram_drift

        contrib = good_k
        denom = jnp.where(
            cfg.mean_over_alive, jnp.maximum(jnp.sum(contrib), 1), m
        ).astype(jnp.float32)
        with jax.named_scope("guard/aggregate"):
            xi, byz_sum = ops.gen_xi(
                contrib.astype(jnp.float32) / denom, genctx.w_byz,
                x_k, gen.h, gen.x_star, gen.het_dir,
                genctx.worker_keys, genctx.skewsign, genctx.slot,
                genctx.params, d_block=self.d_block,
                stats_dtype=str(self.stats_dtype),
            )

        new_state = GuardState(A=A, B=B, alive=good_k, k=k, gram_B=gram_b)
        return new_state, xi, byz_sum, diag


def _gram32(x: jax.Array) -> jax.Array:
    """X Xᵀ with f32 accumulation from X's storage dtype (exact upcast)."""
    x32 = x.astype(jnp.float32)
    return x32 @ x32.T
