"""Byzantine attack zoo.

Remark 2.3 of the paper allows Byzantine workers to return *arbitrary*
vectors, to collude, and to observe everything sent so far (they may depend
on all gradients of all machines up to the current iteration).  We implement
the standard adversary classes from the Byzantine-SGD literature plus two
paper-specific ones:

* ``hidden_shift`` — the Section-1.3 "hide inside the thresholds" adversary:
  a coordinated small bias of magnitude ≈ c·V that *passes* the A/B/∇
  checks; Lemmas 3.6/3.7 prove its damage is bounded — our tests verify the
  empirical loss inflation matches the O(αDV/√T) prediction.
* ``lower_bound`` — the Section-5 indistinguishability adversary: Byzantine
  workers faithfully simulate good workers of the *mirror* objective.

All attacks share the signature::

    attack(key, grads, byz_mask, ctx) -> grads'

where ``grads`` is (m, d) with rows of *good* gradients everywhere (the
simulator first computes honest gradients for every worker, then the attack
overwrites the Byzantine rows), ``byz_mask`` is (m,) bool, and ``ctx`` is a
dict of adversary knowledge: ``true_grad`` (d,), ``V``, ``step`` and
optionally ``mirror_grad``.

The solver additionally feeds back everything the Remark-2.3 adversary is
entitled to observe from the *previous* iteration (zeros / all-alive on the
first step):

* ``ctx["alive"]`` (m,) bool — good_{k-1}, the guard's filter decision
  (all-True under stateless aggregators),
* ``ctx["n_alive"]`` () — |good_{k-1}|,
* ``ctx["prev_xi"]`` (d,) — the realized aggregated update ξ_{k-1}
  (observable from the broadcast iterates: x_k = x_{k-1} − η ξ_{k-1}).

Stateless attacks ignore these; *adaptive* attacks (``retreat_on_filter``
here, and anything run with ``adapt_rate > 0`` through
:mod:`repro.scenarios`) condition on them.  Scheduled / coalition behaviour
is built from these primitives via the combinators at the bottom
(:func:`phase_switch`, :func:`coalition`).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def _overwrite(grads: jax.Array, byz_mask: jax.Array, rows: jax.Array) -> jax.Array:
    """Replace Byzantine rows with ``rows`` (broadcast against (m, d));
    honest rows pass through bit-identical.  A shared colluding row should
    be passed as (1, d) — ``jnp.where`` broadcasts it, so no (m, d) temp is
    materialized in the scan body."""
    return jnp.where(byz_mask[:, None], rows, grads)


def attack_none(key, grads, byz_mask, ctx):
    """Byzantine workers behave honestly (sanity baseline)."""
    return grads


def attack_sign_flip(key, grads, byz_mask, ctx, scale: float = 3.0):
    """Classic reversed-gradient attack: send −scale · (own gradient)."""
    return _overwrite(grads, byz_mask, -scale * grads)


def attack_random_gaussian(key, grads, byz_mask, ctx, scale: float = 100.0):
    """Large iid Gaussian noise — crashes naive mean, trivially filtered."""
    noise = scale * jax.random.normal(key, grads.shape, grads.dtype)
    return _overwrite(grads, byz_mask, noise)


def attack_constant_drift(key, grads, byz_mask, ctx, scale: float = 10.0):
    """All Byzantine workers send the same constant vector (colluding pull
    toward a fixed wrong direction)."""
    d = grads.shape[1]
    direction = jnp.ones((d,), grads.dtype) / jnp.sqrt(d)
    return _overwrite(grads, byz_mask, scale * ctx["V"] * direction[None, :])


def alie_z_max(n_workers, n_byz) -> jax.Array:
    """The calibrated ALIE deviation z_max (Baruch et al., blades parity).

    With m of n workers Byzantine, the attack needs s = ⌊n/2 + 1⌋ − m
    honest *supporters* — honest workers whose gradients land further from
    the mean than the Byzantine rows — for the corrupted rows to sit inside
    the majority.  Under the per-coordinate normality assumption that means

        z_max = Φ⁻¹((n − m − s) / (n − m)),

    evaluated in-trace via ``jax.scipy.special.ndtri`` (the norm-ppf
    equivalent), so scenario campaigns vmap it over traced per-step
    Byzantine counts (churn/late-join schedules change m mid-run).  The cdf
    argument is clipped away from {0, 1}: a coalition past n/2 (outside the
    calibration's regime) saturates instead of returning ±inf.
    """
    n = jnp.asarray(n_workers, jnp.float32)
    mb = jnp.asarray(n_byz, jnp.float32)
    n_good = jnp.maximum(n - mb, 1.0)
    s = jnp.floor(n / 2.0 + 1.0) - mb
    cdf = (n_good - s) / n_good
    return jax.scipy.special.ndtri(jnp.clip(cdf, 1e-6, 1.0 - 1e-6))


def _good_row_stats(grads, byz_mask):
    """(μ, σ²) over the honest rows (population moments, coordinate-wise)."""
    w = (~byz_mask).astype(grads.dtype)[:, None]
    n_good = jnp.maximum(jnp.sum(w), 1.0)
    mu = jnp.sum(grads * w, axis=0) / n_good
    var = jnp.sum(w * (grads - mu[None, :]) ** 2, axis=0) / n_good
    return mu, var


def attack_alie(key, grads, byz_mask, ctx, z: float | None = None,
                z_scale: float = 1.0):
    """'A little is enough' (Baruch et al.): colluding workers send
    mean − z·std (coordinate-wise), staying within plausible deviation.

    ``z=None`` (the default) calibrates z to the supporter count exactly as
    the blades benchmark does — :func:`alie_z_max` computed in-trace from
    the *current* Byzantine count; a float pins it explicitly (the
    historical toy behaviour was the uncalibrated ``z=1.0``).  ``z_scale``
    multiplies whichever z is in effect — the scenario engine's generic
    magnitude knob."""
    zz = alie_z_max(grads.shape[0], jnp.sum(byz_mask)) if z is None else z
    mu, var = _good_row_stats(grads, byz_mask)
    row = mu - z_scale * zz * jnp.sqrt(var + 1e-12)
    return _overwrite(grads, byz_mask, row[None, :])


def attack_alie_update(key, grads, byz_mask, ctx, z: float | None = None,
                       z_scale: float = 1.0):
    """The fedavg/update ALIE variant (blades ``is_fedavg=True``): the same
    μ − z·σ lie applied to the workers' *updates* rather than their
    gradients.  An honest update is u_i = −η·g_i, so ALIE on updates sends
    u = μ_u − z·σ_u = −η(μ_g + z·σ_g) — i.e. expressed back in gradient
    space the perturbation flips sign: μ + z·σ.  The two variants probe
    opposite coordinate-wise tails, which is why blades sweeps both."""
    zz = alie_z_max(grads.shape[0], jnp.sum(byz_mask)) if z is None else z
    mu, var = _good_row_stats(grads, byz_mask)
    row = mu + z_scale * zz * jnp.sqrt(var + 1e-12)
    return _overwrite(grads, byz_mask, row[None, :])


def attack_inner_product(key, grads, byz_mask, ctx, scale: float = 1.0):
    """Omniscient negative-inner-product attack: push exactly against the
    true gradient, scaled to the top of the allowed deviation V."""
    g = ctx["true_grad"]
    gn = g / jnp.maximum(jnp.linalg.norm(g), 1e-12)
    row = g - (1.0 + scale) * ctx["V"] * gn
    return _overwrite(grads, byz_mask, row[None, :])


def attack_hidden_shift(key, grads, byz_mask, ctx, c: float = 0.9):
    """The paper's 'hide inside the thresholds' adversary (Section 1.3):
    report (true gradient + c·V·u) for a fixed colluding unit direction u.
    Each row is a *valid-looking* stochastic gradient (deviation c·V ≤ V),
    its A/B martingales grow like an honest worker's, so the filter
    (correctly) cannot remove it; Lemma 3.6 bounds the damage instead."""
    d = grads.shape[1]
    u = jnp.ones((d,), grads.dtype) / jnp.sqrt(d)
    row = ctx["true_grad"] + c * ctx["V"] * u
    return _overwrite(grads, byz_mask, row[None, :])


def attack_mirror(key, grads, byz_mask, ctx):
    """Section-5 lower-bound adversary: Byzantine workers behave as honest
    workers of the mirror objective (requires ctx['mirror_grads'])."""
    return _overwrite(grads, byz_mask, ctx["mirror_grads"])


def attack_retreat_on_filter(key, grads, byz_mask, ctx, scale: float = 1.0):
    """Filter-feedback evasion: strike (inner-product row) only while the
    whole coalition is still alive per the guard's previous filter decision
    (``ctx["alive"]``); once any colluder is caught, the survivors revert to
    honest behaviour to avoid tripping the martingale checks themselves.
    Against stateless aggregators ``alive`` is constant all-True, so this
    degenerates to the static inner-product attack."""
    alive = ctx["alive"]
    n_byz = jnp.maximum(jnp.sum(byz_mask), 1)
    coalition_intact = jnp.sum(alive & byz_mask) >= n_byz
    struck = attack_inner_product(key, grads, byz_mask, ctx, scale=scale)
    return jnp.where(coalition_intact, struck, grads)


ATTACKS: dict[str, Callable] = {
    "none": attack_none,
    "sign_flip": attack_sign_flip,
    "random_gaussian": attack_random_gaussian,
    "constant_drift": attack_constant_drift,
    "alie": attack_alie,
    "alie_update": attack_alie_update,
    "inner_product": attack_inner_product,
    "hidden_shift": attack_hidden_shift,
    "mirror": attack_mirror,
    "retreat_on_filter": attack_retreat_on_filter,
}


def get_attack(name: str) -> Callable:
    if name not in ATTACKS:
        raise KeyError(f"unknown attack {name!r}; have {sorted(ATTACKS)}")
    return ATTACKS[name]


def apply_attack(name: str, key, grads, byz_mask, ctx, **kwargs):
    return get_attack(name)(key, grads, byz_mask, ctx, **kwargs)


# ---------------------------------------------------------------------------
# combinators — scheduled / split adversaries from the primitives above.
# Each returns a callable with the standard attack signature; the closed-over
# parameters may be Python numbers or traced scalars (so the scenario engine
# can vmap over them — see repro.scenarios.adversary).
# ---------------------------------------------------------------------------

def phase_switch(attack_a: Callable, attack_b: Callable, switch_step) -> Callable:
    """Scheduled phase change: play ``attack_a`` while ``step < switch_step``,
    then ``attack_b`` (e.g. lie low past the 𝔗_A/𝔗_B warmup, then strike)."""

    def attack(key, grads, byz_mask, ctx, **kwargs):
        ka, kb = jax.random.split(key)
        ga = attack_a(ka, grads, byz_mask, ctx, **kwargs)
        gb = attack_b(kb, grads, byz_mask, ctx, **kwargs)
        return jnp.where(ctx["step"] >= switch_step, gb, ga)

    return attack


def coalition(attack_a: Callable, attack_b: Callable, frac) -> Callable:
    """Coalition split: the first ⌈frac·n_byz⌉ Byzantine workers (by index
    order) play ``attack_a``, the rest simultaneously play ``attack_b``."""

    def attack(key, grads, byz_mask, ctx, **kwargs):
        ka, kb = jax.random.split(key)
        ga = attack_a(ka, grads, byz_mask, ctx, **kwargs)
        gb = attack_b(kb, grads, byz_mask, ctx, **kwargs)
        n_byz = jnp.sum(byz_mask)
        rank = jnp.cumsum(byz_mask) - 1          # 0-based index among byz
        in_a = byz_mask & (rank < jnp.ceil(frac * n_byz))
        return jnp.where(in_a[:, None], ga, gb)

    return attack
