"""Byzantine attack zoo.

Remark 2.3 of the paper allows Byzantine workers to return *arbitrary*
vectors, to collude, and to observe everything sent so far (they may depend
on all gradients of all machines up to the current iteration).  We implement
the standard adversary classes from the Byzantine-SGD literature plus two
paper-specific ones:

* ``hidden_shift`` — the Section-1.3 "hide inside the thresholds" adversary:
  a coordinated small bias of magnitude ≈ c·V that *passes* the A/B/∇
  checks; Lemmas 3.6/3.7 prove its damage is bounded — our tests verify the
  empirical loss inflation matches the O(αDV/√T) prediction.
* ``lower_bound`` — the Section-5 indistinguishability adversary: Byzantine
  workers faithfully simulate good workers of the *mirror* objective.

All attacks share the signature::

    attack(key, grads, byz_mask, ctx) -> grads'

where ``grads`` is (m, d) with rows of *good* gradients everywhere (the
simulator first computes honest gradients for every worker, then the attack
overwrites the Byzantine rows), ``byz_mask`` is (m,) bool, and ``ctx`` is a
dict of adversary knowledge: ``true_grad`` (d,), ``V``, ``step`` and
optionally ``mirror_grad``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def _overwrite(grads: jax.Array, byz_mask: jax.Array, rows: jax.Array) -> jax.Array:
    return jnp.where(byz_mask[:, None], rows, grads)


def attack_none(key, grads, byz_mask, ctx):
    """Byzantine workers behave honestly (sanity baseline)."""
    return grads


def attack_sign_flip(key, grads, byz_mask, ctx, scale: float = 3.0):
    """Classic reversed-gradient attack: send −scale · (own gradient)."""
    return _overwrite(grads, byz_mask, -scale * grads)


def attack_random_gaussian(key, grads, byz_mask, ctx, scale: float = 100.0):
    """Large iid Gaussian noise — crashes naive mean, trivially filtered."""
    noise = scale * jax.random.normal(key, grads.shape, grads.dtype)
    return _overwrite(grads, byz_mask, noise)


def attack_constant_drift(key, grads, byz_mask, ctx, scale: float = 10.0):
    """All Byzantine workers send the same constant vector (colluding pull
    toward a fixed wrong direction)."""
    d = grads.shape[1]
    direction = jnp.ones((d,), grads.dtype) / jnp.sqrt(d)
    return _overwrite(grads, byz_mask, scale * ctx["V"] * direction[None, :])


def attack_alie(key, grads, byz_mask, ctx, z: float = 1.0):
    """'A little is enough' (Baruch et al.): colluding workers send
    mean − z·std (coordinate-wise), staying within plausible deviation."""
    good = ~byz_mask
    w = good.astype(grads.dtype)[:, None]
    n_good = jnp.maximum(jnp.sum(w), 1.0)
    mu = jnp.sum(grads * w, axis=0) / n_good
    var = jnp.sum(w * (grads - mu[None, :]) ** 2, axis=0) / n_good
    row = mu - z * jnp.sqrt(var + 1e-12)
    return _overwrite(grads, byz_mask, row[None, :].repeat(grads.shape[0], 0))


def attack_inner_product(key, grads, byz_mask, ctx, scale: float = 1.0):
    """Omniscient negative-inner-product attack: push exactly against the
    true gradient, scaled to the top of the allowed deviation V."""
    g = ctx["true_grad"]
    gn = g / jnp.maximum(jnp.linalg.norm(g), 1e-12)
    row = g - (1.0 + scale) * ctx["V"] * gn
    return _overwrite(grads, byz_mask, row[None, :].repeat(grads.shape[0], 0))


def attack_hidden_shift(key, grads, byz_mask, ctx, c: float = 0.9):
    """The paper's 'hide inside the thresholds' adversary (Section 1.3):
    report (true gradient + c·V·u) for a fixed colluding unit direction u.
    Each row is a *valid-looking* stochastic gradient (deviation c·V ≤ V),
    its A/B martingales grow like an honest worker's, so the filter
    (correctly) cannot remove it; Lemma 3.6 bounds the damage instead."""
    d = grads.shape[1]
    u = jnp.ones((d,), grads.dtype) / jnp.sqrt(d)
    row = ctx["true_grad"] + c * ctx["V"] * u
    return _overwrite(grads, byz_mask, row[None, :].repeat(grads.shape[0], 0))


def attack_mirror(key, grads, byz_mask, ctx):
    """Section-5 lower-bound adversary: Byzantine workers behave as honest
    workers of the mirror objective (requires ctx['mirror_grads'])."""
    return _overwrite(grads, byz_mask, ctx["mirror_grads"])


ATTACKS: dict[str, Callable] = {
    "none": attack_none,
    "sign_flip": attack_sign_flip,
    "random_gaussian": attack_random_gaussian,
    "constant_drift": attack_constant_drift,
    "alie": attack_alie,
    "inner_product": attack_inner_product,
    "hidden_shift": attack_hidden_shift,
    "mirror": attack_mirror,
}


def get_attack(name: str) -> Callable:
    if name not in ATTACKS:
        raise KeyError(f"unknown attack {name!r}; have {sorted(ATTACKS)}")
    return ATTACKS[name]


def apply_attack(name: str, key, grads, byz_mask, ctx, **kwargs):
    return get_attack(name)(key, grads, byz_mask, ctx, **kwargs)
