"""Section-4 epoch solver for σ-strongly-convex objectives.

Repeatedly applies ByzantineSGD with halving radii: epoch p starts at
x^{(p−1)} with guarantee ‖x^{(p−1)} − x*‖ ≤ D_{p−1} and runs Theorem-3.8
SGD until f(x^{(p)}) − f(x*) ≤ σ D_p² / 2 (which implies the next radius
bound by strong convexity).  P = ⌈log₂ √(σD²/2ε)⌉ epochs reach ε.

T_p is chosen from the Theorem-3.8 upper bound (with its constants), times
a user ``t_scale`` — theory constants are intentionally conservative and the
benchmarks sweep t_scale to locate the empirical constant.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.solver import Problem, SolverConfig, run_sgd
from repro.utils import log_c


class EpochSolverConfig(NamedTuple):
    m: int
    alpha: float = 0.0
    epsilon: float = 1e-3
    aggregator: str = "byzantine_sgd"
    attack: str = "sign_flip"
    attack_kwargs: tuple = ()
    delta: float = 1e-3
    t_scale: float = 1.0        # scale on the theory iteration count
    max_t_per_epoch: int = 200_000


class EpochResult(NamedTuple):
    x: jax.Array
    total_iters: int
    epochs: int
    per_epoch_T: list
    per_epoch_gap: list


def theory_iterations(
    L: float, sigma: float, D: float, V: float, m: int, alpha: float,
    eps: float, delta: float, t_scale: float,
) -> int:
    """Smallest T making the Theorem-3.8 bound ≤ eps with η = 1/(2L),
    scaled by t_scale.  Solved by doubling search (the bound is monotone)."""
    eta = 1.0 / (2.0 * L)

    def bound(T: float) -> float:
        C = log_c(m, max(int(T), 1), delta)
        term_gd = D * D / (eta * T)
        term_stat = 8.0 * D * V * math.sqrt(C / (T * m))
        term_byz = 32.0 * alpha * D * V * math.sqrt(C / T)
        term_var = eta * (8.0 * V * V * C / m + 32.0 * alpha * alpha * V * V)
        return term_gd + term_stat + term_byz + term_var

    T = 1.0
    while bound(T) > eps and T < 1e12:
        T *= 2.0
    # halve-refine
    lo, hi = T / 2.0, T
    for _ in range(20):
        mid = 0.5 * (lo + hi)
        if bound(mid) > eps:
            lo = mid
        else:
            hi = mid
    return max(1, int(hi * t_scale))


def solve_strongly_convex(
    problem: Problem, cfg: EpochSolverConfig, key: jax.Array
) -> EpochResult:
    """The Section-4 reduction.  ``problem.sigma`` must be > 0."""
    assert problem.sigma > 0, "epoch solver requires strong convexity"
    sigma, D0 = problem.sigma, problem.D
    P = max(1, math.ceil(math.log2(math.sqrt(sigma * D0 * D0 / (2 * cfg.epsilon)))))

    x = problem.x1
    total, per_T, per_gap = 0, [], []
    for p in range(1, P + 1):
        D_prev = D0 * (2.0 ** -(p - 1))
        D_p = D0 * (2.0 ** -p)
        eps_p = sigma * D_p * D_p / 2.0
        T_p = min(
            theory_iterations(
                max(problem.L, problem.sigma), sigma, D_prev, problem.V,
                cfg.m, cfg.alpha, eps_p, cfg.delta, cfg.t_scale,
            ),
            cfg.max_t_per_epoch,
        )
        eta_p = 1.0 / (2.0 * max(problem.L, problem.sigma))
        sub = problem._replace(x1=x, D=D_prev)
        scfg = SolverConfig(
            m=cfg.m, T=T_p, eta=eta_p, alpha=cfg.alpha,
            aggregator=cfg.aggregator, attack=cfg.attack,
            attack_kwargs=cfg.attack_kwargs, delta=cfg.delta,
        )
        key, sub_key = jax.random.split(key)
        res = run_sgd(sub, scfg, sub_key)
        x = res.x_avg
        total += T_p
        per_T.append(T_p)
        per_gap.append(float(problem.f(x) - problem.f(problem.x_star)))
    return EpochResult(x=x, total_iters=total, epochs=P, per_epoch_T=per_T, per_epoch_gap=per_gap)
