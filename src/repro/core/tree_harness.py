"""Pytree ⇄ flat-harness adapter — one guard axis from vectors to models
(DESIGN.md §10).

The paper's guard is defined on worker gradient *vectors*; every backend in
:mod:`repro.core.guard_backends` therefore consumes the flat ``(m, d)``
stacked view, and the LM trainer historically kept its own parallel pytree
implementation of the same filter.  :class:`TreeHarness` collapses the two
stacks: it presents per-worker gradient *pytrees* (leaves with leading
worker axis ``W``) as the flat ``(W, d)`` matrix the backends, the attack
zoo, and the scenario adversaries already understand, and maps the filtered
mean ξ back into a parameter-shaped update.

Three properties make the adapter exact rather than approximate:

* **zero padding** — ``d`` is padded up to a lane multiple (default 128),
  which keeps Pallas block shapes and mesh shardings divisible; padded
  coordinates are identically zero in every row, so Gram matrices, norms,
  inner products — and therefore every filter decision — are unchanged;
* **fixed leaf order** — ravel/unravel use the template's flattened leaf
  order, so ``unravel(ravel(t)) == t`` bit-for-bit (round-trip property
  test in ``tests/test_tree_harness.py``);
* **dtype discipline** — ravelling promotes to the widest leaf float dtype
  (f32 for the reduced configs; bf16 survives when every leaf is bf16, so
  the ``low_precision_stats`` lever still means something), and unravel
  casts each slice back to its template leaf dtype.  ``ravel*`` accept a
  ``dtype`` override so the trainer can cast gradient trees *once at
  ravel* into ``SolverConfig.stats_dtype`` — natively-bf16 LM gradients
  reach a bf16-stats guard without an intermediate f32 copy.

:class:`FlatSpec` duck-types the ``problem`` argument of the guard-backend
factories (they read only ``d`` / ``V`` / ``D``), so
``make_aggregator(FlatSpec(harness.d, V, D), cfg)`` instantiates any
registered backend — or any stateless baseline — for the training path with
no trainer-specific wiring.

:class:`VectorModel` wraps a convex :class:`~repro.core.solver.Problem` in
the minimal LanguageModel surface ``build_train_step`` needs (``init`` +
``loss_fn``); it is how the flat-vs-pytree parity tests drive the *trainer*
with the *solver's* exact gradient stream.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

LANE = 128  # TPU lane width; default ravel padding multiple


class FlatSpec(NamedTuple):
    """The guard-backend factories' view of a problem: dimension and the
    Assumption-2.2 constants.  ``V = 0`` means "unknown — calibrate online"
    and is only meaningful for the auto-V-capable ``dp_*`` backends."""

    d: int
    V: float = 0.0
    D: float = 10.0


class TreeHarness:
    """Ravel/unravel between a parameter-shaped pytree and the flat ``(d,)``
    (or worker-stacked ``(W, d)``) view, with lane padding.

    Built once from a template tree (concrete arrays *or*
    ``ShapeDtypeStruct``s — only shapes/dtypes are read), then used inside
    jitted code: all metadata is static Python.
    """

    def __init__(self, template: PyTree, pad_to: int = LANE):
        leaves, self.treedef = jax.tree_util.tree_flatten(template)
        self.shapes = tuple(tuple(l.shape) for l in leaves)
        self.dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
        self.sizes = tuple(int(np.prod(s)) for s in self.shapes)
        self.d_raw = int(sum(self.sizes))
        pad_to = max(int(pad_to), 1)
        self.d = -(-self.d_raw // pad_to) * pad_to
        floats = [dt for dt in self.dtypes if jnp.issubdtype(dt, jnp.floating)]
        self.flat_dtype = jnp.result_type(*floats) if floats else jnp.dtype(jnp.float32)

    # -- tree → flat ---------------------------------------------------------

    def ravel(self, tree: PyTree, dtype: jnp.dtype | None = None) -> jax.Array:
        """(d,) flat view of a parameter-shaped tree (zero-padded).

        ``dtype`` overrides the promoted ``flat_dtype`` — the *cast-once-at-
        ravel* hook of the ``stats_dtype`` axis (DESIGN.md §5 Numerics): the
        trainer ravels gradient trees straight into the guard's statistics
        dtype, so natively-bf16 LM gradients never pay an f32 inflation
        pass just to be rounded back down by the guard."""
        leaves = jax.tree_util.tree_leaves(tree)
        dt = self.flat_dtype if dtype is None else dtype
        flat = jnp.concatenate([l.reshape(-1).astype(dt) for l in leaves])
        pad = self.d - self.d_raw
        return jnp.pad(flat, (0, pad)) if pad else flat

    def ravel_workers(self, tree: PyTree,
                      dtype: jnp.dtype | None = None) -> jax.Array:
        """(W, d) flat view of a worker-stacked tree (leaves lead with W);
        ``dtype`` as in :meth:`ravel`."""
        leaves = jax.tree_util.tree_leaves(tree)
        W = leaves[0].shape[0]
        dt = self.flat_dtype if dtype is None else dtype
        flat = jnp.concatenate(
            [l.reshape(W, -1).astype(dt) for l in leaves], axis=1
        )
        pad = self.d - self.d_raw
        return jnp.pad(flat, ((0, 0), (0, pad))) if pad else flat

    # -- flat → tree ---------------------------------------------------------

    def unravel(self, vec: jax.Array) -> PyTree:
        """Parameter-shaped tree from a (d,) flat vector (padding dropped,
        leaves cast back to their template dtypes)."""
        out, ofs = [], 0
        for shape, dtype, size in zip(self.shapes, self.dtypes, self.sizes):
            out.append(vec[ofs: ofs + size].reshape(shape).astype(dtype))
            ofs += size
        return jax.tree_util.tree_unflatten(self.treedef, out)


def params_harness(model, pad_to: int = LANE) -> TreeHarness:
    """Harness over a model's parameter tree, built shape-only (no init)."""
    abstract = jax.eval_shape(
        model.init, jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    return TreeHarness(abstract, pad_to=pad_to)


class VectorModel:
    """A convex :class:`~repro.core.solver.Problem` wearing the minimal
    model interface ``build_train_step`` consumes.

    Params are the single-leaf tree ``{"x": (d,)}`` (the iterate) and each
    per-worker batch carries a ``noise`` vector, so the per-worker gradient
    is exactly ``∇f(x) + noise`` — the solver's additive-noise stochastic
    gradient.  Feeding the *same* noise stream run_sgd's key chain would
    draw makes the trainer and the flat harness bit-comparable; that is the
    parity contract ``tests/test_tree_harness.py`` pins for the ``dense``,
    ``fused`` and ``dp_exact`` backends.
    """

    def __init__(self, problem):
        self.problem = problem

    def init(self, key: jax.Array) -> PyTree:
        del key  # the paper's x₁ is deterministic
        return {"x": self.problem.x1.astype(jnp.float32)}

    def loss_fn(self, params: PyTree, tb: dict):
        x = params["x"]
        # ⟨noise, x⟩ has gradient `noise`: grad(loss) = ∇f(x) + noise
        return self.problem.f(x) + jnp.vdot(tb["noise"][0], x), {}
