"""Guard-backend axis — one protocol, four guard realizations (DESIGN.md §9).

``run_sgd``'s ``byzantine_sgd`` branch historically hard-coded the dense
single-host :class:`~repro.core.byzantine_sgd.ByzantineGuard`, which meant
every campaign and every Table-1 sweep exercised only the three-pass
reference path: the fused Pallas pipeline (DESIGN.md §5) was tested at the
``ByzantineGuard.step`` level but never driven through the scan, and the
distributed ``exact``/``sketch`` guards of
:mod:`repro.distributed.byzantine_dp` could not be swept against the
Remark-2.3 adaptive adversaries at all.

This module closes that gap with a tiny functional protocol.  A **guard
backend** is a factory

    ``factory(problem, cfg, **opts) -> (state0, step)``

where ``step(state, grads, x, x1[, report]) -> (state', xi, n_alive, alive)``
consumes the flat ``(m, d)`` stacked worker gradients of the convex harness
and returns the paper's filtered mean ξ_k.  The optional ``report`` mask
((m,) bool, default ``None`` = everyone reports) is the partial-
participation axis of DESIGN.md §13: every backend zero-masks non-reporting
rows out of its streamed statistics and scores only reporters in the
filter.  ``state`` is an arbitrary pytree
(scan-carried, vmap-able), so any backend drops into the solver's
``lax.scan`` body and — because the campaign runner unrolls the backend axis
statically next to the aggregator axis — into a one-jit campaign grid.

Registered backends:

==========  ================================================================
``dense``   three-pass reference ``ByzantineGuard`` — the correctness oracle
            (DESIGN.md §1 rule: never deleted when a faster path lands)
``fused``   ``ByzantineGuard(use_fused=True)`` — the one-pass Pallas sweep +
            incremental Gram + fused filtered-mean (DESIGN.md §5)
``dp_exact``  the distributed exact-mode guard of ``byzantine_dp`` adapted
            to the flat harness: an ``(m, d)`` gradient array is already a
            valid one-leaf worker pytree, ``x``/``x1`` stand in for
            params/anchor.  Preserves the incremental-Gram/resync semantics
            (DESIGN.md §5) and, by default, the online auto-V calibration.
``dp_sketch`` the CountSketch guard on the same adaptation — B-state and
            cross-worker inner products in ``sketch_dim ≪ d`` dimensions,
            thresholds widened by ``sketch_slack``.
==========  ================================================================

Per-backend knobs ride ``SolverConfig.guard_opts`` (a hashable tuple of
``(key, value)`` pairs, same convention as ``attack_kwargs``): ``d_block`` /
``gram_resync_every`` for ``fused``; ``auto_v`` / ``sketch_dim`` /
``sketch_slack`` / ``incremental_gram`` / ``gram_resync_every`` /
``low_precision_stats`` / ``v_ema`` for the ``dp_*`` backends.  One
``guard_opts`` tuple configures a whole multi-backend sweep: each factory
receives only the knobs it declares (a ``sketch_dim`` does not crash the
``dense`` variant of the same campaign), while a knob *no* registered
backend declares raises ``KeyError`` — typos fail loudly, cross-backend
knobs drop silently by design.  ``dp_exact`` with ``auto_v=False`` must
match ``dense`` to float tolerance — that is the oracle contract
``tests/test_guard_backends.py`` pins end-to-end.

**Statistics precision** rides ``SolverConfig.stats_dtype`` (``'f32'`` |
``'bf16'``, DESIGN.md §5 Numerics) and is threaded through *every*
factory: dense/fused store the B martingale (and stream the fused
kernel's strips) in that dtype, and the ``dp_*`` backends map ``bf16``
onto their ``low_precision_stats`` contraction path plus bf16 B storage.
Campaign axes spell a combined (backend, precision) point as
``"<backend>@<dtype>"`` (e.g. ``"fused@bf16"``) — parsed by
:func:`parse_backend_spec`.
"""
from __future__ import annotations

import inspect
from typing import Callable

import jax.numpy as jnp

from repro.core.byzantine_sgd import ByzantineGuard, GuardConfig, resolve_stats_dtype
from repro.kernels.ops import default_d_block
from repro.obs.telemetry import guard_frame, telemetry_on

GuardBackendFactory = Callable  # (problem, cfg, *, telemetry, **opts) -> (state0, step)

_REGISTRY: dict[str, GuardBackendFactory] = {}


def register_guard_backend(name: str):
    """Decorator registering a backend factory under ``name``."""
    def deco(factory: GuardBackendFactory) -> GuardBackendFactory:
        _REGISTRY[name] = factory
        return factory
    return deco


def guard_backend_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def parse_backend_spec(spec: str) -> tuple[str, str | None]:
    """``"fused@bf16"`` → ``("fused", "bf16")``; ``"fused"`` → ``("fused",
    None)``.  The campaign/bench spelling for a (backend, stats-precision)
    point; a dtype suffix is validated, the backend name is validated by
    :func:`make_guard_backend` at instantiation."""
    name, sep, dt = spec.partition("@")
    if sep:
        resolve_stats_dtype(dt)  # loud KeyError on typos (incl. 'fused@')
        return name, dt
    return name, None


def _declared_opts(factory: GuardBackendFactory) -> set[str]:
    """Knob names a factory declares (everything past (problem, cfg);
    ``telemetry`` is the protocol's own axis, not a backend knob)."""
    sig = inspect.signature(factory)
    return {
        p.name for p in sig.parameters.values()
        if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
        and p.name not in ("problem", "cfg", "telemetry")
    }


def make_guard_backend(name: str, problem, cfg, telemetry=None):
    """Instantiate backend ``name`` for (problem, cfg) — the solver's entry.

    Returns ``(state0, step)`` with the step signature documented above.
    ``cfg.guard_opts`` keys the factory does not declare are dropped (so a
    single opts tuple serves every backend of a campaign sweep), but a key
    unknown to *every* registered backend is a ``KeyError``.

    ``telemetry`` (a :class:`repro.obs.TelemetryConfig`, DESIGN.md §12)
    switches the step into *probed* form: it returns a fifth element, the
    flight-recorder frame (per-worker martingale deviations vs thresholds,
    alive mask, auto-V, resync drift) on the shared
    ``repro.obs.telemetry.FRAME_SCHEMA`` — identical keys from every
    backend, NaN where a backend has nothing to report.  With telemetry
    off (the default) the step signature and trace are exactly the
    historical four-tuple.
    """
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown guard backend {name!r}; have {guard_backend_names()}"
        ) from None
    resolve_stats_dtype(cfg.stats_dtype)  # fail loudly before tracing
    opts = dict(cfg.guard_opts)
    known = set().union(*(_declared_opts(f) for f in _REGISTRY.values()))
    unknown = set(opts) - known
    if unknown:
        raise KeyError(
            f"unknown guard_opts {sorted(unknown)}; "
            f"known knobs: {sorted(known)}"
        )
    declared = _declared_opts(factory)
    return factory(problem, cfg, telemetry=telemetry,
                   **{k: v for k, v in opts.items() if k in declared})


# ---------------------------------------------------------------------------
# dense / fused — the single-host ByzantineGuard pair
# ---------------------------------------------------------------------------

def _guard_config(problem, cfg) -> GuardConfig:
    return GuardConfig(
        m=cfg.m, T=cfg.T, V=problem.V, D=problem.D, delta=cfg.delta,
        threshold_mode=cfg.threshold_mode, mean_over_alive=cfg.mean_over_alive,
    )


def _wrap_byzantine_guard(guard: ByzantineGuard, d: int, telemetry=None):
    state0 = guard.init(d)
    probe = telemetry_on(telemetry)
    m = guard.cfg.m

    def step(state, grads, x, x1, report=None):
        state, xi, diag = guard.step(state, grads, x, x1, report)
        if not probe:
            return state, xi, diag["n_alive"], state.alive
        return (state, xi, diag["n_alive"], state.alive,
                guard_frame(m, diag, state.alive))

    return state0, step


@register_guard_backend("dense")
def _dense_backend(problem, cfg, telemetry=None):
    # three-pass reference; gram_B is re-derived from the stored B every
    # step, which is what makes dense the drift oracle at either stats
    # dtype (per-step re-derivation = gram_resync_every-style resync
    # taken to its limit)
    guard = ByzantineGuard(_guard_config(problem, cfg),
                           stats_dtype=cfg.stats_dtype,
                           sanitize=cfg.sanitize == "quarantine")
    return _wrap_byzantine_guard(guard, problem.d, telemetry)


def _wrap_gen_guard(guard: ByzantineGuard, d: int, telemetry=None):
    """Generating-step wrapper (DESIGN.md §14): same shape as
    :func:`_wrap_byzantine_guard` but the step consumes a
    :class:`~repro.kernels.gradgen.GenStepCtx` instead of a materialized
    (m, d) batch, and returns the adversary's feedback row-sum as a fifth
    element (sixth is the probe frame)."""
    state0 = guard.init(d)
    probe = telemetry_on(telemetry)
    m = guard.cfg.m

    def step(state, genctx, x, x1, report=None):
        # report must be None by the solver's gen gate (partial
        # participation needs the materialized batch)
        state, xi, byz_sum, diag = guard.gen_step(state, genctx, x, x1)
        if not probe:
            return state, xi, diag["n_alive"], state.alive, byz_sum
        return (state, xi, diag["n_alive"], state.alive, byz_sum,
                guard_frame(m, diag, state.alive))

    return state0, step


@register_guard_backend("fused")
def _fused_backend(problem, cfg, telemetry=None, d_block: int | None = None,
                   gram_resync_every: int = 64):
    gen_on = getattr(cfg, "generate", "off") == "kernel"
    guard = ByzantineGuard(
        _guard_config(problem, cfg),
        use_fused=True,
        d_block=d_block if d_block is not None else default_d_block(problem.d),
        gram_resync_every=gram_resync_every,
        stats_dtype=cfg.stats_dtype,
        gen_spec=problem.gen if gen_on else None,
        sanitize=cfg.sanitize == "quarantine",
    )
    if gen_on:
        # generate="kernel" is NOT a separate registry entry: registered
        # backends share the grads-consuming step contract (and the
        # conformance suite calls every name with it) — the generating
        # step's different signature rides the fused factory behind the
        # SolverConfig gate instead
        return _wrap_gen_guard(guard, problem.d, telemetry)
    return _wrap_byzantine_guard(guard, problem.d, telemetry)


# ---------------------------------------------------------------------------
# dp_exact / dp_sketch — the distributed guard on the flat harness
# ---------------------------------------------------------------------------

def _dp_backend(problem, cfg, mode: str, *, telemetry=None,
                auto_v: bool = True,
                sketch_dim: int = 4096, sketch_slack: float = 1.5,
                incremental_gram: bool = True, gram_resync_every: int = 64,
                low_precision_stats: bool = False, v_ema: float = 0.9):
    # imported here so the core layer has no import-time dependency on the
    # distributed layer for users that never select a dp backend
    from repro.distributed.byzantine_dp import (
        DPGuardConfig,
        guard_step,
        init_guard_state,
    )

    # stats_dtype='bf16' implies the low-precision contraction path (native
    # dtype operands, f32 accumulation) on top of bf16 B storage — the two
    # knobs named the same thing before this axis existed, so the legacy
    # guard_opt stays as an alias
    dcfg = DPGuardConfig(
        n_workers=cfg.m, T=cfg.T, V=problem.V, D=problem.D, delta=cfg.delta,
        mode=mode, threshold_mode=cfg.threshold_mode,
        mean_over_alive=cfg.mean_over_alive, auto_v=auto_v,
        sketch_dim=sketch_dim, sketch_slack=sketch_slack,
        incremental_gram=incremental_gram,
        gram_resync_every=gram_resync_every,
        low_precision_stats=low_precision_stats or cfg.stats_dtype == "bf16",
        v_ema=v_ema,
        stats_dtype=cfg.stats_dtype,
    )
    # flat harness: the "model" is the iterate itself, so params_like is a
    # single (d,) leaf and the stacked (m, d) gradients are a one-leaf
    # worker pytree — worker_vdot/worker_pair_gram consume them unchanged
    state0 = init_guard_state(dcfg, jnp.zeros((problem.d,), jnp.float32))
    probe = telemetry_on(telemetry)
    san = cfg.sanitize == "quarantine"

    def step(state, grads, x, x1, report=None):
        if san:
            # host-side sanitize stage (DESIGN.md §15) — the dp guard's
            # einsum/sketch contractions are shared with the pytree mesh
            # path, so the quarantine wraps the step instead of forking
            # them: zero non-finite entries out of every streamed
            # statistic, score poisoned rows as non-reporting (the
            # pass-through keeps their filter state), then close the
            # pass-through by killing them in the carried alive mask.
            fin = jnp.isfinite(grads)
            finite = jnp.all(fin, axis=1)
            grads = jnp.where(fin, grads, jnp.zeros((), grads.dtype))
            report = finite if report is None else report & finite
        state, xi, diag = guard_step(dcfg, state, grads, x, x1, report)
        if san:
            state = state._replace(alive=state.alive & finite)
            diag["n_alive"] = jnp.sum(state.alive)
            diag["n_nonfinite"] = jnp.sum(~finite)
        # ξ is an f32 accumulator output on the flat harness (the dense/
        # fused convention; the solver's scan carries f32 feedback) — the
        # pytree mesh path keeps gradient-dtype ξ, but here the low-
        # precision einsum's grads-dtype result casts back up
        if not probe:
            return state, xi.astype(jnp.float32), diag["n_alive"], state.alive
        return (state, xi.astype(jnp.float32), diag["n_alive"], state.alive,
                guard_frame(cfg.m, diag, state.alive))

    return state0, step


@register_guard_backend("dp_exact")
def _dp_exact_backend(problem, cfg, telemetry=None, **opts):
    return _dp_backend(problem, cfg, "exact", telemetry=telemetry, **opts)


@register_guard_backend("dp_sketch")
def _dp_sketch_backend(problem, cfg, telemetry=None, **opts):
    return _dp_backend(problem, cfg, "sketch", telemetry=telemetry, **opts)


# the dp wrappers forward **opts to _dp_backend, whose signature is the
# real knob declaration — advertise it for the opts filter
_dp_exact_backend.__signature__ = _dp_sketch_backend.__signature__ = (
    inspect.Signature(
        [p for p in inspect.signature(_dp_backend).parameters.values()
         if p.name != "mode"]
    )
)
