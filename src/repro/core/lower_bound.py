"""Section-5 lower bounds, realized as executable distinguishing experiments.

Theorem 5.4 (linear / non-strongly-convex) and Theorem 5.5 (strongly convex)
reduce ε-optimization to distinguishing two sample distributions that differ
by O(α) in mean — information-theoretically impossible for small T (Lemma
5.3).  We *simulate the reduction*: Byzantine workers are honest workers of
the mirror objective; if T ≪ α²V²D²/ε² no algorithm (ours included) can tell
which objective generated the data, so its success probability over random
cases must hover near 1/2; for T ≫ threshold ByzantineSGD's success → 1.

The benchmark sweeps T through the predicted threshold and plots the
empirical success curve — this is the paper's "lower bound table" made
observable.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.byzantine_sgd import ByzantineGuard, GuardConfig


class LowerBoundResult(NamedTuple):
    success_rate: jax.Array     # fraction of trials where the case was identified
    threshold_T: float          # the theory threshold α²V²D²/ε² (or SC analogue)


def _run_one_dim_byzantine_sgd(
    grads_per_iter: jax.Array,   # (T, m) — scalar gradient sent by worker i at iter k
    D: float, V: float, eta: float, delta: float,
) -> jax.Array:
    """Run ByzantineSGD on a 1-D problem where worker messages are fixed
    upfront (they do not depend on x for the hard instances: linear case is
    x-independent; SC case handled by caller via closure). Returns x̄."""
    T, m = grads_per_iter.shape
    guard = ByzantineGuard(GuardConfig(m=m, T=T, V=V, D=D, delta=delta))
    state0 = guard.init(1)
    x1 = jnp.zeros((1,), jnp.float32)

    def body(carry, g_row):
        x, state, x_sum = carry
        grads = g_row[:, None].astype(jnp.float32)   # (m, 1)
        state, xi, _ = guard.step(state, grads, x, x1)
        x_new = x - eta * xi
        x_new = jnp.clip(x_new, -D, D)
        return (x_new, state, x_sum + x_new), None

    (x, _, x_sum), _ = jax.lax.scan(body, (x1, state0, jnp.zeros_like(x1)), grads_per_iter)
    return (x_sum / T)[0]


@functools.partial(
    jax.jit,
    static_argnames=("m", "T", "n_trials", "alpha", "D", "V", "eps", "eta", "delta"),
)
def _linear_trials(key, m: int, T: int, n_trials: int, alpha, D, V, eps, eta, delta):
    n_byz = jnp.floor(alpha * m).astype(jnp.int32)

    def one_trial(tk):
        ck, sk, mk = jax.random.split(tk, 3)
        case = jax.random.bernoulli(ck)                 # True → f_+, False → f_−
        mu = jnp.where(case, eps / (D * V), -eps / (D * V))
        # honest sample s ~ N(±mu, 1); gradient is s·V  (f_s = sVx)
        s = jax.random.normal(sk, (T, m)) + mu          # honest draws for case
        s_mirror = s - 2.0 * mu                         # same noise, mirror mean
        byz = jnp.arange(m) < n_byz                     # Lemma 5.3's random S — WLOG a prefix,
        perm = jax.random.permutation(mk, m)            # then permuted
        byz = byz[perm]
        samples = jnp.where(byz[None, :], s_mirror, s)
        xbar = _run_one_dim_byzantine_sgd(samples * V, D, V, eta, delta)
        guess_plus = xbar < 0.0                          # f_+ minimized at −D
        return guess_plus == case

    keys = jax.random.split(key, n_trials)
    wins = jax.vmap(one_trial)(keys)
    return jnp.mean(wins.astype(jnp.float32))


def distinguishing_experiment_linear(
    key: jax.Array, m: int = 16, T: int = 256, n_trials: int = 32,
    alpha: float = 0.25, D: float = 1.0, V: float = 1.0, eps: float = 0.05,
    eta: float | None = None, delta: float = 1e-3,
) -> LowerBoundResult:
    """Theorem 5.4 experiment (linear objective f_±(x) = ±εx/D on [−D, D])."""
    if eta is None:
        eta = D / (V * (T ** 0.5))
    rate = _linear_trials(key, m, T, n_trials, alpha, D, V, eps, eta, delta)
    threshold = (alpha ** 2) * (V ** 2) * (D ** 2) / (eps ** 2)
    return LowerBoundResult(success_rate=rate, threshold_T=threshold)


@functools.partial(
    jax.jit,
    static_argnames=("m", "T", "n_trials", "alpha", "sigma", "V", "eps_hat", "eta", "delta"),
)
def _sc_trials(key, m: int, T: int, n_trials: int, alpha, sigma, V, eps_hat, eta, delta):
    n_byz = jnp.floor(alpha * m).astype(jnp.int32)
    D = 10.0 * eps_hat  # domain radius; x* = ±ε̂ is well inside

    def one_trial(tk):
        ck, sk, mk = jax.random.split(tk, 3)
        case = jax.random.bernoulli(ck)                 # True → x* = +ε̂
        mu = jnp.where(case, eps_hat, -eps_hat)
        s = mu + (V / sigma) * jax.random.normal(sk, (T, m))
        s_mirror = s - 2.0 * mu
        byz = jnp.arange(m) < n_byz
        perm = jax.random.permutation(mk, m)
        byz = byz[perm]
        samples = jnp.where(byz[None, :], s_mirror, s)

        # f_s(x) = σ/2 (x−s)² → ∇f_s(x) = σ(x−s); depends on x, so run the
        # guard inline with gradients formed at the current iterate.
        guard = ByzantineGuard(GuardConfig(m=m, T=T, V=V, D=D, delta=delta))
        state0 = guard.init(1)
        x1 = jnp.zeros((1,), jnp.float32)

        def body(carry, srow):
            x, state, x_sum = carry
            grads = (sigma * (x[0] - srow))[:, None]
            state, xi, _ = guard.step(state, grads, x, x1)
            x_new = jnp.clip(x - eta * xi, -D, D)
            return (x_new, state, x_sum + x_new), None

        (x, _, x_sum), _ = jax.lax.scan(body, (x1, state0, jnp.zeros_like(x1)), samples)
        xbar = (x_sum / T)[0]
        return (xbar > 0.0) == case                      # x* sign identifies the case

    keys = jax.random.split(key, n_trials)
    wins = jax.vmap(one_trial)(keys)
    return jnp.mean(wins.astype(jnp.float32))


def distinguishing_experiment_strongly_convex(
    key: jax.Array, m: int = 16, T: int = 256, n_trials: int = 32,
    alpha: float = 0.25, sigma: float = 1.0, V: float = 1.0,
    eps_hat: float = 0.05, eta: float | None = None, delta: float = 1e-3,
) -> LowerBoundResult:
    """Theorem 5.5 experiment (f_±(x) = σ/2 (x ∓ ε̂)²)."""
    if eta is None:
        eta = 1.0 / (2.0 * sigma)
    rate = _sc_trials(key, m, T, n_trials, alpha, sigma, V, eps_hat, eta, delta)
    threshold = (alpha ** 2) * (V ** 2) / (sigma ** 2 * eps_hat ** 2)
    return LowerBoundResult(success_rate=rate, threshold_T=threshold)
