"""Baseline gradient-aggregation rules the paper compares against (Table 1,
Section 1.4), plus the aggregators of the empirical Byzantine-robustness
literature the leaderboard is benchmarked against (DESIGN.md §11).

Two kinds of rule live here:

* **stateless** — ``agg(grads: (m, d)) -> (d,)``, registered in
  :data:`AGGREGATORS` and resolved by :func:`get_aggregator`;
* **stateful** — cross-step memory (e.g. centered clipping's carried
  center), registered in :data:`STATEFUL_AGGREGATORS` as factories
  ``factory(d, **knobs) -> (state0, step)`` with
  ``step(state, grads) -> (state', xi)``.  The solver's
  :func:`repro.core.solver.make_aggregator` carries the state through its
  scan exactly as it does the ByzantineSGD guard's martingales, so stateful
  baselines drop into campaigns, the LM trainer, and the sharding specs
  with no extra wiring.

ByzantineSGD itself (Algorithm 1) stays in :mod:`repro.core.byzantine_sgd`
behind the guard-backend registry (DESIGN.md §9).

References:
  * coordinate-wise median / trimmed mean — Yin et al., "Byzantine-robust
    distributed learning: towards optimal statistical rates" (Median-GD in
    Table 1 of our paper).
  * Krum — Blanchard et al., NeurIPS'17 [ref 8].
  * geometric median (of means) — Chen, Su, Xu [ref 11]; Weiszfeld iteration.
  * medoid — minimum-total-distance point, the cheap geometric-median proxy.
  * AutoGM — Li et al., "Auto-weighted robust federated learning with
    corrupted data sources" (IEEE IoT J. 2022): geometric median with
    simplex-constrained per-worker weights, alternating minimization.
  * centered clipping — Karimireddy, He & Jaggi, "Learning from history
    for Byzantine-robust optimization" (ICML 2021).
  * bucketing — Karimireddy, He & Jaggi, "Byzantine-robust learning on
    heterogeneous datasets via bucketing" (ICLR 2022); composed with any
    base rule via :func:`repro.core.solver.make_aggregator`'s
    ``bucket<s>:<base>`` spelling.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.byzantine_sgd import pairwise_sq_dists_from_gram
from repro.kernels import ops


def aggregate_mean(grads: jax.Array) -> jax.Array:
    """Plain mini-batch mean — the α = 0 baseline; not Byzantine-robust."""
    return jnp.mean(grads, axis=0)


def aggregate_coordinate_median(grads: jax.Array) -> jax.Array:
    """Coordinate-wise median (Yin et al.'s Median-GD aggregation)."""
    return jnp.median(grads, axis=0)


def aggregate_trimmed_mean(grads: jax.Array, trim_fraction: float = 0.1) -> jax.Array:
    """Coordinate-wise β-trimmed mean: drop the β·m largest and smallest
    entries per coordinate, average the rest (Yin et al., trimmed-mean-GD).
    The epsilon keeps an exactly-integral β·m from flooring one short under
    f32/f64 division (0.3 · 10 → 2.999…), so ceil-convention fractions
    (``ceil_byzantine_count(α, m) / m``) trim the intended count."""
    m = grads.shape[0]
    b = int(trim_fraction * m + 1e-9)
    if 2 * b >= m:
        raise ValueError(f"trim_fraction {trim_fraction} trims everything for m={m}")
    s = jnp.sort(grads, axis=0)
    if b == 0:
        return jnp.mean(s, axis=0)
    return jnp.mean(s[b : m - b], axis=0)


def _pairwise_sq_dists(grads: jax.Array) -> jax.Array:
    # Gram through the tiled pairdist kernel (one MXU matmul per streamed
    # strip, DESIGN.md §4) instead of re-forming the dense distance work
    # inline — Krum/medoid share the guard's hot-spot kernel, so its
    # O(m²d) Table-1 cost rides the same strip layout (and bf16 inputs
    # stream at half the bytes, like every other kernel consumer)
    return pairwise_sq_dists_from_gram(
        ops.gram(grads, d_block=ops.default_d_block(grads.shape[1]))
    )


def aggregate_krum(grads: jax.Array, n_byzantine: int, multi_k: int = 1) -> jax.Array:
    """(Multi-)Krum [Blanchard et al. 2017].

    Score(i) = sum of squared distances to i's m − f − 2 nearest neighbours
    (f = n_byzantine); select the multi_k lowest-scoring gradients and
    average them.  Local complexity O(m²(d + log m)) — the cost the paper
    criticizes in Section 1.4; our benchmark table measures it.
    """
    m = grads.shape[0]
    n_neighbors = max(m - n_byzantine - 2, 1)
    d2 = _pairwise_sq_dists(grads)
    d2 = d2.at[jnp.arange(m), jnp.arange(m)].set(jnp.inf)  # exclude self
    nearest = jnp.sort(d2, axis=1)[:, :n_neighbors]
    scores = jnp.sum(nearest, axis=1)
    if multi_k == 1:
        return grads[jnp.argmin(scores)]
    _, idx = jax.lax.top_k(-scores, multi_k)
    return jnp.mean(grads[idx], axis=0)


def aggregate_medoid(grads: jax.Array) -> jax.Array:
    """The gradient minimizing total distance to all others."""
    d2 = _pairwise_sq_dists(grads)
    scores = jnp.sum(jnp.sqrt(d2), axis=1)
    return grads[jnp.argmin(scores)]


def weiszfeld_update(
    y: jax.Array, g: jax.Array, alphas: jax.Array | None = None,
    tol: float = 1e-6,
) -> jax.Array:
    """One *smoothed* (optionally weighted) Weiszfeld step.

    The classic iteration divides by the distance to every data row, so an
    iterate landing *exactly on a row* — degenerate all-identical inputs,
    colluding attacks that send duplicated rows — is a 1/0 that jit happily
    folds into NaN.  The textbook coincident-point *exclusion* (weight 0
    within a radius) is NaN-free but discontinuous: when the dominant-weight
    row is excluded the iterate teleports to the weighted median of the
    *rest*, and under f32 the teleport fires on one summation order but not
    another — breaking the permutation invariance the conformance suite
    enforces.  We instead smooth the weights (Pillutla et al.'s RFA
    iteration): ``w = a / max(dist, tol)``, which is continuous, keeps every
    iterate a convex combination of rows, and turns a coincident row into a
    strong finite pull rather than a hole.  The remaining ``denom`` guard
    only fires when every weight is zero (all-zero ``alphas``)."""
    dist = jnp.linalg.norm(g - y[None, :], axis=1)
    a = jnp.ones(g.shape[:1], g.dtype) if alphas is None else alphas
    w = a / jnp.maximum(dist, tol)
    denom = jnp.sum(w)
    y_new = (w @ g) / jnp.maximum(denom, 1e-30)
    return jnp.where(denom > 0, y_new, y)


def aggregate_geometric_median(
    grads: jax.Array, n_iters: int = 8, eps: float = 1e-6
) -> jax.Array:
    """Geometric median via smoothed Weiszfeld iterations, warm-started at
    the mean (inside the convex hull but generically *not* on a data row —
    the smoothed weights pin an iterate that starts on a dominant row);
    ``eps`` is the distance floor of :func:`weiszfeld_update`, the guard
    against the Weiszfeld singularity at data points."""
    g32 = grads.astype(jnp.float32)
    y0 = jnp.mean(g32, axis=0)

    def body(y, _):
        return weiszfeld_update(y, g32, tol=eps), None

    y, _ = jax.lax.scan(body, y0, None, length=n_iters)
    return y.astype(grads.dtype)


def simplex_project(y: jax.Array) -> jax.Array:
    """Euclidean projection onto the probability simplex (Duchi et al. 2008)
    — sort + cumsum + threshold, fully jittable."""
    n = y.shape[0]
    u = jnp.sort(y)[::-1]
    css = jnp.cumsum(u)
    j = jnp.arange(1, n + 1, dtype=y.dtype)
    rho = jnp.max(jnp.where(u + (1.0 - css) / j > 0, j, 1.0))
    tau = (jnp.take(css, rho.astype(jnp.int32) - 1) - 1.0) / rho
    return jnp.maximum(y - tau, 0.0)


def aggregate_autogm(
    grads: jax.Array, lamb: float = 2.0, n_outer: int = 4, n_inner: int = 8,
    eps: float = 1e-6,
) -> jax.Array:
    """AutoGM — auto-weighted geometric median (Li et al., IoT J. 2022).

    Alternating minimization of the jointly-robust objective

        min_{v, α ∈ Δ}  Σ_i α_i ‖x_i − v‖  +  λ ‖α‖²

    as a *fixed-iteration* jittable schedule (no data-dependent stopping —
    the campaign engine vmaps this inside one trace): the v-step is
    ``n_inner`` α-weighted Weiszfeld iterations, the α-step is the closed
    form α = proj_Δ(−d / 2λ), which zeroes the weight of rows whose
    distance to the current center exceeds the water-filling threshold —
    outliers are *removed* from the median, not merely down-weighted, which
    is what separates AutoGM from the plain geometric median at high attack
    magnitude.  λ interpolates the family: λ → ∞ recovers the uniform-weight
    geometric median, λ → 0 collapses onto the single nearest row.

    Warm start at the mean keeps every iterate inside the convex hull of
    the rows without starting *on* one (the smoothed Weiszfeld weights of
    :func:`weiszfeld_update` pin an iterate that begins at a dominant data
    row); the same smoothing keeps the degenerate cases (duplicated rows,
    all-identical input) NaN-free.
    """
    g32 = grads.astype(jnp.float32)
    m = g32.shape[0]

    def v_steps(v, alphas):
        def body(y, _):
            return weiszfeld_update(y, g32, alphas, tol=eps), None
        v, _ = jax.lax.scan(body, v, None, length=n_inner)
        return v

    def outer(carry, _):
        v, alphas = carry
        v = v_steps(v, alphas)
        dist = jnp.linalg.norm(g32 - v[None, :], axis=1)
        alphas = simplex_project(-dist / (2.0 * lamb))
        return (v, alphas), None

    v0 = jnp.mean(g32, axis=0)
    a0 = jnp.full((m,), 1.0 / m, jnp.float32)
    (v, alphas), _ = jax.lax.scan(outer, (v0, a0), None, length=n_outer)
    v = v_steps(v, alphas)  # final v-step under the converged weights
    return v.astype(grads.dtype)


AGGREGATORS: dict[str, Callable] = {
    "mean": aggregate_mean,
    "coordinate_median": aggregate_coordinate_median,
    "trimmed_mean": aggregate_trimmed_mean,
    "krum": aggregate_krum,
    "multi_krum": functools.partial(aggregate_krum, multi_k=4),
    "medoid": aggregate_medoid,
    "geometric_median": aggregate_geometric_median,
    "autogm": aggregate_autogm,
}


def get_aggregator(name: str, **kwargs) -> Callable[[jax.Array], jax.Array]:
    """Resolve a stateless aggregator by name with bound hyper-parameters.

    ``krum``/``multi_krum`` require ``n_byzantine``; ``trimmed_mean`` takes
    ``trim_fraction``. ``byzantine_sgd`` (guard backends) and the
    :data:`STATEFUL_AGGREGATORS` are stateful — the solver's
    :func:`repro.core.solver.make_aggregator` handles all three kinds.
    """
    if name not in AGGREGATORS:
        raise KeyError(f"unknown aggregator {name!r}; have {sorted(AGGREGATORS)}")
    fn = AGGREGATORS[name]
    return functools.partial(fn, **kwargs) if kwargs else fn


# ---------------------------------------------------------------------------
# stateful aggregators — cross-step memory outside the ByzantineSGD guard.
# factory(d, **knobs) -> (state0, step); step(state, grads) -> (state', xi).
# The state is an arbitrary pytree: scan-carried by the solver, checkpointed
# by the trainer (TrainState.guard), sharded by distributed/specs.py.
# ---------------------------------------------------------------------------

def make_centered_clip(
    d: int, clip_tau: float = 10.0, clip_iters: int = 5,
) -> tuple[jax.Array, Callable]:
    """Centered clipping (Karimireddy, He & Jaggi 2021).

    Iterative clipping around a *carried* center v (the previous step's
    aggregate — the "learning from history" momentum that defeats
    time-coupled attacks like ALIE):

        v ← v + (1/m) Σ_i clip(x_i − v, τ),   clip(z, τ) = z · min(1, τ/‖z‖)

    repeated ``clip_iters`` times per aggregation.  Each Byzantine row moves
    the center by at most τ/m per inner iteration regardless of magnitude,
    so unbounded attacks are clipped to bounded influence while honest rows
    inside the τ-ball pass unclipped.  v₀ = 0; robustness holds for any
    bounded initialization (ibid., Thm. III) and the first few steps walk v
    into the honest cluster at ≤ τ·clip_iters per step.
    """
    state0 = jnp.zeros((d,), jnp.float32)

    def step(v: jax.Array, grads: jax.Array) -> tuple[jax.Array, jax.Array]:
        g32 = grads.astype(jnp.float32)

        def body(c, _):
            diff = g32 - c[None, :]
            nrm = jnp.linalg.norm(diff, axis=1)
            lam = jnp.minimum(1.0, clip_tau / jnp.maximum(nrm, 1e-12))
            return c + jnp.mean(lam[:, None] * diff, axis=0), None

        v_new, _ = jax.lax.scan(body, v, None, length=clip_iters)
        return v_new, v_new

    return state0, step


STATEFUL_AGGREGATORS: dict[str, Callable] = {
    "centered_clip": make_centered_clip,
}


def aggregator_names() -> tuple[str, ...]:
    """Every registered baseline aggregator, stateless and stateful — the
    roster the conformance suite (tests/test_aggregator_contracts.py)
    enforces invariants over."""
    return tuple(sorted(AGGREGATORS)) + tuple(sorted(STATEFUL_AGGREGATORS))


# ---------------------------------------------------------------------------
# bucketing — s-bucket pre-averaging, composable with any base rule
# ---------------------------------------------------------------------------

def bucket_means(grads: jax.Array, s: int, key: jax.Array) -> jax.Array:
    """(m, d) → (m/s, d): randomly permute worker rows, average disjoint
    groups of ``s`` (Karimireddy, He & Jaggi 2022).  Pre-averaging dilutes
    each Byzantine row into a bucket of mostly-honest ones and shrinks the
    honest variance by s, at the price of up to ⌈αm⌉ *contaminated* buckets
    — an s·α effective fraction the base aggregator must be sized for
    (:func:`repro.core.solver.make_aggregator` resizes Krum's f and the
    trim fraction accordingly)."""
    m = grads.shape[0]
    if m % s:
        raise ValueError(f"bucketing needs s | m, got s={s}, m={m}")
    perm = jax.random.permutation(key, m)
    return jnp.mean(grads[perm].reshape(m // s, s, -1), axis=1)
