"""Baseline gradient-aggregation rules the paper compares against (Table 1,
Section 1.4), plus plain mean.

All rules share the signature ``agg(grads: (m, d)) -> (d,)`` (stateless) so
they can be swapped into both the convex solver and the distributed trainer.
ByzantineSGD itself is *stateful* (cross-iteration martingales) and lives in
:mod:`repro.core.byzantine_sgd`; :func:`get_aggregator` wraps it behind the
same interface via a closure over its state.

References:
  * coordinate-wise median / trimmed mean — Yin et al., "Byzantine-robust
    distributed learning: towards optimal statistical rates" (Median-GD in
    Table 1 of our paper).
  * Krum — Blanchard et al., NeurIPS'17 [ref 8].
  * geometric median (of means) — Chen, Su, Xu [ref 11]; Weiszfeld iteration.
  * medoid — minimum-total-distance point, the cheap geometric-median proxy.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.byzantine_sgd import pairwise_sq_dists_from_gram
from repro.kernels import ops


def aggregate_mean(grads: jax.Array) -> jax.Array:
    """Plain mini-batch mean — the α = 0 baseline; not Byzantine-robust."""
    return jnp.mean(grads, axis=0)


def aggregate_coordinate_median(grads: jax.Array) -> jax.Array:
    """Coordinate-wise median (Yin et al.'s Median-GD aggregation)."""
    return jnp.median(grads, axis=0)


def aggregate_trimmed_mean(grads: jax.Array, trim_fraction: float = 0.1) -> jax.Array:
    """Coordinate-wise β-trimmed mean: drop the β·m largest and smallest
    entries per coordinate, average the rest (Yin et al., trimmed-mean-GD).
    The epsilon keeps an exactly-integral β·m from flooring one short under
    f32/f64 division (0.3 · 10 → 2.999…), so ceil-convention fractions
    (``ceil_byzantine_count(α, m) / m``) trim the intended count."""
    m = grads.shape[0]
    b = int(trim_fraction * m + 1e-9)
    if 2 * b >= m:
        raise ValueError(f"trim_fraction {trim_fraction} trims everything for m={m}")
    s = jnp.sort(grads, axis=0)
    if b == 0:
        return jnp.mean(s, axis=0)
    return jnp.mean(s[b : m - b], axis=0)


def _pairwise_sq_dists(grads: jax.Array) -> jax.Array:
    # Gram through the tiled pairdist kernel (one MXU matmul per streamed
    # strip, DESIGN.md §4) instead of re-forming the dense distance work
    # inline — Krum/medoid share the guard's hot-spot kernel, so its
    # O(m²d) Table-1 cost rides the same strip layout (and bf16 inputs
    # stream at half the bytes, like every other kernel consumer)
    return pairwise_sq_dists_from_gram(
        ops.gram(grads, d_block=ops.default_d_block(grads.shape[1]))
    )


def aggregate_krum(grads: jax.Array, n_byzantine: int, multi_k: int = 1) -> jax.Array:
    """(Multi-)Krum [Blanchard et al. 2017].

    Score(i) = sum of squared distances to i's m − f − 2 nearest neighbours
    (f = n_byzantine); select the multi_k lowest-scoring gradients and
    average them.  Local complexity O(m²(d + log m)) — the cost the paper
    criticizes in Section 1.4; our benchmark table measures it.
    """
    m = grads.shape[0]
    n_neighbors = max(m - n_byzantine - 2, 1)
    d2 = _pairwise_sq_dists(grads)
    d2 = d2.at[jnp.arange(m), jnp.arange(m)].set(jnp.inf)  # exclude self
    nearest = jnp.sort(d2, axis=1)[:, :n_neighbors]
    scores = jnp.sum(nearest, axis=1)
    if multi_k == 1:
        return grads[jnp.argmin(scores)]
    _, idx = jax.lax.top_k(-scores, multi_k)
    return jnp.mean(grads[idx], axis=0)


def aggregate_medoid(grads: jax.Array) -> jax.Array:
    """The gradient minimizing total distance to all others."""
    d2 = _pairwise_sq_dists(grads)
    scores = jnp.sum(jnp.sqrt(d2), axis=1)
    return grads[jnp.argmin(scores)]


def aggregate_geometric_median(
    grads: jax.Array, n_iters: int = 8, eps: float = 1e-8
) -> jax.Array:
    """Geometric median via Weiszfeld iterations, warm-started at the medoid
    (guarantees we start within the convex hull and avoids the classic
    Weiszfeld singularity at data points via eps-smoothing)."""
    g32 = grads.astype(jnp.float32)
    y0 = aggregate_medoid(g32)

    def body(y, _):
        dist = jnp.sqrt(jnp.sum((g32 - y[None, :]) ** 2, axis=1) + eps)
        w = 1.0 / dist
        y_new = (w @ g32) / jnp.sum(w)
        return y_new, None

    y, _ = jax.lax.scan(body, y0, None, length=n_iters)
    return y.astype(grads.dtype)


AGGREGATORS: dict[str, Callable] = {
    "mean": aggregate_mean,
    "coordinate_median": aggregate_coordinate_median,
    "trimmed_mean": aggregate_trimmed_mean,
    "krum": aggregate_krum,
    "multi_krum": functools.partial(aggregate_krum, multi_k=4),
    "medoid": aggregate_medoid,
    "geometric_median": aggregate_geometric_median,
}


def get_aggregator(name: str, **kwargs) -> Callable[[jax.Array], jax.Array]:
    """Resolve a stateless aggregator by name with bound hyper-parameters.

    ``krum``/``multi_krum`` require ``n_byzantine``; ``trimmed_mean`` takes
    ``trim_fraction``. ``byzantine_sgd`` is stateful — construct a
    :class:`repro.core.byzantine_sgd.ByzantineGuard` instead (the solver in
    :mod:`repro.core.solver` handles both kinds).
    """
    if name not in AGGREGATORS:
        raise KeyError(f"unknown aggregator {name!r}; have {sorted(AGGREGATORS)}")
    fn = AGGREGATORS[name]
    return functools.partial(fn, **kwargs) if kwargs else fn
