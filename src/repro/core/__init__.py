"""repro.core — the paper's contribution (Alistarh, Allen-Zhu, Li, NeurIPS'18).

Faithful, composable JAX implementation of ByzantineSGD (Algorithm 1), the
Section-4 strongly-convex epoch solver, the Section-5 lower-bound hard
instances, the baseline robust aggregators the paper compares against, and
the Byzantine attack zoo used to exercise them.
"""
from repro.core.byzantine_sgd import (
    GuardConfig,
    GuardState,
    ByzantineGuard,
    counting_median_index,
    pairwise_sq_dists_from_gram,
)
from repro.core.aggregators import (
    AGGREGATORS,
    STATEFUL_AGGREGATORS,
    aggregate_mean,
    aggregate_coordinate_median,
    aggregate_trimmed_mean,
    aggregate_krum,
    aggregate_geometric_median,
    aggregate_autogm,
    aggregate_medoid,
    aggregator_names,
    bucket_means,
    get_aggregator,
    make_centered_clip,
    simplex_project,
    weiszfeld_update,
)
from repro.core.attacks import ATTACKS, alie_z_max, apply_attack, get_attack
from repro.core.guard_backends import (
    guard_backend_names,
    make_guard_backend,
    register_guard_backend,
)
from repro.core.solver import (
    ByzantineSGDSolver,
    SolverConfig,
    byz_rank,
    ceil_byzantine_count,
    make_aggregator,
    run_sgd,
)
from repro.core.tree_harness import (
    FlatSpec,
    TreeHarness,
    VectorModel,
    params_harness,
)
from repro.core.epoch_solver import EpochSolverConfig, solve_strongly_convex
from repro.core.lower_bound import (
    distinguishing_experiment_linear,
    distinguishing_experiment_strongly_convex,
)

__all__ = [
    "GuardConfig",
    "GuardState",
    "ByzantineGuard",
    "counting_median_index",
    "pairwise_sq_dists_from_gram",
    "AGGREGATORS",
    "STATEFUL_AGGREGATORS",
    "ATTACKS",
    "aggregate_mean",
    "aggregate_coordinate_median",
    "aggregate_trimmed_mean",
    "aggregate_krum",
    "aggregate_geometric_median",
    "aggregate_autogm",
    "aggregate_medoid",
    "aggregator_names",
    "bucket_means",
    "get_aggregator",
    "make_centered_clip",
    "simplex_project",
    "weiszfeld_update",
    "alie_z_max",
    "apply_attack",
    "get_attack",
    "guard_backend_names",
    "make_guard_backend",
    "register_guard_backend",
    "ByzantineSGDSolver",
    "SolverConfig",
    "byz_rank",
    "ceil_byzantine_count",
    "make_aggregator",
    "run_sgd",
    "FlatSpec",
    "TreeHarness",
    "VectorModel",
    "params_harness",
    "EpochSolverConfig",
    "solve_strongly_convex",
    "distinguishing_experiment_linear",
    "distinguishing_experiment_strongly_convex",
]
