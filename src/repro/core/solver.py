"""Convex Byzantine-SGD driver — the paper's experimental harness.

Runs Problem (a stochastic convex objective, Section 2.1 model) for T
iterations with m simulated workers, an α-fraction of which are Byzantine
and controlled by an attack from :mod:`repro.core.attacks`.  The update is
the paper's projected mirror-descent step (Fact 2.5):

    x_{k+1} = Proj_{‖y − x_1‖ ≤ D} (x_k − η ξ_k)

with ξ_k produced either by the stateful ByzantineSGD guard (Algorithm 1)
or by any stateless baseline aggregator.  Everything is one ``lax.scan`` so
T ~ 10⁴ iterations on small d run in milliseconds — which is what the
Table-1 benchmarks sweep.
"""
from __future__ import annotations

import functools
import inspect
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import aggregators as agg_lib
from repro.core import attacks as attack_lib
from repro.core.guard_backends import make_guard_backend
from repro.kernels import gradgen
from repro.obs.telemetry import (
    Telemetry,
    baseline_frame,
    ring_init,
    ring_push,
    telemetry_on,
)


class Problem(NamedTuple):
    """A stochastic convex objective in the Section-2.1 model.

    ``stoch_grad(key, x) -> g`` must satisfy Assumption 2.2:
    E[g] = ∇f(x) and ‖g − ∇f(x)‖ ≤ V almost surely.

    ``het_grad`` (optional, DESIGN.md §13) is the *non-iid* sampler
    ``het_grad(key, x, skew, w) -> g``: worker w draws from a distribution
    whose mean is ∇f(x) + skew·C[w] for a fixed zero-sum per-worker bias
    matrix C — honest workers disagree by design, yet the biases cancel
    over the fleet so the global optimum (and the Theorem-3.8 check) is
    unchanged.  When set, ``V`` must already account for the worst-case
    per-worker bias (see :func:`repro.data.problems.heterogenize_problem`,
    which inflates it) and ``het`` records the provenance
    ``{'V0', 'cmax', 'skew_max'}`` so reports can re-derive the bound at
    the *realized* per-row skew.
    """

    d: int
    f: Callable[[jax.Array], jax.Array]
    grad: Callable[[jax.Array], jax.Array]
    stoch_grad: Callable[[jax.Array, jax.Array], jax.Array]
    x1: jax.Array
    x_star: jax.Array
    D: float
    V: float
    L: float = 1.0      # smoothness (0 = treat as nonsmooth)
    sigma: float = 0.0  # strong convexity (0 = merely convex)
    het_grad: Callable | None = None  # (key, x, skew, w) -> g (non-iid axis)
    het: dict | None = None           # {'V0','cmax','skew_max'} provenance
    gen: object | None = None         # repro.kernels.gradgen.GenSpec when the
    #                                   problem is counter-generatable
    #                                   (DESIGN.md §14); required by
    #                                   SolverConfig.generate="kernel"


def ceil_byzantine_count(alpha: float, m: int) -> int:
    """max(⌈αm⌉, 1) — the *covering* Byzantine count.

    Defense parameters (Krum's f, trimmed-mean's b, the trainer's baseline
    sizing) must round **up** so they cover the corrupted set, while the
    adversary's realized count floors (whole workers are corrupted —
    :attr:`SolverConfig.n_byzantine`).  The tiny epsilon guards against f32
    grid alphas landing just above an integer.
    """
    return max(math.ceil(alpha * m - 1e-9), 1)


class SolverConfig(NamedTuple):
    m: int                      # number of workers
    T: int                      # iterations
    eta: float                  # learning rate
    alpha: float = 0.0          # Byzantine fraction
    aggregator: str = "byzantine_sgd"
    attack: str = "sign_flip"
    attack_kwargs: tuple = ()   # tuple of (key, value) pairs (hashable)
    mean_over_alive: bool = False
    delta: float = 1e-3
    threshold_mode: str = "anytime"
    krum_f: int | None = None   # override Krum's f (defaults to ⌈αm⌉)
    trim_fraction: float | None = None  # defaults to α
    guard_backend: str = "dense"  # byzantine_sgd realization (DESIGN.md §9):
    #                               'dense' | 'fused' | 'dp_exact' | 'dp_sketch'
    guard_opts: tuple = ()      # backend knobs as (key, value) pairs (hashable)
    stats_dtype: str = "f32"    # storage dtype of the guard statistics
    #                             ('f32' | 'bf16'): the precision axis of
    #                             DESIGN.md §5 Numerics, threaded through
    #                             every guard backend; bf16 halves the
    #                             filter pipeline's HBM traffic
    agg_opts: tuple = ()        # baseline-aggregator knobs as (key, value)
    #                             pairs (hashable, DESIGN.md §11): e.g.
    #                             clip_tau / clip_iters for centered_clip,
    #                             lamb / n_outer for autogm, bucket_seed
    #                             for bucket<s>:<base> composition; each
    #                             aggregator receives only the knobs it
    #                             declares (guard_opts convention)
    max_delay: int = 0          # static cap on the WorkerProfile staleness
    #                             schedule (DESIGN.md §13); 0 = staleness
    #                             machinery off (no stale buffer in the
    #                             scan carry, pre-profile trace)
    partial_participation: bool = False  # static gate for the per-step
    #                             reporting mask; False = everyone reports
    #                             (no report mask in the trace)
    generate: str = "off"       # "off" | "kernel" (DESIGN.md §14): "kernel"
    #                             regenerates every worker gradient inside
    #                             the fused guard sweep from counter-based
    #                             PRNG bits — the (m, d) batch never lands
    #                             in HBM.  Requires problem.gen, a scenario
    #                             adversary, aggregator="byzantine_sgd",
    #                             guard_backend="fused"; statically gated
    #                             so "off" traces the pre-gen program
    #                             byte-for-byte
    sanitize: str = "off"       # "off" | "quarantine" (DESIGN.md §15):
    #                             non-finite hygiene ahead of every
    #                             aggregator.  "quarantine" zeroes NaN/Inf
    #                             entries before any statistic and marks
    #                             rows containing them dead (guards: via
    #                             the carried alive mask, permanently;
    #                             baselines: per-step), so every backend
    #                             returns finite ξ under arbitrary
    #                             contamination.  Statically gated: "off"
    #                             traces the pre-sanitize program
    #                             byte-for-byte

    @property
    def n_byzantine(self) -> int:
        return int(self.alpha * self.m)

    @property
    def krum_f_default(self) -> int:
        """⌈αm⌉ — Krum's f must *cover* the Byzantine count, so it rounds up
        (n_byzantine floors: the adversary corrupts whole workers).  Shared
        convention: :func:`ceil_byzantine_count`.
        """
        return ceil_byzantine_count(self.alpha, self.m)


class SolverResult(NamedTuple):
    x_final: jax.Array          # last iterate
    x_avg: jax.Array            # (1/T) Σ_{k≤T} x_k  (Theorem 3.8 average)
    gaps: jax.Array             # (T,) f(x_k) − f(x*)
    n_alive: jax.Array          # (T,) |good_k| (m for stateless aggregators)
    byz_mask: jax.Array         # (m,) workers that were *ever* Byzantine
    ever_filtered_good: jax.Array  # () bool — did the filter ever drop a good worker
    final_alive: jax.Array      # (m,) bool
    telemetry: object = None    # repro.obs.Telemetry when the flight recorder
    #                             ran (DESIGN.md §12); None otherwise — a None
    #                             leaf keeps the pytree structure (and every
    #                             historical consumer) unchanged
    n_reporting: jax.Array | None = None  # (T,) int32 per-step reporter count
    #                             under partial participation (DESIGN.md
    #                             §13); None when everyone reports


def byz_rank(key: jax.Array, m: int) -> jax.Array:
    """Random per-worker rank; worker w is Byzantine iff rank[w] < n_byz.
    (``argsort(perm)[w]`` is w's position in ``perm``, so ``rank < n_byz``
    equals the historical ``isin(arange(m), perm[:n_byz])`` bit-for-bit.)
    Scenario adversaries re-derive a *per-step* mask from the same rank
    (churn/late-join schedules — repro.scenarios.adversary); the LM trainer
    consumes the identical rank convention (DESIGN.md §10)."""
    return jnp.argsort(jax.random.permutation(key, m))


_byz_rank = byz_rank  # historical name


def parse_aggregator_spec(name: str) -> tuple[int | None, str]:
    """``"bucket2:krum"`` → ``(2, "krum")``; ``"krum"`` → ``(None, "krum")``.

    The campaign spelling for s-bucket pre-averaging composed with a base
    aggregator (DESIGN.md §11); the base may itself be any spec this
    function accepts (stateless, stateful, ``byzantine_sgd``, or another
    bucketing layer).
    """
    head, sep, base = name.partition(":")
    if sep and head.startswith("bucket"):
        try:
            s = int(head[len("bucket"):])
        except ValueError:
            raise KeyError(f"malformed bucketing spec {name!r}; "
                           "expected 'bucket<s>:<base>'") from None
        if s < 1:
            raise KeyError(f"bucketing needs s >= 1, got {name!r}")
        return s, base
    return None, name


def _declared_knobs(target) -> set[str]:
    """Parameter names ``target`` accepts beyond its data arguments."""
    sig = inspect.signature(target)
    return {p.name for p in sig.parameters.values()
            if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
            and p.name not in ("grads", "d")}


def _validate_agg_opts(opts: dict) -> None:
    """Loud KeyError on knobs no registered aggregator declares — the
    ``guard_opts`` convention: one tuple serves a whole campaign sweep
    (cross-aggregator knobs drop silently), typos fail before tracing."""
    known = {"bucket_seed"}
    for fn in agg_lib.AGGREGATORS.values():
        known |= _declared_knobs(fn)
    for factory in agg_lib.STATEFUL_AGGREGATORS.values():
        known |= _declared_knobs(factory)
    unknown = set(opts) - known
    if unknown:
        raise KeyError(f"unknown agg_opts {sorted(unknown)}; "
                       f"known knobs: {sorted(known)}")


def make_aggregator(problem, cfg: SolverConfig, telemetry=None):
    """Returns (init_state, step(state, grads, x, x1) -> (state, xi, n_alive, alive)).

    ``byzantine_sgd`` dispatches through the guard-backend registry
    (:mod:`repro.core.guard_backends`, DESIGN.md §9): ``cfg.guard_backend``
    selects dense / fused / dp_exact / dp_sketch, all behind the same step
    signature, so campaigns sweep guard realizations like any other axis.

    Baselines come in two kinds (DESIGN.md §11): **stateless** rules from
    :data:`repro.core.aggregators.AGGREGATORS` (wrapped with a scalar dummy
    state) and **stateful** ones from :data:`~repro.core.aggregators.
    STATEFUL_AGGREGATORS` (e.g. centered clipping's carried center), whose
    pytree state the solver scan-carries exactly like the guard martingales.
    A ``bucket<s>:<base>`` spec composes s-bucket pre-averaging in front of
    any base aggregator: worker rows are permuted with a scan-carried PRNG
    key, averaged in groups of s, and the base rule — instantiated at the
    bucket count m/s with its Byzantine sizing inflated to the s·α
    contaminated-bucket fraction — aggregates the bucket means.

    Per-aggregator knobs ride ``cfg.agg_opts`` ((key, value) pairs, the
    ``guard_opts`` convention): each target receives only the knobs it
    declares; a knob nothing declares is a KeyError.

    ``problem`` only needs ``d`` / ``V`` / ``D`` — a full :class:`Problem`
    or the :class:`repro.core.tree_harness.FlatSpec` the LM trainer builds
    from its ravelled parameter tree (DESIGN.md §10) both qualify, which is
    what makes this the *single* aggregation entry point for the flat
    harness and for model training.

    ``telemetry`` (a :class:`repro.obs.TelemetryConfig`, DESIGN.md §12)
    switches every branch into the *probed* five-tuple form of
    :func:`repro.core.guard_backends.make_guard_backend`: the step also
    returns a flight-recorder frame on the shared ``FRAME_SCHEMA``.
    Guard backends fill the per-worker martingale forensics; baseline
    aggregators report the baseline frame (alive mask + n_alive, NaN
    elsewhere).  Off (the default) is the historical four-tuple —
    signature and trace unchanged.
    """
    opts = dict(cfg.agg_opts)
    _validate_agg_opts(opts)
    bucket_s, name = parse_aggregator_spec(cfg.aggregator)
    probe = telemetry_on(telemetry)
    if cfg.sanitize not in ("off", "quarantine"):
        raise ValueError(
            f"sanitize must be 'off' or 'quarantine', got {cfg.sanitize!r}")
    san_on = cfg.sanitize == "quarantine"

    def _probed(state0, step4):
        # generic baseline wrapping: the sanitize stage (DESIGN.md §15) in
        # front of the rule, then the flight-recorder probe behind it
        if san_on:
            inner4 = step4

            def step4(state, grads, x, x1, report=None):
                # quarantine contract for baselines: non-finite entries are
                # zeroed before the rule sees them (a zero row instead of a
                # poisoned one — mean/median/krum all stay finite) and the
                # offending rows are reported dead this step.  Baselines are
                # memoryless about membership, so per-step alive is the
                # whole contract; guards persist the kill via state.alive.
                fin = jnp.isfinite(grads)
                finite = jnp.all(fin, axis=1)
                state, xi, n_alive, alive = inner4(
                    state, jnp.where(fin, grads, 0), x, x1, report)
                alive = alive & finite
                return state, xi, jnp.sum(alive).astype(jnp.int32), alive

        if not probe:
            return state0, step4

        def step(state, grads, x, x1, report=None):
            state, xi, n_alive, alive = step4(state, grads, x, x1, report)
            frame = baseline_frame(cfg.m, alive, n_alive)
            if san_on:
                frame["n_nonfinite"] = jnp.sum(
                    ~jnp.all(jnp.isfinite(grads), axis=1)).astype(jnp.float32)
            return state, xi, n_alive, alive, frame

        return state0, step

    if bucket_s is not None:
        if cfg.m % bucket_s:
            raise ValueError(
                f"bucketing needs s | m, got s={bucket_s}, m={cfg.m}")
        # the base rule sees m/s bucket means, of which up to ⌈αm⌉ are
        # contaminated — an s·α effective Byzantine fraction (capped at
        # 1/2; the base's own sizing caps, e.g. trimmed-mean survivors,
        # still apply on top)
        inner_cfg = cfg._replace(
            aggregator=name,
            m=cfg.m // bucket_s,
            alpha=min(cfg.alpha * bucket_s, 0.5),
        )
        inner_state0, inner_step = make_aggregator(problem, inner_cfg)
        state0 = (jax.random.PRNGKey(int(opts.get("bucket_seed", 0))),
                  inner_state0)

        def step(state, grads, x, x1, report=None):
            # baselines (and bucketing) ignore the reporting mask: the
            # server reuses a non-reporter's last row — which is exactly
            # what `grads` holds under the staleness buffer (DESIGN.md §13)
            key, inner = state
            key, sub = jax.random.split(key)
            buckets = agg_lib.bucket_means(grads, bucket_s, sub)
            inner, xi, _, _ = inner_step(inner, buckets, x, x1)
            # per-bucket filter decisions don't map back onto workers —
            # bucketing reports the stateless all-alive convention
            return (key, inner), xi, jnp.asarray(cfg.m), jnp.ones((cfg.m,), bool)

        return _probed(state0, step)

    if name == "byzantine_sgd":
        return make_guard_backend(cfg.guard_backend, problem, cfg, telemetry)

    if name in agg_lib.STATEFUL_AGGREGATORS:
        factory = agg_lib.STATEFUL_AGGREGATORS[name]
        fkwargs = {k: v for k, v in opts.items()
                   if k in _declared_knobs(factory)}
        state0, agg_step = factory(problem.d, **fkwargs)

        def step(state, grads, x, x1, report=None):
            state, xi = agg_step(state, grads)
            return state, xi, jnp.asarray(cfg.m), jnp.ones((cfg.m,), bool)

        return _probed(state0, step)

    kwargs = {}
    if name in ("krum", "multi_krum"):
        kwargs["n_byzantine"] = cfg.krum_f if cfg.krum_f is not None else cfg.krum_f_default
    if name == "trimmed_mean":
        # default: the ceil convention (cover ⌈αm⌉ per side), capped so a
        # near-1/2 α leaves at least one survivor; identical to the old
        # max(α, 1/m) whenever αm is integral
        tf = (cfg.trim_fraction if cfg.trim_fraction is not None
              else min(ceil_byzantine_count(cfg.alpha, cfg.m),
                       (cfg.m - 1) // 2) / cfg.m)
        kwargs["trim_fraction"] = tf
    fn = agg_lib.get_aggregator(name)
    kwargs.update({k: v for k, v in opts.items() if k in _declared_knobs(fn)})
    fn = functools.partial(fn, **kwargs) if kwargs else fn

    def step(state, grads, x, x1, report=None):
        xi = fn(grads)
        return state, xi, jnp.asarray(cfg.m), jnp.ones((cfg.m,), bool)

    return _probed(jnp.zeros(()), step)


def run_sgd(
    problem: Problem,
    cfg: SolverConfig,
    key: jax.Array,
    adversary=None,
    telemetry=None,
) -> SolverResult:
    """Run one full optimization (jit-compiled scan over T iterations).

    ``adversary`` (optional) replaces the static ``cfg.attack`` /
    ``cfg.alpha`` pair with a *scenario* adversary — any object with the
    :class:`repro.scenarios.adversary.ScenarioAdversary` interface:

    * ``mask_at(rank, k) -> (m,) bool`` — the per-step Byzantine set (the
      static path evaluates its mask once; churn/late-join schedules vary it),
    * ``init_state(m, d) -> pytree`` — adversary memory, scan-carried next
      to the aggregator state,
    * ``attack(key, grads, mask_k, ctx, state) -> grads'`` and
      ``update_state(state, mask_k, grads', xi, alive, n_alive, ctx) ->
      state'`` — the (possibly adaptive) corruption and its feedback update.

    Its leaves may be traced arrays, so an entire grid of scenarios runs
    under one ``jit(vmap)`` (see :func:`repro.scenarios.campaign.run_campaign`).
    Both paths feed the attack a ``ctx`` extended with the previous step's
    filter feedback (``alive``, ``n_alive``, ``prev_xi``) — everything the
    Remark-2.3 adversary may observe.

    ``telemetry`` (:class:`repro.obs.TelemetryConfig`, DESIGN.md §12) arms
    the guard flight recorder: the aggregator step runs in probed form and
    its per-step frame — completed here with ``step``, ``‖ξ_k‖``, and the
    adversary's ``adapt_scale`` feedback signal when it carries one — is
    pushed into a fixed-size on-device ring buffer carried by the scan.
    Two full-horizon series ride alongside: per-worker first-filter step
    and the per-step count of surviving Byzantine workers.  The result's
    ``telemetry`` field holds all three; everything stays on device until
    the caller drains it (``ring_read``).  ``None`` / ``enabled=False``
    is statically off — the scan carry, ys, and trace are bit-identical
    to the historical program.
    """
    tel_on = telemetry_on(telemetry)
    # per-worker-state gates (DESIGN.md §13): each is a *static* Python
    # decision, so a run without a profile (or with a machinery axis off)
    # lowers to literally the pre-profile trace — the bit-identity
    # guarantee of the degenerate WorkerProfile costs nothing to keep
    profile = getattr(adversary, "profile", None)
    het_on = profile is not None and problem.het_grad is not None
    stale_on = profile is not None and cfg.max_delay > 0
    part_on = profile is not None and cfg.partial_participation
    # fault-injection gate (DESIGN.md §15): static like the rest — no
    # FaultPlan on the adversary, no fault machinery in the trace
    fault_plan = getattr(adversary, "faults", None)
    fault_on = fault_plan is not None
    if fault_on:
        from repro.scenarios import faults as faults_mod  # avoid import cycle
    # on-device generation gate (DESIGN.md §14): a static Python decision —
    # "off" leaves the materializing trace untouched byte-for-byte
    if cfg.generate not in ("off", "kernel"):
        raise ValueError(f"generate must be 'off' or 'kernel', "
                         f"got {cfg.generate!r}")
    gen_on = cfg.generate == "kernel"
    if gen_on:
        if problem.gen is None:
            raise ValueError("generate='kernel' needs a counter-generatable "
                             "problem (make_generated_problem)")
        if adversary is None or not hasattr(adversary, "gen_attack_ctx"):
            raise ValueError("generate='kernel' needs a scenario adversary "
                             "(ScenarioAdversary) — the static attack path "
                             "is not parameterized for in-kernel generation")
        if cfg.aggregator != "byzantine_sgd" or cfg.guard_backend != "fused":
            raise ValueError("generate='kernel' requires "
                             "aggregator='byzantine_sgd' with "
                             "guard_backend='fused', got "
                             f"{cfg.aggregator!r}/{cfg.guard_backend!r}")
        if cfg.max_delay or cfg.partial_participation:
            raise ValueError("generate='kernel' does not compose with "
                             "staleness buffers or partial participation "
                             "(both need the materialized batch)")
        if fault_on or cfg.sanitize != "off":
            raise ValueError("generate='kernel' does not compose with "
                             "fault injection or sanitize='quarantine' "
                             "(both need the materialized batch)")
        if het_on and problem.gen.het_sign is None:
            raise ValueError("generate='kernel' with a heterogeneous "
                             "profile needs heterogenize_generated (rank-1 "
                             "skew); heterogenize_problem's dense bias "
                             "cannot stream through a strip")
        # attack ids must come from the generatable subset; only checkable
        # here when the scenario is concrete (a vmapped campaign row passes
        # tracers — bad ids there fall through to the honest row)
        try:
            ids = (int(adversary.scenario.attack_a),
                   int(adversary.scenario.attack_b))
        except jax.errors.ConcretizationTypeError:
            ids = None
        if ids is not None:
            bad = [i for i in ids if i not in gradgen.GEN_SUPPORTED_IDS]
            if bad:
                raise ValueError(
                    f"attack ids {bad} are not in-kernel generatable "
                    f"(supported: {gradgen.GEN_SUPPORTED_IDS})")
    key, mask_key = jax.random.split(key)
    rank = byz_rank(mask_key, cfg.m)
    if adversary is None:
        static_mask = rank < cfg.n_byzantine
        attack_fn = attack_lib.get_attack(cfg.attack)
        attack_kwargs = dict(cfg.attack_kwargs)
        adv_state0: object = jnp.zeros(())
    else:
        adv_state0 = adversary.init_state(cfg.m, problem.d)
    agg_state0, agg_step = make_aggregator(problem, cfg, telemetry)
    x1 = problem.x1.astype(jnp.float32)

    def body(carry, k):
        x, agg_state, adv_state, x_sum, ever_byz, any_good_filtered, fb, rng = (
            carry[:8]
        )
        extras = list(carry[8:])
        buf = extras.pop(0) if stale_on else None
        tel = extras.pop(0) if tel_on else None
        prev_xi, prev_alive, prev_n_alive = fb
        rng, gkey, akey = jax.random.split(rng, 3)
        if gen_on:
            # on-device generation (DESIGN.md §14): no (m, d) batch — the
            # guard's two generating kernels rebuild every worker row from
            # the same key chain (split(gkey, m)) the materializing path
            # hands stoch_grad.  akey is still split above so the rng
            # stream matches step-for-step (the generatable attacks are
            # key-free, exactly like their materialized counterparts).
            worker_keys = jax.random.split(gkey, cfg.m)
            mask_k = adversary.mask_at(rank, k)
            ctx = {
                "true_grad": problem.grad(x), "V": problem.V, "step": k,
                "alive": prev_alive, "n_alive": prev_n_alive,
                "prev_xi": prev_xi,
            }
            slot, params, w_byz = adversary.gen_attack_ctx(
                mask_k, ctx, adv_state, problem.gen.noise_scale
            )
            skewsign = (profile.skew * problem.gen.het_sign if het_on
                        else jnp.zeros((cfg.m,), jnp.float32))
            genctx = gradgen.GenStepCtx(
                worker_keys=gradgen.key_bits(worker_keys),
                skewsign=skewsign, slot=slot, params=params, w_byz=w_byz,
            )
            if tel_on:
                agg_state, xi, n_alive, alive, byz_sum, frame = agg_step(
                    agg_state, genctx, x, x1, None
                )
            else:
                agg_state, xi, n_alive, alive, byz_sum = agg_step(
                    agg_state, genctx, x, x1, None
                )
            # the adversary's feedback signal, regenerated in-kernel: the
            # same Σ mask·rows / max(n_byz, 1) the host update computes
            byz_row = byz_sum / jnp.maximum(jnp.sum(mask_k), 1)
            adv_state = adversary.update_state_from_byz_row(
                adv_state, mask_k, byz_row, xi, alive, n_alive, ctx
            )
        else:
            worker_keys = jax.random.split(gkey, cfg.m)
            if het_on:
                # non-iid honest sampling: worker w draws from its skewed
                # distribution (mean ∇f + skew[w]·C[w]) — same RNG stream as
                # the iid path, so skew ≡ 0 reproduces it bit-for-bit
                grads = jax.vmap(
                    lambda wk, s, w: problem.het_grad(wk, x, s, w)
                )(worker_keys, profile.skew, jnp.arange(cfg.m))
            else:
                grads = jax.vmap(lambda wk: problem.stoch_grad(wk, x))(worker_keys)
            if stale_on:
                # periodic-refresh staleness: worker w recomputes its gradient
                # only when its schedule fires; between refreshes the scan
                # carries the stale row (computed at an older iterate).  With
                # delay ≡ 0 the refresh mask is all-True and buf ≡ fresh.
                refresh = adversary.refresh_at(k, cfg.max_delay)
                buf = jnp.where(refresh[:, None], grads, buf)
                grads = buf
            ctx = {
                "true_grad": problem.grad(x), "V": problem.V, "step": k,
                "alive": prev_alive, "n_alive": prev_n_alive, "prev_xi": prev_xi,
            }
            if adversary is None:
                mask_k = static_mask
                grads = attack_fn(akey, grads, mask_k, ctx, **attack_kwargs)
            else:
                mask_k = adversary.mask_at(rank, k)
                grads = adversary.attack(akey, grads, mask_k, ctx, adv_state)
            if fault_on:
                # machine faults land AFTER the attack — they model the
                # platform, not the adversary, and may hit honest workers
                # (rank convention: faults take the TOP ranks, Byzantine
                # the bottom).  fold_in keeps the gkey/akey streams
                # untouched, so an armed mode-0 plan stays on the
                # fault-free trajectory (pinned by test).
                fkey = jax.random.fold_in(akey, faults_mod.FAULT_KEY_TAG)
                grads = faults_mod.apply_fault_plan(
                    fault_plan, fkey, grads, rank, k)
                fault_rows_k = faults_mod.fault_rows(fault_plan, rank, k)
            if part_on:
                # the reporting mask is *distinct* from the Byzantine mask:
                # honest workers skip steps per p_report, Byzantine workers
                # always report (worst case).  fold_in keeps the existing
                # gkey/akey streams untouched, so arming the machinery with
                # p_report ≡ 1 stays on the pre-profile trajectory.
                pkey = jax.random.fold_in(akey, 7919)
                report = adversary.report_at(pkey, mask_k)
                n_rep = jnp.sum(report).astype(jnp.int32)
            else:
                report = None

            if tel_on:
                agg_state, xi, n_alive, alive, frame = agg_step(
                    agg_state, grads, x, x1, report
                )
            else:
                agg_state, xi, n_alive, alive = agg_step(agg_state, grads, x, x1, report)
            if adversary is not None:
                adv_state = adversary.update_state(
                    adv_state, mask_k, grads, xi, alive, n_alive, ctx
                )

        x_new = x - cfg.eta * xi
        # Fact 2.5 projected step: ball of radius D around x_1
        delta = x_new - x1
        nrm = jnp.linalg.norm(delta)
        x_new = x1 + delta * jnp.minimum(1.0, problem.D / jnp.maximum(nrm, 1e-30))

        gap = problem.f(x) - problem.f(problem.x_star)
        # ever_byz stays the pure schedule union: Byzantine workers always
        # report, so mask_k ∩ report = mask_k by construction
        ever_byz = ever_byz | mask_k
        if fault_on:
            # a machine emitting corrupted values is "arbitrary behavior" in
            # the paper's sense: fault victims count toward the realized
            # ever-Byzantine fraction (and thus are never flagged as
            # wrongly-filtered good workers when the sanitizer kills them)
            ever_byz = ever_byz | fault_rows_k
        any_good_filtered = any_good_filtered | jnp.any((~alive) & (~ever_byz))
        fb = (xi, alive, jnp.asarray(n_alive, jnp.int32))
        if tel_on:
            ring, ffs = tel
            # complete the aggregator's frame with the solver-level signals:
            # 1-based step (the paper's k), ‖ξ_k‖, and the adaptive
            # adversary's feedback scale when its state carries one
            # (duck-typed — the core layer doesn't import AdvState)
            frame["step"] = (k + 1).astype(jnp.float32)
            frame["xi_norm"] = jnp.linalg.norm(xi).astype(jnp.float32)
            scale = getattr(adv_state, "adapt_scale", None)
            if scale is not None:
                frame["adapt_scale"] = jnp.asarray(scale, jnp.float32)
            if part_on:
                frame["n_reporting"] = n_rep.astype(jnp.float32)
            if stale_on:
                frame["staleness"] = jnp.mean(
                    adversary.staleness_at(k, cfg.max_delay).astype(jnp.float32)
                )
            ring = ring_push(ring, frame)
            # first step (1-based) each worker was filtered; -1 = never
            ffs = jnp.where((ffs < 0) & ~alive, k + 1, ffs)
            byz_alive = jnp.sum(alive & mask_k).astype(jnp.int32)
            tel_new = (ring, ffs)
        # Theorem-3.8 average is over the iterates the gradients were *taken
        # at*: x̄ = (1/T) Σ_{k≤T} x_k — accumulate x (= x_k), not x_new
        # (= x_{k+1}), or the sum runs x_2…x_{T+1} and excludes x_1
        new_carry = (x_new, agg_state, adv_state, x_sum + x, ever_byz,
                     any_good_filtered, fb, rng)
        if stale_on:
            new_carry = new_carry + (buf,)
        if tel_on:
            new_carry = new_carry + (tel_new,)
        ys = (gap, n_alive)
        if tel_on:
            ys = ys + (byz_alive,)
        if part_on:
            ys = ys + (n_rep,)
        return new_carry, ys

    fb0 = (
        jnp.zeros_like(x1),
        jnp.ones((cfg.m,), bool),
        jnp.asarray(cfg.m, jnp.int32),
    )
    carry0 = (x1, agg_state0, adv_state0, jnp.zeros_like(x1),
              jnp.zeros((cfg.m,), bool), jnp.asarray(False), fb0, key)
    if stale_on:
        # the scan-carried stale-gradient buffer; every schedule fires at
        # k = 0 (k % period == 0), so the zeros are never consumed
        carry0 = carry0 + (jnp.zeros((cfg.m, problem.d), jnp.float32),)
    if tel_on:
        tel0 = (ring_init(cfg.m, telemetry.ring_size),
                jnp.full((cfg.m,), -1, jnp.int32))
        carry0 = carry0 + (tel0,)
    carry_fin, ys = jax.lax.scan(body, carry0, jnp.arange(cfg.T))
    x_fin, agg_state, _, x_sum, ever_byz, good_filtered, _, _ = carry_fin[:8]
    gaps, n_alive = ys[0], ys[1]
    ys_rest = list(ys[2:])
    if tel_on:
        byz_alive = ys_rest.pop(0)
        ring_fin, ffs_fin = carry_fin[-1]
        tel_out = Telemetry(ring=ring_fin, first_filter_step=ffs_fin,
                            byz_alive=byz_alive)
    else:
        tel_out = None
    n_reporting = ys_rest.pop(0) if part_on else None
    final_alive = (
        agg_state.alive if hasattr(agg_state, "alive") else jnp.ones((cfg.m,), bool)
    )
    return SolverResult(
        x_final=x_fin,
        x_avg=x_sum / cfg.T,
        gaps=gaps,
        n_alive=n_alive,
        byz_mask=ever_byz,
        ever_filtered_good=good_filtered,
        final_alive=final_alive,
        telemetry=tel_out,
        n_reporting=n_reporting,
    )


class ByzantineSGDSolver:
    """Convenience OO wrapper with a jitted ``run``."""

    def __init__(self, problem: Problem, cfg: SolverConfig):
        self.problem = problem
        self.cfg = cfg
        self._run = jax.jit(functools.partial(run_sgd, problem, cfg))

    def run(self, seed: int = 0) -> SolverResult:
        return self._run(jax.random.PRNGKey(seed))

    def suboptimality(self, seed: int = 0) -> float:
        res = self.run(seed)
        return float(self.problem.f(res.x_avg) - self.problem.f(self.problem.x_star))
