"""Small pytree / math utilities shared across the framework.

Everything here is pure JAX (jit/vmap/scan friendly) and dependency-free —
we deliberately do not depend on optax/flax/chex since the substrate is
built in-repo.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# pytree arithmetic
# ---------------------------------------------------------------------------

def tree_map(fn: Callable, *trees: PyTree) -> PyTree:
    return jax.tree_util.tree_map(fn, *trees)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return tree_map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y."""
    return tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a: PyTree) -> PyTree:
    return tree_map(jnp.zeros_like, a)


def tree_ones_like(a: PyTree) -> PyTree:
    return tree_map(jnp.ones_like, a)


def tree_vdot(a: PyTree, b: PyTree) -> jax.Array:
    """Sum of elementwise products across all leaves (float32 accumulate)."""
    leaves = tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sq_norm(a: PyTree) -> jax.Array:
    return tree_vdot(a, a)


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_sq_norm(a))


def global_norm(a: PyTree) -> jax.Array:
    return tree_norm(a)


def tree_count_params(a: PyTree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(a)))


def tree_cast(a: PyTree, dtype) -> PyTree:
    return tree_map(lambda x: x.astype(dtype), a)


def tree_random_normal(key: jax.Array, like: PyTree, scale: float = 1.0) -> PyTree:
    """A tree of iid normal leaves shaped like ``like``."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = jax.random.split(key, len(leaves))
    new = [
        scale * jax.random.normal(k, l.shape, l.dtype if jnp.issubdtype(l.dtype, jnp.floating) else jnp.float32)
        for k, l in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, new)


def tree_flatten_vector(a: PyTree) -> jax.Array:
    """Concatenate all leaves into one flat float32 vector (small trees only)."""
    leaves = jax.tree_util.tree_leaves(a)
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in leaves])


def tree_unflatten_vector(vec: jax.Array, like: PyTree) -> PyTree:
    """Inverse of :func:`tree_flatten_vector` given a template tree."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, ofs = [], 0
    for l in leaves:
        n = int(np.prod(l.shape))
        out.append(vec[ofs : ofs + n].reshape(l.shape).astype(l.dtype))
        ofs += n
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# projections (paper uses ball-constrained mirror descent, Fact 2.5)
# ---------------------------------------------------------------------------

def project_ball(x: PyTree, center: PyTree, radius) -> PyTree:
    """Euclidean projection of ``x`` onto {y : ||y - center|| <= radius}.

    Operates on whole pytrees with the global l2 norm, matching the paper's
    single-vector iterate x ∈ R^d.
    """
    delta = tree_sub(x, center)
    nrm = tree_norm(delta)
    scale = jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-30))
    return tree_add(center, tree_scale(delta, scale))


def clip_by_global_norm(g: PyTree, max_norm) -> PyTree:
    nrm = tree_norm(g)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(nrm, 1e-30))
    return tree_scale(g, scale)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def pad_to_multiple(x: jax.Array, multiple: int, axis: int, value=0.0) -> jax.Array:
    """Pad ``axis`` of x up to the next multiple of ``multiple``."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def fold_key(key: jax.Array, *data: int) -> jax.Array:
    for d in data:
        key = jax.random.fold_in(key, d)
    return key


def chunked(seq: Iterable, n: int):
    seq = list(seq)
    for i in range(0, len(seq), n):
        yield seq[i : i + n]


@functools.lru_cache(maxsize=None)
def log_c(m: int, T: int, delta: float) -> float:
    """The paper's C = log(16 m T / δ) (Section 3.1)."""
    return float(np.log(16.0 * m * T / delta))
