"""Mamba2 (SSD — state-space duality) mixer, arXiv:2405.21060.

Chunked SSD algorithm for train/prefill (quadratic *within* length-Q chunks,
linear recurrence *across* chunks → O(S·Q) work, O(1) state), and the exact
O(1)-per-token recurrence for decode. This is what makes ``long_500k``
native for mamba2/jamba: decode state is (H, N, P) regardless of context.

Projection layout: we split the fused in_proj of the reference CUDA
implementation into separate z/x/B/C/dt projections and give x, B, C their
own depthwise causal convs — functionally identical, but each output dim
then has a clean logical sharding axis (heads → 'model'), which is the TPU
adaptation of Mamba2's GPU-fused layout.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from repro.models.common import ParamDef, rms_norm


def mamba_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner_ssm
    H = cfg.n_ssm_heads
    G, N, W = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv_width
    return {
        "w_z": ParamDef((d, di), ("embed", "mlp")),
        "w_x": ParamDef((d, di), ("embed", "mlp")),
        "w_B": ParamDef((d, G * N), ("embed", None)),
        "w_C": ParamDef((d, G * N), ("embed", None)),
        "w_dt": ParamDef((d, H), ("embed", "heads")),
        "conv_x": ParamDef((W, di), (None, "mlp"), init="normal", scale=1.0),
        "conv_B": ParamDef((W, G * N), (None, None)),
        "conv_C": ParamDef((W, G * N), (None, None)),
        "A_log": ParamDef((H,), ("heads",), init="zeros"),
        "D": ParamDef((H,), ("heads",), init="ones"),
        "dt_bias": ParamDef((H,), ("heads",), init="zeros"),
        "norm": ParamDef((di,), ("mlp",), init="ones"),
        "w_out": ParamDef((di, d), ("mlp", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along S. x: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _ssd_scan(
    xh: jax.Array,    # (B, S, H, P)  — conv'd, silu'd inputs
    dt: jax.Array,    # (B, S, H)     — softplus'd step sizes
    A: jax.Array,     # (H,)          — negative decay rates
    Bm: jax.Array,    # (B, S, G, N)
    Cm: jax.Array,    # (B, S, G, N)
    chunk: int,
    initial_state: jax.Array | None = None,   # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    R = H // G
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q

    f32 = jnp.float32
    xc = xh.reshape(Bsz, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, Q, G, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, Q, G, N).astype(f32)

    dtx = dtc[..., None] * xc                                  # (B,nc,Q,H,P)
    log_a = A.astype(f32) * dtc                                # negative, (B,nc,Q,H)
    cum = jnp.cumsum(log_a, axis=2)                            # inclusive cumsum
    cum_last = cum[:, :, -1]                                   # (B,nc,H)

    # ---- intra-chunk (quadratic within Q) ----
    s = jnp.einsum("bcign,bcjgn->bcgij", Cc, Bc)               # (B,nc,G,Q,Q)
    s = jnp.repeat(s, R, axis=2)                               # (B,nc,H,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # cum_i - cum_j (B,nc,Q,Q,H)
    decay = jnp.moveaxis(decay, -1, 2)                         # (B,nc,H,Q,Q)
    iq = jnp.arange(Q)
    causal = (iq[:, None] >= iq[None, :])
    M = jnp.where(causal[None, None, None], s * jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M, dtx)

    # ---- per-chunk outgoing state ----
    w_end = jnp.exp(cum_last[:, :, None, :] - cum)             # decay to chunk end (B,nc,Q,H)
    # state contribution: sum_j w_end_j * B_j ⊗ dtx_j → (B,nc,H,N,P)
    Bfull = jnp.repeat(Bc, R, axis=3)                          # (B,nc,Q,H,N)
    chunk_states = jnp.einsum("bcjhn,bcjhp,bcjh->bchnp", Bfull, dtx, w_end)

    # ---- inter-chunk recurrence (sequential scan over nc chunks) ----
    state0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((Bsz, H, N, P), f32)
    )
    Cfull = jnp.repeat(Cc, R, axis=3)                          # (B,nc,Q,H,N)

    def body(state, inp):
        c_full, cum_c, cum_last_c, cs = inp
        # y_inter[i] = exp(cum_i) · C_i · state_prev
        w_in = jnp.exp(cum_c)                                  # (B,Q,H)
        y_int = jnp.einsum("bqhn,bhnp,bqh->bqhp", c_full, state, w_in)
        state_new = jnp.exp(cum_last_c)[..., None, None] * state + cs
        return state_new, y_int

    xs = (
        jnp.moveaxis(Cfull, 1, 0),        # (nc, B, Q, H, N)
        jnp.moveaxis(cum, 1, 0),          # (nc, B, Q, H)
        jnp.moveaxis(cum_last, 1, 0),     # (nc, B, H)
        jnp.moveaxis(chunk_states, 1, 0),  # (nc, B, H, N, P)
    )
    final_state, y_inter = jax.lax.scan(body, state0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1).reshape(Bsz, nc, Q, H, P)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y.astype(xh.dtype), final_state


class MambaCache(NamedTuple):
    """Decode-time state: SSM state + conv tail (last W−1 inputs)."""

    state: jax.Array     # (B, H, N, P) f32
    conv_x: jax.Array    # (B, W-1, di)
    conv_B: jax.Array    # (B, W-1, G·N)
    conv_C: jax.Array    # (B, W-1, G·N)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> MambaCache:
    H, N, P = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    G = cfg.ssm_groups
    return MambaCache(
        state=jnp.zeros((batch, H, N, P), jnp.float32),
        conv_x=jnp.zeros((batch, W - 1, cfg.d_inner_ssm), dtype),
        conv_B=jnp.zeros((batch, W - 1, G * N), dtype),
        conv_C=jnp.zeros((batch, W - 1, G * N), dtype),
    )


def _proj_zxbcdt(p: dict, x: jax.Array):
    z = jnp.einsum("bsd,df->bsf", x, p["w_z"])
    xr = jnp.einsum("bsd,df->bsf", x, p["w_x"])
    Br = jnp.einsum("bsd,df->bsf", x, p["w_B"])
    Cr = jnp.einsum("bsd,df->bsf", x, p["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    return z, xr, Br, Cr, dt


def mamba_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, return_state: bool = False,
):
    """Train/prefill SSD pass. x: (B, S, D) → (B, S, D) [, MambaCache]."""
    Bsz, S, _ = x.shape
    H, N, P, G = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_groups
    W = cfg.ssm_conv_width

    z, xr_raw, Br_raw, Cr_raw, dt = _proj_zxbcdt(p, x)
    xr = jax.nn.silu(_causal_conv(xr_raw, p["conv_x"]).astype(jnp.float32)).astype(x.dtype)
    Br = jax.nn.silu(_causal_conv(Br_raw, p["conv_B"]).astype(jnp.float32)).astype(x.dtype)
    Cr = jax.nn.silu(_causal_conv(Cr_raw, p["conv_C"]).astype(jnp.float32)).astype(x.dtype)

    xh = xr.reshape(Bsz, S, H, P)
    xh = shard_act(xh, "batch", None, "heads", None)
    Bm = Br.reshape(Bsz, S, G, N)
    Cm = Cr.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, final_state = _ssd_scan(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bsz, S, H * P)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, p["w_out"])
    if return_state:
        def tail(raw):
            t = raw[:, -(W - 1):]
            pad = (W - 1) - t.shape[1]
            return jnp.pad(t, [(0, 0), (pad, 0), (0, 0)]) if pad else t
        cache = MambaCache(
            state=final_state,
            conv_x=tail(xr_raw), conv_B=tail(Br_raw), conv_C=tail(Cr_raw),
        )
        return out, cache
    return out


def mamba_decode_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, cache: MambaCache,
) -> tuple[jax.Array, MambaCache]:
    """One-token recurrence. x: (B, 1, D)."""
    Bsz = x.shape[0]
    H, N, P, G = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_groups

    z, xr, Br, Cr, dt = _proj_zxbcdt(p, x)

    def step_conv(tail: jax.Array, new: jax.Array, w: jax.Array):
        """tail: (B, W-1, C); new: (B, 1, C) → (conv output (B, C), new tail)."""
        window = jnp.concatenate([tail, new.astype(tail.dtype)], axis=1)  # (B, W, C)
        out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
        return out, window[:, 1:]

    cx, tail_x = step_conv(cache.conv_x, xr, p["conv_x"])
    cB, tail_B = step_conv(cache.conv_B, Br, p["conv_B"])
    cC, tail_C = step_conv(cache.conv_C, Cr, p["conv_C"])
    xh = jax.nn.silu(cx).reshape(Bsz, H, P)
    Bm = jax.nn.silu(cB).reshape(Bsz, G, N)
    Cm = jax.nn.silu(cC).reshape(Bsz, G, N)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt1)     # (B,H)

    R = H // G
    Bfull = jnp.repeat(Bm, R, axis=1)                               # (B,H,N)
    Cfull = jnp.repeat(Cm, R, axis=1)
    dtx = dt1[..., None] * xh.astype(jnp.float32)                   # (B,H,P)
    state = a[..., None, None] * cache.state + Bfull[..., None] * dtx[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Cfull.astype(jnp.float32), state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, 1, H * P).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, p["w_out"])
    return out, MambaCache(state=state, conv_x=tail_x, conv_B=tail_B, conv_C=tail_C)
