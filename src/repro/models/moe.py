"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

TPU adaptation notes:
  * We deliberately avoid the GShard one-hot ``(T, E, C)`` dispatch einsum —
    at kimi-k2 scale (T ≈ 1M tokens, E = 384) that temp is ~10¹² elements.
    Instead tokens are *scattered* into a per-expert capacity buffer
    ``(E, C, D)`` (one scatter per top-k choice, k unrolled) and gathered
    back after the expert matmuls. With experts sharded over the 'model'
    mesh axis this lowers to XLA all-to-all-style collectives.
  * Capacity C = ceil(T·k/E · capacity_factor); overflow tokens drop (their
    combine weight is zero) — standard GShard semantics, and the router
    aux loss pushes load balance so drops are rare at convergence.
  * The router runs in f32; an auxiliary load-balance loss (Switch-style)
    is returned to the caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from repro.models.common import ParamDef, swiglu


def moe_defs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    defs = {
        "router": ParamDef((d, e), ("embed", None), scale=0.1),
        "gate": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "up": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "down": ParamDef((e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        defs["shared"] = {
            "gate": ParamDef((d, fs), ("embed", "mlp")),
            "up": ParamDef((d, fs), ("embed", "mlp")),
            "down": ParamDef((fs, d), ("mlp", "embed")),
        }
    return defs


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out (B, S, D), aux_loss ()).

    Dispatch: top-k routing → position-in-expert via cumsum → k scatters
    into (E, C, D) → expert SwiGLU → k gathers, combine-weighted.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_p, top_e = jax.lax.top_k(probs, K)                       # (T, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)                                 # mean router prob / expert
    onehot_tot = jnp.zeros((T, E), jnp.float32)
    for j in range(K):
        onehot_tot = onehot_tot + jax.nn.one_hot(top_e[:, j], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_tot, axis=0) / K                        # fraction of tokens / expert
    aux = E * jnp.sum(me * ce)

    capacity = max(int(T * K / E * cfg.capacity_factor), 4)
    capacity = min(capacity, T)

    # position of each (token, choice) within its expert's capacity buffer:
    # flatten choices in priority order (choice-major keeps top-1 first)
    flat_e = top_e.T.reshape(K * T)                              # (K·T,) choice-major
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (K·T, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1) * onehot         # running index
    flat_pos = jnp.sum(pos_in_e, axis=1)                         # (K·T,)
    keep = flat_pos < capacity
    pos = flat_pos.reshape(K, T)
    keep = keep.reshape(K, T)

    buf = jnp.zeros((E, capacity, D), x.dtype)
    buf = shard_act(buf, "experts", None, "act_embed")
    for j in range(K):
        # dropped (over-capacity) tokens scatter a zero update into slot 0
        slot = jnp.minimum(pos[j], capacity - 1)
        upd = jnp.where(keep[j][:, None], xt, 0).astype(buf.dtype)
        buf = buf.at[top_e[:, j], slot].add(upd)

    g = jnp.einsum("ecd,edf->ecf", buf, p["gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["up"])
    hmid = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    h = jnp.einsum("ecf,efd->ecd", hmid, p["down"])
    h = shard_act(h, "experts", None, "act_embed")

    out = jnp.zeros((T, D), jnp.float32)
    for j in range(K):
        gathered = h[top_e[:, j], jnp.minimum(pos[j], capacity - 1)]
        w = jnp.where(keep[j], top_p[:, j], 0.0)
        out = out + w[:, None] * gathered.astype(jnp.float32)

    out = out.astype(x.dtype).reshape(B, S, D)
    if "shared" in p:
        out = out + swiglu(x, p["shared"]["gate"], p["shared"]["up"], p["shared"]["down"])
    return out, aux.astype(jnp.float32)
