"""repro.models — the architecture zoo (pure JAX, config-driven).

All six assigned families: dense decoder (GQA / sliding-window), MLA
(DeepSeek), MoE (GShard-free scatter dispatch + shared experts), SSM
(Mamba2/SSD chunked scan), hybrid interleave (Jamba), encoder–decoder
(Seamless backbone), and VLM/audio embedding frontstubs.

Entry points:
  * :func:`repro.models.model.build_model` — returns a :class:`LanguageModel`
    bundle: param defs, init, ``loss_fn`` (train), ``prefill`` and
    ``decode_step`` (serve), all scanned over stacked per-group params.
"""
from repro.models.model import LanguageModel, build_model

__all__ = ["LanguageModel", "build_model"]
