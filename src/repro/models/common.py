"""Shared model building blocks: parameter definitions, norms, RoPE, MLPs.

Parameters are declared as :class:`ParamDef` trees — a single source of
truth for shape, initialization *and* logical sharding axes — from which we
derive (a) initialized pytrees, (b) PartitionSpec trees for pjit
in_shardings, and (c) ShapeDtypeStruct trees for AOT dry-runs that never
allocate memory.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamDef(NamedTuple):
    shape: tuple
    axes: tuple                 # logical axis names, len == len(shape)
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float = 1.0          # stddev multiplier (normal) / fan-in handled below

    def with_leading(self, n: int, axis_name: str | None = None) -> "ParamDef":
        """Stack this def along a new leading 'layers' axis (for scan)."""
        return self._replace(shape=(n, *self.shape), axes=(axis_name, *self.axes))


def _fan_in(shape: tuple) -> int:
    return int(shape[-2]) if len(shape) >= 2 else int(shape[-1])


def init_param(key: jax.Array, d: ParamDef, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "embed":
        return (d.scale * jax.random.normal(key, d.shape)).astype(dtype)
    # truncated-normal with 1/sqrt(fan_in) scaling
    std = d.scale / np.sqrt(max(_fan_in(d.shape), 1))
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, d.shape)).astype(dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(key: jax.Array, defs: Any, dtype) -> Any:
    """Initialize a pytree of ParamDef into arrays (deterministic per-leaf
    keys derived from the tree path hash, so adding parameters does not
    reshuffle existing ones)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrs = [init_param(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(defs: Any, dtype) -> Any:
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def
    )


def param_count(defs: Any) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def mlp_defs(d_model: int, d_ff: int) -> dict:
    return {
        "gate": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "up": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "down": ParamDef((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    return swiglu(x, p["gate"], p["up"], p["down"])


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., 0::2], x32[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array, z_loss: float = 1e-4):
    """Mean next-token cross entropy in f32 with optional z-loss.

    logits: (..., V); labels: (...,) int32. Returns (loss, metrics).
    """
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse * lse)
    return loss, {"nll": jnp.mean(nll), "z": jnp.mean(lse * lse)}
