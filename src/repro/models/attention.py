"""Attention mixers: GQA, sliding-window, and MLA (DeepSeek-style latent KV).

Design notes (TPU adaptation):
  * Train/prefill attention is *chunked over KV blocks* with an online
    softmax (flash-attention recurrence in pure JAX): the (S, S) score
    matrix never materializes, peak temp is (Sq, chunk). This is what lets
    ``prefill_32k`` fit; on real TPU the same structure maps 1:1 onto a
    Pallas flash kernel.
  * Decode keeps a preallocated KV cache (ring buffer for sliding window)
    and computes a single-query attention; MLA decode uses the
    absorbed-projection form so the cache is only (S, kv_lora + rope_dim).
  * Heads shard over the 'model' mesh axis via logical-axis annotations;
    when a KV-head count does not divide the axis (e.g. starcoder2's kv=2 on
    model=16) the divisibility-aware rules replicate instead.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_act
from repro.models.common import ParamDef, apply_rope


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ParamDef((d, h, hd), ("embed", "heads", None)),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed")),
    }


def mla_defs(cfg: ModelConfig) -> dict:
    d, h, hd, r = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    lk = cfg.kv_lora_rank
    defs = {
        "w_dkv": ParamDef((d, lk + r), ("embed", None)),      # latent + rope key
        "w_uk": ParamDef((lk, h, hd), (None, "heads", None)),
        "w_uv": ParamDef((lk, h, hd), (None, "heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.q_lora_rank:
        defs["w_dq"] = ParamDef((d, cfg.q_lora_rank), ("embed", None))
        defs["w_uq"] = ParamDef((cfg.q_lora_rank, h, hd + r), (None, "heads", None))
    else:
        defs["wq"] = ParamDef((d, h, hd + r), ("embed", "heads", None))
    return defs


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — train / prefill
# ---------------------------------------------------------------------------

def _chunk_pad(x: jax.Array, chunk: int, axis: int):
    s = x.shape[axis]
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, pad)
        x = jnp.pad(x, pads)
    new_shape = x.shape[:axis] + (n_chunks, chunk) + x.shape[axis + 1 :]
    return x.reshape(new_shape), n_chunks


def chunked_attention(
    q: jax.Array,            # (B, Sq, H, hd)   — RoPE already applied
    k: jax.Array,            # (B, Sk, KV, hd)  — RoPE already applied
    v: jax.Array,            # (B, Sk, KV, hd)
    q_positions: jax.Array,  # (Sq,)
    k_positions: jax.Array,  # (Sk,)
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention, scanning KV in blocks of ``chunk``.

    q/k may have a different head dim than v (MLA concatenates a RoPE part
    onto q/k only) — the output takes v's head dim.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    dv = v.shape[-1]
    R = H // KV
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    qg = q.reshape(B, Sq, KV, R, hd)
    kc, n_chunks = _chunk_pad(k, chunk, axis=1)            # (B, C, ck, KV, hd)
    vc, _ = _chunk_pad(v, chunk, axis=1)
    pc, _ = _chunk_pad(k_positions.astype(jnp.int32), chunk, axis=0)   # (C, ck)
    valid_c, _ = _chunk_pad(jnp.ones_like(k_positions, jnp.bool_), chunk, axis=0)

    # scan carries: running max m, running sum l, running out acc (f32)
    m0 = jnp.full((B, KV, R, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, R, Sq), jnp.float32)
    acc0 = jnp.zeros((B, KV, R, Sq, dv), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, p_blk, ok_blk = xs                   # (B, ck, KV, hd), ..., (ck,)
        s = jnp.einsum(
            "bqkrh,bckh->bkrqc", qg.astype(jnp.float32), k_blk.astype(jnp.float32)
        ) * scale                                           # (B, KV, R, Sq, ck)
        mask = ok_blk[None, :]
        if causal:
            mask = mask & (q_positions[:, None] >= p_blk[None, :])
        if window is not None:
            mask = mask & (q_positions[:, None] - p_blk[None, :] < window)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)

        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        # guard the all-masked case (exp(-inf - -inf)) → 0 contribution
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = corr * l + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkrqc,bckh->bkrqh", p, v_blk.astype(jnp.float32))
        acc_new = corr[..., None] * acc + pv
        return (m_new, l_new, acc_new), None

    xs = (
        jnp.moveaxis(kc, 1, 0),   # (C, B, ck, KV, hd)
        jnp.moveaxis(vc, 1, 0),
        pc,                        # (C, ck)
        valid_c,
    )
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), xs)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1)                           # (B, Sq, KV, R, dv)
    return out.reshape(B, Sq, H, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA mixer (train / prefill)
# ---------------------------------------------------------------------------

def gqa_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
    window: Optional[int] = None, return_kv: bool = False,
):
    """x: (B, S, D) → (B, S, D) [, (k, v) for prefill-cache capture]."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = shard_act(q, "batch", None, "heads", None)
    k = shard_act(k, "batch", None, "kv_heads", None)
    v = shard_act(v, "batch", None, "kv_heads", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = chunked_attention(
        q, k, v, positions, positions, causal=True,
        window=window,
        chunk=cfg.attn_chunk,
    )
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if return_kv:
        return out, (k, v)
    return out


def encoder_attn_apply(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Bidirectional self-attention (encoder side of enc-dec)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, positions, positions, causal=False, chunk=cfg.attn_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cross_attn_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, memory_k: jax.Array, memory_v: jax.Array,
) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V
    (memory_k/v: (B, Sm, KV, hd), already projected)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    Sm = memory_k.shape[1]
    pos_q = jnp.zeros((x.shape[1],), jnp.int32)
    pos_k = jnp.zeros((Sm,), jnp.int32)
    o = chunked_attention(q, memory_k, memory_v, pos_q, pos_k, causal=False, chunk=cfg.attn_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def project_memory(p: dict, memory: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Encoder output → cross-attention K/V (done once per request)."""
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    return k, v


# ---------------------------------------------------------------------------
# KV caches + decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Preallocated decode cache. ``length`` = cache capacity (sliding
    window size or max seq); ``pos`` = tokens generated so far (scalar)."""

    k: jax.Array     # (B, L, KV, hd) — RoPE-applied keys
    v: jax.Array     # (B, L, KV, hd)
    pos: jax.Array   # () int32


class QuantKVCache(NamedTuple):
    """int8 KV cache (§Perf serving lever): halves the per-token HBM read
    (decode is cache-bandwidth-bound).  Per-(batch, slot, head) absmax
    scales; dequantization fuses into the attention einsums."""

    k: jax.Array        # (B, L, KV, hd) int8
    v: jax.Array        # (B, L, KV, hd) int8
    k_scale: jax.Array  # (B, L, KV) f16
    v_scale: jax.Array  # (B, L, KV) f16
    pos: jax.Array      # () int32


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., hd) → (int8 values, (...) f16 absmax scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / safe[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def init_kv_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> KVCache:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        return QuantKVCache(
            k=jnp.zeros((batch, length, kv, hd), jnp.int8),
            v=jnp.zeros((batch, length, kv, hd), jnp.int8),
            k_scale=jnp.zeros((batch, length, kv), jnp.float16),
            v_scale=jnp.zeros((batch, length, kv), jnp.float16),
            pos=jnp.zeros((), jnp.int32),
        )
    return KVCache(
        k=jnp.zeros((batch, length, kv, hd), dtype),
        v=jnp.zeros((batch, length, kv, hd), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def cache_from_prefill(k: jax.Array, v: jax.Array, length: int, pos: jax.Array,
                       quantize: bool = False):
    """Pack prefill K/V (B, S, KV, hd) into a decode cache of capacity
    ``length`` with ring-buffer alignment (slot = position % length)."""
    S = k.shape[1]
    if S <= length:
        pad = [(0, 0), (0, length - S), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    else:
        off = S % length
        k = jnp.roll(k[:, -length:], off, axis=1)
        v = jnp.roll(v[:, -length:], off, axis=1)
    if quantize:
        kq, ks = _quantize(k)
        vq, vs = _quantize(v)
        return QuantKVCache(k=kq, v=vq, k_scale=ks, v_scale=vs, pos=pos)
    return KVCache(k=k, v=v, pos=pos)


def gqa_decode_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, cache,
    window: Optional[int] = None,
):
    """One-token decode. x: (B, 1, D). Ring-buffer write when windowed.
    Handles both bf16 (KVCache) and int8 (QuantKVCache) caches."""
    B = x.shape[0]
    L = cache.k.shape[1]
    pos = cache.pos
    quant = isinstance(cache, QuantKVCache)
    # ring-buffer write: for a full-length cache (L ≥ max seq) pos % L == pos,
    # so the same indexing covers both the windowed and the full case.
    slot = pos % L

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    positions = pos[None].astype(jnp.int32)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)

    if quant:
        kq, ks = _quantize(k_new)
        vq, vs = _quantize(v_new)
        k_cache = jax.lax.dynamic_update_slice(cache.k, kq, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache.v, vq, (0, slot, 0, 0))
        ks_cache = jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, slot, 0))
        vs_cache = jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, slot, 0))
    else:
        k_cache = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))
    k_cache = shard_act(k_cache, "batch", "cache_seq", "kv_heads", None)
    v_cache = shard_act(v_cache, "batch", "cache_seq", "kv_heads", None)

    KV, hd = cfg.n_kv_heads, cfg.head_dim
    H = cfg.n_heads
    R = H // KV
    qg = q.reshape(B, KV, R, hd)
    s = jnp.einsum("bkrh,blkh->bkrl", qg.astype(jnp.float32), k_cache.astype(jnp.float32))
    if quant:
        s = s * ks_cache.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    s = s / jnp.sqrt(jnp.float32(hd))
    # slots < min(pos+1, L) hold real tokens (ring wraps; full cache fills L)
    valid = jnp.arange(L) < jnp.minimum(pos + 1, L)
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    if quant:
        w = w * vs_cache.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    o = jnp.einsum("bkrl,blkh->bkrh", w, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, H, hd).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if quant:
        return out, QuantKVCache(k=k_cache, v=v_cache, k_scale=ks_cache,
                                 v_scale=vs_cache, pos=pos + 1)
    return out, KVCache(k=k_cache, v=v_cache, pos=pos + 1)


# ---------------------------------------------------------------------------
# MLA (train / prefill / decode)
# ---------------------------------------------------------------------------

def _mla_q(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
        return jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    return jnp.einsum("bsd,dhk->bshk", x, p["wq"])


def mla_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
    return_kv: bool = False,
):
    """Train/prefill MLA: expand the latent into per-head K/V, then run the
    standard chunked attention (KV == H after expansion)."""
    hd, r, lk = cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    q = _mla_q(p, cfg, x)                                   # (B,S,H,hd+r)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])          # (B,S,lk+r)
    c, k_rope = ckv[..., :lk], ckv[..., lk:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,r)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["w_uk"])      # (B,S,H,hd)
    v = jnp.einsum("bsr,rhk->bshk", c, p["w_uv"])           # (B,S,H,hd)

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (r,))], axis=-1)
    o = chunked_attention(qf, kf, v, positions, positions, causal=True, chunk=cfg.attn_chunk)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if return_kv:
        return out, (c, k_rope[:, :, 0, :])
    return out


def mla_cache_from_prefill(c: jax.Array, k_rope: jax.Array, length: int, pos: jax.Array) -> MLACache:
    S = c.shape[1]
    if S <= length:
        return MLACache(
            ckv=jnp.pad(c, [(0, 0), (0, length - S), (0, 0)]),
            k_rope=jnp.pad(k_rope, [(0, 0), (0, length - S), (0, 0)]),
            pos=pos,
        )
    off = S % length
    return MLACache(
        ckv=jnp.roll(c[:, -length:], off, axis=1),
        k_rope=jnp.roll(k_rope[:, -length:], off, axis=1),
        pos=pos,
    )


class MLACache(NamedTuple):
    ckv: jax.Array    # (B, L, lk)  — latent KV
    k_rope: jax.Array  # (B, L, r)  — RoPE'd shared key
    pos: jax.Array


def init_mla_cache(cfg: ModelConfig, batch: int, length: int, dtype) -> MLACache:
    return MLACache(
        ckv=jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, length, cfg.rope_head_dim), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def mla_decode_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, cache: MLACache,
) -> tuple[jax.Array, MLACache]:
    """Absorbed-form MLA decode: score via q·W_uk against the latent cache —
    cache stays (L, lk + r) per token, the decode-memory advantage of MLA."""
    B = x.shape[0]
    hd, r, lk, H = cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora_rank, cfg.n_heads
    L = cache.ckv.shape[1]
    pos = cache.pos
    slot = pos % L

    q = _mla_q(p, cfg, x)[:, 0]                             # (B,H,hd+r)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope[:, None], pos[None], cfg.rope_theta)[:, 0]

    ckv_new = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])[:, 0]  # (B, lk+r)
    c_new, kr_new = ckv_new[..., :lk], ckv_new[..., lk:]
    kr_new = apply_rope(kr_new[:, None, None], pos[None], cfg.rope_theta)[:, 0, 0]

    ckv_cache = jax.lax.dynamic_update_slice(cache.ckv, c_new[:, None].astype(cache.ckv.dtype), (0, slot, 0))
    kr_cache = jax.lax.dynamic_update_slice(cache.k_rope, kr_new[:, None].astype(cache.k_rope.dtype), (0, slot, 0))

    # absorbed q: (B,H,lk)
    q_eff = jnp.einsum("bhk,rhk->bhr", q_nope.astype(jnp.float32), p["w_uk"].astype(jnp.float32))
    s = jnp.einsum("bhr,blr->bhl", q_eff, ckv_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhk,blk->bhl", q_rope.astype(jnp.float32), kr_cache.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd + r))
    valid = jnp.arange(L) <= slot
    s = jnp.where(valid[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhl,blr->bhr", w, ckv_cache.astype(jnp.float32))   # (B,H,lk)
    o = jnp.einsum("bhr,rhk->bhk", o_lat, p["w_uv"].astype(jnp.float32))   # (B,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", o[:, None].astype(x.dtype), p["wo"])
    return out, MLACache(ckv=ckv_cache, k_rope=kr_cache, pos=pos + 1)
