"""Model assembly: config → (param defs, init, loss_fn, prefill, decode_step).

Layer stacks are grouped into homogeneous :class:`BlockSpec` groups
(``cfg.layer_plan()``) and executed with ``lax.scan`` over parameters
stacked along a leading layer axis, each block wrapped in
``jax.checkpoint`` (full per-layer remat).  This keeps the HLO size
independent of depth (80-layer internvl2 compiles as fast as 2 layers) and
caps activation residency at one layer — both essential for the
512-device AOT dry-runs.

The LM loss is computed in sequence chunks with vocab-sharded logits so the
(B, S, 128k) logits tensor never materializes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.distributed.sharding import shard_act
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (
    ParamDef,
    abstract_params,
    cross_entropy,
    init_params,
    mlp_apply,
    mlp_defs,
    param_count,
    rms_norm,
)

LOSS_CHUNK = 512  # sequence chunk for the vocab-sharded CE loss


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------

def _norm_def(d: int) -> ParamDef:
    return ParamDef((d,), ("embed",), init="ones")


def _mixer_defs(spec: BlockSpec, cfg: ModelConfig) -> dict:
    if spec.mixer in ("attn", "swa"):
        return attn_lib.attn_defs(cfg)
    if spec.mixer == "mla":
        return attn_lib.mla_defs(cfg)
    if spec.mixer == "mamba":
        return ssm_lib.mamba_defs(cfg)
    raise ValueError(spec.mixer)


def _ff_defs(spec: BlockSpec, cfg: ModelConfig) -> dict:
    if spec.ff == "mlp":
        return mlp_defs(cfg.d_model, cfg.d_ff)
    if spec.ff == "moe":
        return moe_lib.moe_defs(cfg)
    if spec.ff == "none":
        return {}
    raise ValueError(spec.ff)


def _block_defs(spec: BlockSpec, cfg: ModelConfig, cross: bool) -> dict:
    d = {
        "norm1": _norm_def(cfg.d_model),
        "mixer": _mixer_defs(spec, cfg),
        "norm2": _norm_def(cfg.d_model),
        "ff": _ff_defs(spec, cfg),
    }
    if cross:
        d["cross_norm"] = _norm_def(cfg.d_model)
        d["cross"] = attn_lib.attn_defs(
            dataclasses.replace(cfg, n_kv_heads=cfg.n_heads)
        )
    return d


def _stack_defs(defs: dict, n: int) -> dict:
    return jax.tree_util.tree_map(
        lambda d: d.with_leading(n), defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


def model_defs(cfg: ModelConfig) -> dict:
    """Full ParamDef tree for the model."""
    defs: dict[str, Any] = {
        # the embed table's d_model dim uses its own logical axis
        # ('embed_table') that is never FSDP-sharded: its gradient is a
        # scatter-add (backward of the token gather), and XLA's SPMD
        # partitioner cannot handle scatter operands sharded on two axes.
        # The table is small (≤2.3GB bf16 across the pool), so vocab→model
        # sharding alone is plenty.
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed_table"), init="embed"),
        "final_norm": _norm_def(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    defs["groups"] = [
        _stack_defs(_block_defs(spec, cfg, cross=cfg.enc_dec), spec.count)
        for spec in cfg.layer_plan()
    ]
    if cfg.frontend != "none" and not cfg.enc_dec:
        defs["frontend_proj"] = ParamDef((cfg.frontend_dim, cfg.d_model), (None, "embed"))
    if cfg.enc_dec:
        enc_spec = BlockSpec(mixer="attn", ff="mlp", count=cfg.n_enc_layers)
        defs["enc"] = {
            "proj": ParamDef((cfg.frontend_dim or cfg.d_model, cfg.d_model), (None, "embed")),
            "group": _stack_defs(_block_defs(enc_spec, cfg, cross=False), cfg.n_enc_layers),
            "norm": _norm_def(cfg.d_model),
        }
    return defs


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------

def _apply_mixer(spec: BlockSpec, cfg: ModelConfig, p: dict, x, positions):
    if spec.mixer == "attn":
        return attn_lib.gqa_apply(p, cfg, x, positions, window=None)
    if spec.mixer == "swa":
        return attn_lib.gqa_apply(p, cfg, x, positions, window=cfg.sliding_window)
    if spec.mixer == "mla":
        return attn_lib.mla_apply(p, cfg, x, positions)
    if spec.mixer == "mamba":
        return ssm_lib.mamba_apply(p, cfg, x)
    raise ValueError(spec.mixer)


def _apply_ff(spec: BlockSpec, cfg: ModelConfig, p: dict, x):
    if spec.ff == "mlp":
        return mlp_apply(p, x), jnp.float32(0.0)
    if spec.ff == "moe":
        return moe_lib.moe_apply(p, cfg, x)
    return jnp.zeros_like(x), jnp.float32(0.0)


def _block_apply(spec: BlockSpec, cfg: ModelConfig, p: dict, x, positions, memory_kv=None):
    """One transformer block (pre-norm residual). Returns (x, aux)."""
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    x = x + _apply_mixer(spec, cfg, p["mixer"], h, positions)
    if memory_kv is not None:
        h = rms_norm(x, p["cross_norm"], cfg.norm_eps)
        x = x + attn_lib.cross_attn_apply(p["cross"], cfg, h, *memory_kv)
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    ff, aux = _apply_ff(spec, cfg, p["ff"], h)
    x = x + ff
    x = shard_act(x, "batch", "act_seq", "act_embed")
    return x, aux


def _run_groups(cfg: ModelConfig, groups_params, x, positions, memory=None, enc_cross_p=None):
    """Scan each homogeneous group with per-layer remat. Returns (x, aux)."""
    aux_total = jnp.float32(0.0)
    for spec, gp in zip(cfg.layer_plan(), groups_params):
        @jax.checkpoint
        def body(carry, lp, spec=spec):
            xc, aux = carry
            mem_kv = None
            if memory is not None:
                mem_kv = attn_lib.project_memory(lp["cross"], memory)
            xc, a = _block_apply(spec, cfg, lp, xc, positions, mem_kv)
            return (xc, aux + a), None

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), gp)
    return x, aux_total


def _run_encoder(cfg: ModelConfig, enc_params, frames):
    """Bidirectional encoder over frontend frames: (B, Sm, F) → (B, Sm, D)."""
    x = jnp.einsum("bsf,fd->bsd", frames, enc_params["proj"]).astype(frames.dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    @jax.checkpoint
    def body(carry, lp):
        xc = carry
        h = rms_norm(xc, lp["norm1"], cfg.norm_eps)
        xc = xc + attn_lib.encoder_attn_apply(lp["mixer"], cfg, h, positions)
        h = rms_norm(xc, lp["norm2"], cfg.norm_eps)
        xc = xc + mlp_apply(lp["ff"], h)
        return xc, None

    x, _ = jax.lax.scan(body, x, enc_params["group"])
    return rms_norm(x, enc_params["norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# losses (chunked, vocab-sharded)
# ---------------------------------------------------------------------------

def _lm_head(cfg: ModelConfig, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, w)
    return shard_act(logits, "batch", None, "vocab")


def _chunked_ce(cfg: ModelConfig, params, h, labels, mask):
    """CE over sequence chunks; h: (B,S,D), labels/mask: (B,S)."""
    B, S, D = h.shape
    c = min(LOSS_CHUNK, S)
    n = S // c if S % c == 0 else 1
    c = S // n
    hc = h.reshape(B, n, c, D)
    lc = labels.reshape(B, n, c)
    mc = mask.reshape(B, n, c)

    def body(carry, xs):
        tot, cnt = carry
        hh, ll, mm = xs
        logits = _lm_head(cfg, params, hh)
        logits32 = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits32, axis=-1)
        gold = jnp.take_along_axis(logits32, ll[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return (tot + jnp.sum(nll), cnt + jnp.sum(mm)), None

    xs = (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0), jnp.moveaxis(mc, 1, 0))
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), xs)
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _group_cache(spec: BlockSpec, cfg: ModelConfig, batch: int, length: int, dtype):
    if spec.mixer in ("attn", "swa"):
        L = min(length, cfg.sliding_window) if spec.mixer == "swa" and cfg.sliding_window else length
        one = attn_lib.init_kv_cache(cfg, batch, L, dtype)
    elif spec.mixer == "mla":
        one = attn_lib.init_mla_cache(cfg, batch, length, dtype)
    elif spec.mixer == "mamba":
        one = ssm_lib.init_mamba_cache(cfg, batch, dtype)
    else:
        raise ValueError(spec.mixer)
    return jax.tree_util.tree_map(lambda a: jnp.broadcast_to(a, (spec.count, *a.shape)), one)


# ---------------------------------------------------------------------------
# public bundle
# ---------------------------------------------------------------------------

class LanguageModel(NamedTuple):
    cfg: ModelConfig
    defs: dict
    init: Callable            # (key) -> params
    abstract: Callable        # () -> ShapeDtypeStruct tree
    loss_fn: Callable         # (params, batch) -> (loss, metrics)
    forward: Callable         # (params, batch) -> hidden (B,S,D)
    prefill: Callable         # (params, batch, cache_len) -> (last_logits, cache)
    decode_step: Callable     # (params, cache, token, extras) -> (logits, cache)
    init_cache: Callable      # (batch, length, dtype) -> cache
    n_params: int


def build_model(cfg: ModelConfig) -> LanguageModel:
    defs = model_defs(cfg)
    pdt = jnp.dtype(cfg.param_dtype)
    adt = jnp.dtype(cfg.activation_dtype)

    # ----------------------------- train -----------------------------
    def forward(params, batch):
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(adt)
        prefix = 0
        if cfg.frontend != "none" and not cfg.enc_dec:
            fe = batch["frontend"].astype(adt)                    # (B, F, fd)
            fx = jnp.einsum("bfe,ed->bfd", fe, params["frontend_proj"]).astype(adt)
            x = jnp.concatenate([fx, x], axis=1)
            prefix = fe.shape[1]
        x = shard_act(x, "batch", "act_seq", "act_embed")
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        memory = None
        if cfg.enc_dec:
            memory = _run_encoder(cfg, params["enc"], batch["frontend"].astype(adt))
        x, aux = _run_groups(cfg, params["groups"], x, positions, memory=memory)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux, prefix

    def loss_fn(params, batch):
        h, aux, prefix = forward(params, batch)
        labels = batch["labels"]
        if prefix:
            h = h[:, prefix:]
        mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
        ce = _chunked_ce(cfg, params, h, labels, mask.astype(jnp.float32))
        loss = ce + cfg.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    # ----------------------------- serve -----------------------------
    def prefill(params, batch, cache_len: int):
        """Process a full prompt; emit last-token logits + a decode cache."""
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(adt)
        if cfg.frontend != "none" and not cfg.enc_dec:
            fe = batch["frontend"].astype(adt)
            fx = jnp.einsum("bfe,ed->bfd", fe, params["frontend_proj"]).astype(adt)
            x = jnp.concatenate([fx, x], axis=1)
        x = shard_act(x, "batch", "act_seq", "act_embed")
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        pos_final = jnp.asarray(S, jnp.int32)
        memory = None
        if cfg.enc_dec:
            memory = _run_encoder(cfg, params["enc"], batch["frontend"].astype(adt))

        layer_caches, memory_kvs = [], []
        for spec, gp in zip(cfg.layer_plan(), params["groups"]):
            def body(carry, lp, spec=spec):
                xc = carry
                h = rms_norm(xc, lp["norm1"], cfg.norm_eps)
                if spec.mixer in ("attn", "swa"):
                    win = cfg.sliding_window if spec.mixer == "swa" else None
                    o, (k, v) = attn_lib.gqa_apply(
                        lp["mixer"], cfg, h, positions, window=win, return_kv=True
                    )
                    L = min(cache_len, win) if win else cache_len
                    lc = attn_lib.cache_from_prefill(
                        k, v, L, pos_final, quantize=cfg.kv_cache_dtype == "int8"
                    )
                elif spec.mixer == "mla":
                    o, (c, kr) = attn_lib.mla_apply(lp["mixer"], cfg, h, positions, return_kv=True)
                    lc = attn_lib.mla_cache_from_prefill(c, kr, cache_len, pos_final)
                else:
                    o, lc = ssm_lib.mamba_apply(lp["mixer"], cfg, h, return_state=True)
                xc = xc + o
                mem_kv = None
                if cfg.enc_dec:
                    hh = rms_norm(xc, lp["cross_norm"], cfg.norm_eps)
                    mem_kv = attn_lib.project_memory(lp["cross"], memory)
                    xc = xc + attn_lib.cross_attn_apply(lp["cross"], cfg, hh, *mem_kv)
                h = rms_norm(xc, lp["norm2"], cfg.norm_eps)
                ff, _ = _apply_ff(spec, cfg, lp["ff"], h)
                ys = (lc, mem_kv) if cfg.enc_dec else (lc,)
                return xc + ff, ys

            x, ys = jax.lax.scan(body, x, gp)
            layer_caches.append(ys[0])
            if cfg.enc_dec:
                memory_kvs.append(ys[1])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = _lm_head(cfg, params, x[:, -1:, :])
        cache = {"layers": layer_caches}
        if cfg.enc_dec:
            cache["memory_kv"] = memory_kvs
        return logits, cache

    def init_cache(batch: int, length: int, dtype=None):
        dtype = dtype or adt
        cache = {
            "layers": [
                _group_cache(spec, cfg, batch, length, dtype)
                for spec in cfg.layer_plan()
            ]
        }
        if cfg.enc_dec:
            kv, hd = cfg.n_heads, cfg.head_dim  # cross attn uses full heads
            n_dec = cfg.n_layers
            cache["memory_kv"] = [
                (
                    jnp.zeros((spec.count, batch, cfg.enc_seq_len, kv, hd), dtype),
                    jnp.zeros((spec.count, batch, cfg.enc_seq_len, kv, hd), dtype),
                )
                for spec in cfg.layer_plan()
            ]
        return cache

    def decode_step(params, cache, token, extras=None):
        """token: (B, 1) int32 → (logits (B, 1, V), cache')."""
        x = params["embed"][token].astype(adt)
        new_layers = []
        for gi, (spec, gp) in enumerate(zip(cfg.layer_plan(), params["groups"])):
            gcache = cache["layers"][gi]
            mem = cache.get("memory_kv")[gi] if cfg.enc_dec else None

            def body(carry, xs, spec=spec, mem_static=cfg.enc_dec):
                xc = carry
                if mem_static:
                    lp, lc, mk, mv = xs
                else:
                    lp, lc = xs
                h = rms_norm(xc, lp["norm1"], cfg.norm_eps)
                if spec.mixer in ("attn", "swa"):
                    o, lc = attn_lib.gqa_decode_apply(lp["mixer"], cfg, h, lc)
                elif spec.mixer == "mla":
                    o, lc = attn_lib.mla_decode_apply(lp["mixer"], cfg, h, lc)
                else:
                    o, lc = ssm_lib.mamba_decode_apply(lp["mixer"], cfg, h, lc)
                xc = xc + o
                if mem_static:
                    hh = rms_norm(xc, lp["cross_norm"], cfg.norm_eps)
                    xc = xc + attn_lib.cross_attn_apply(lp["cross"], cfg, hh, mk, mv)
                h = rms_norm(xc, lp["norm2"], cfg.norm_eps)
                ff, _ = _apply_ff(spec, cfg, lp["ff"], h)
                return xc + ff, lc

            xs = (gp, gcache, *cache["memory_kv"][gi]) if cfg.enc_dec else (gp, gcache)
            x, new_cache = jax.lax.scan(body, x, xs)
            new_layers.append(new_cache)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = _lm_head(cfg, params, x)
        new = dict(cache)
        new["layers"] = new_layers
        return logits, new

    return LanguageModel(
        cfg=cfg,
        defs=defs,
        init=lambda key: init_params(key, defs, pdt),
        abstract=lambda: abstract_params(defs, pdt),
        loss_fn=loss_fn,
        forward=forward,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        n_params=param_count(defs),
    )
