"""Deterministic synthetic token pipeline for LM training.

Design goals:
  * per-worker *disjoint* streams (the paper's sampling model: each machine
    draws its own iid samples) — worker w, step k sees a batch derived from
    fold_in(seed, w, k), so runs are exactly reproducible and independent
    of how many hosts participate;
  * a learnable signal (orderly n-gram-ish structure), so a few hundred
    steps of a ~100M model measurably reduce loss in the e2e example;
  * a Byzantine *data poisoning* hook (label corruption) — attacks can act
    at the data level, not only the gradient level.

Tokens are generated on-device with jax.random (no host I/O), shaped
``(n_workers, per_worker_batch, seq_len)`` so the leading axis shards over
the mesh's data axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SyntheticTokens(NamedTuple):
    vocab_size: int
    seq_len: int
    seed: int = 0
    # Markov-ish structure: token_{t+1} = (a * token_t + b + noise) % vocab
    a: int = 31
    b: int = 7
    noise_levels: int = 8

    def sample(
        self, worker: jax.Array, step: jax.Array, batch: int,
        b_shift: jax.Array | int = 0,
    ) -> jax.Array:
        """Batch of token sequences (batch, seq_len+1) — inputs + next-token
        labels come from slicing. Deterministic in (seed, worker, step).

        ``b_shift`` (scalar, may be traced) offsets the recurrence's
        additive constant — the *non-iid* axis (DESIGN.md §13): workers
        with different shifts draw from visibly different token
        distributions while the task (predict the recurrence) stays
        learnable.  0 reproduces the iid stream bit-for-bit (the offset
        is integer arithmetic on tokens, so +0 is exact)."""
        key = jax.random.PRNGKey(self.seed)
        key = jax.random.fold_in(key, worker)
        key = jax.random.fold_in(key, step)
        k0, kn = jax.random.split(key)
        x0 = jax.random.randint(k0, (batch,), 0, self.vocab_size)
        noise = jax.random.randint(kn, (batch, self.seq_len + 1), 0, self.noise_levels)

        def body(tok, n):
            nxt = (self.a * tok + self.b + b_shift + n) % self.vocab_size
            return nxt, nxt

        _, seq = jax.lax.scan(body, x0, noise.T)
        return seq.T  # (batch, seq_len+1)


def make_worker_batch(
    stream: SyntheticTokens,
    n_workers: int,
    per_worker_batch: int,
    step: jax.Array,
    poison_mask: jax.Array | None = None,
    skew: jax.Array | None = None,
) -> dict:
    """Global batch with a leading worker axis.

    Returns {'tokens': (W, b, S), 'labels': (W, b, S)}.  If ``poison_mask``
    (W,) is given, poisoned workers get labels shifted by a constant offset
    — a label-flip data attack (gradients of those workers are then honest
    gradients *of corrupted data*, a realistic Byzantine behaviour).

    ``skew`` ((W,) f32, usually ``WorkerProfile.skew``) turns on non-iid
    per-worker streams: worker w's recurrence constant shifts by
    ``round(skew[w] · vocab/4)`` — heterogeneous honest data whose
    gradients genuinely disagree.  ``skew ≡ 0`` is bit-identical to the
    iid pipeline."""
    workers = jnp.arange(n_workers)
    if skew is None:
        seqs = jax.vmap(lambda w: stream.sample(w, step, per_worker_batch))(workers)
    else:
        shifts = jnp.round(skew * (stream.vocab_size // 4)).astype(jnp.int32)
        seqs = jax.vmap(
            lambda w, s: stream.sample(w, step, per_worker_batch, b_shift=s)
        )(workers, shifts)
    tokens, labels = seqs[..., :-1], seqs[..., 1:]
    if poison_mask is not None:
        flipped = (labels + stream.vocab_size // 2) % stream.vocab_size
        labels = jnp.where(poison_mask[:, None, None], flipped, labels)
    return {"tokens": tokens, "labels": labels}
