"""repro.data — data pipeline substrate.

* :mod:`repro.data.problems` — stochastic convex objectives in the paper's
  Section-2.1 model (Assumption 2.2 bounded-deviation estimators).
* :mod:`repro.data.synthetic` — deterministic synthetic token streams for
  LM training with per-worker independent shards and Byzantine corruption
  hooks (label-flip data poisoning).
"""
from repro.data.problems import (
    make_quadratic_problem,
    make_least_squares_problem,
    make_logistic_problem,
)
from repro.data.synthetic import SyntheticTokens, make_worker_batch

__all__ = [
    "make_quadratic_problem",
    "make_least_squares_problem",
    "make_logistic_problem",
    "SyntheticTokens",
    "make_worker_batch",
]
