"""Stochastic convex objectives satisfying the paper's Assumption 2.2.

Every factory returns a :class:`repro.core.solver.Problem` whose
``stoch_grad`` obeys E[g] = ∇f(x) and ‖g − ∇f(x)‖ ≤ V **almost surely**
(we draw noise on the sphere or truncate), with known L, σ, x*, so tests
can check convergence rates against Theorem 3.8/3.9/4.2 exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solver import Problem
from repro.kernels import gradgen


def _sphere_noise(key: jax.Array, d: int, V: float) -> jax.Array:
    """Uniform on the sphere of radius r ≤ V (r ~ V·u^{1/d} keeps E ≈ ball);
    mean-zero and ‖·‖ ≤ V a.s. — the strongest form of Assumption 2.2."""
    nk, rk = jax.random.split(key)
    n = jax.random.normal(nk, (d,))
    n = n / jnp.maximum(jnp.linalg.norm(n), 1e-12)
    r = V * jax.random.uniform(rk) ** (1.0 / d)
    return r * n


def make_quadratic_problem(
    d: int = 16, sigma: float = 1.0, L: float = 10.0, V: float = 1.0,
    D: float | None = None, seed: int = 0,
) -> Problem:
    """f(x) = ½ (x−x*)ᵀ H (x−x*) with spec(H) ⊂ [σ, L]; stochastic gradient
    = ∇f(x) + sphere noise.  σ-strongly convex, L-smooth."""
    rng = np.random.default_rng(seed)
    # random orthogonal basis, eigenvalues log-spaced in [sigma, L]
    Q, _ = np.linalg.qr(rng.normal(size=(d, d)))
    eigs = np.geomspace(sigma, L, d)
    H = jnp.asarray((Q * eigs) @ Q.T, jnp.float32)
    x_star = jnp.asarray(rng.normal(size=(d,)) / np.sqrt(d), jnp.float32)
    x1 = jnp.zeros((d,), jnp.float32)
    if D is None:
        D = float(2.0 * np.linalg.norm(np.asarray(x_star)))

    def f(x):
        r = x - x_star
        return 0.5 * r @ H @ r

    def grad(x):
        return H @ (x - x_star)

    def stoch_grad(key, x):
        return grad(x) + _sphere_noise(key, d, V)

    return Problem(d=d, f=f, grad=grad, stoch_grad=stoch_grad, x1=x1,
                   x_star=x_star, D=D, V=V, L=L, sigma=sigma)


def heterogenize_problem(
    problem: Problem, m: int, skew_max: float, seed: int = 0,
) -> Problem:
    """Non-iid per-worker gradient distributions with a *known* global
    optimum (DESIGN.md §13).

    Worker w's stochastic gradient becomes ``stoch_grad(key, x) +
    skew·C[w]`` for a fixed near-unit-row direction matrix C whose rows
    sum to zero — per-worker means disagree by up to ``skew·cmax``
    (cmax = max ‖C[w]‖ ≈ 1), yet with a fleet-uniform skew the average
    gradient (and hence f, ∇f, x*, and the Theorem-3.8 gap check) is
    exactly the base problem's.  ``V`` is inflated statically
    by ``skew_max`` (the worst per-worker bias any profile on this problem
    may request) so the guard's 2V/4V honest-disagreement radii still
    cover Assumption 2.2; the provenance triple ``het = {'V0', 'cmax',
    'skew_max'}`` lets the campaign report re-derive the bound at each
    row's *realized* skew instead of the worst case.

    The wrapper is the data-layer half of the heterogeneity axis: a run
    only samples through ``het_grad`` when its adversary carries a
    :class:`~repro.scenarios.spec.WorkerProfile`, and ``skew ≡ 0``
    reproduces the iid sampler bit-for-bit (same RNG stream, bias branch
    selected away per worker).
    """
    if skew_max < 0:
        raise ValueError(f"skew_max must be >= 0, got {skew_max}")
    rng = np.random.default_rng(seed)
    # zero-sum near-unit directions: center Gaussian rows, normalize, then
    # center once more — the final projection keeps the row sum *exactly*
    # zero (the invariant the optimum-preservation argument needs; exact
    # for uniform skew, residual O(skew·spread/√m) otherwise) at the cost
    # of row norms ≈ 1; cmax records the realized worst norm for the V
    # inflation
    C = rng.normal(size=(m, problem.d))
    C -= C.mean(axis=0, keepdims=True)
    C /= np.maximum(np.linalg.norm(C, axis=1, keepdims=True), 1e-12)
    C -= C.mean(axis=0, keepdims=True)
    cmax = float(np.linalg.norm(C, axis=1).max())
    C_j = jnp.asarray(C, jnp.float32)
    base = problem.stoch_grad

    def het_grad(key, x, skew, w):
        g = base(key, x)
        # bitwise passthrough at skew == 0 (g + 0.0 would flip -0.0 signs)
        return jnp.where(skew != 0.0, g + skew * C_j[w], g)

    return problem._replace(
        V=problem.V + skew_max * cmax,
        het_grad=het_grad,
        het={"V0": float(problem.V), "cmax": cmax,
             "skew_max": float(skew_max)},
    )


def make_generated_problem(
    d: int = 16, sigma: float = 1.0, L: float = 10.0, V: float = 1.0,
    D: float | None = None, seed: int = 0,
) -> Problem:
    """The quadratic family restated in *counter-generatable* form
    (DESIGN.md §14): f(x) = ½ Σⱼ hⱼ (xⱼ − x*ⱼ)² with hⱼ log-spaced in
    [σ, L] (diagonal H — same spectrum as :func:`make_quadratic_problem`,
    rotated into the coordinate basis so a kernel strip can evaluate its
    slice of ∇f locally), and stochastic gradient = ∇f(x) + noise where
    noise_j = (V/√d)·uniform(−1, 1) from Threefry counters keyed on
    (worker key, coordinate j) — mean-zero and ‖noise‖ ≤ V a.s.
    (Assumption 2.2, box instead of sphere).

    ``stoch_grad`` consumes the standard per-worker key from the solver's
    chain but draws every coordinate through
    :mod:`repro.kernels.gradgen` — the *same* expressions the fused guard
    sweep regenerates in-kernel, so the host and device sides agree
    bit-for-bit under jit.  The returned problem carries the
    :class:`~repro.kernels.gradgen.GenSpec` in ``Problem.gen``, which is
    what ``SolverConfig.generate="kernel"`` requires.
    """
    rng = np.random.default_rng(seed)
    h = jnp.asarray(np.geomspace(sigma, L, d), jnp.float32)
    x_star = jnp.asarray(rng.normal(size=(d,)) / np.sqrt(d), jnp.float32)
    x1 = jnp.zeros((d,), jnp.float32)
    if D is None:
        D = float(2.0 * np.linalg.norm(np.asarray(x_star)))
    noise_scale = jnp.float32(V) / jnp.sqrt(jnp.float32(d))
    coords = jnp.arange(d, dtype=jnp.uint32)

    def f(x):
        r = x - x_star
        return 0.5 * jnp.sum(h * r * r)

    def grad(x):
        return gradgen.mean_grad(h, x, x_star)

    def stoch_grad(key, x):
        kd = gradgen.key_bits(key)
        return (gradgen.mean_grad(h, x, x_star)
                + gradgen.noise_row(kd, coords, noise_scale))

    gen = gradgen.GenSpec(h=h, x_star=x_star, noise_scale=noise_scale,
                          het_dir=jnp.zeros((d,), jnp.float32))
    return Problem(d=d, f=f, grad=grad, stoch_grad=stoch_grad, x1=x1,
                   x_star=x_star, D=D, V=V, L=L, sigma=sigma, gen=gen)


def heterogenize_generated(
    problem: Problem, m: int, skew_max: float, seed: int = 0,
) -> Problem:
    """:func:`heterogenize_problem` for generated problems — the bias
    matrix is constrained to rank 1, ``C[w] = sign[w] · dir`` with a fixed
    unit direction and alternating ±1 worker signs (exact zero fleet sum),
    so a kernel strip folds worker w's bias in as the O(1)-per-worker
    scalar ``skew·sign[w]`` times the streamed ``dir`` strip.  Multiplying
    by ±1 is exact in IEEE arithmetic, so ``skew·(sign·dir)`` on the host
    and ``(skew·sign)·dir`` in the kernel are bitwise identical.
    """
    if problem.gen is None:
        raise ValueError("heterogenize_generated needs a generated problem "
                         "(make_generated_problem); use heterogenize_problem "
                         "for dense bias matrices")
    if skew_max < 0:
        raise ValueError(f"skew_max must be >= 0, got {skew_max}")
    if m % 2:
        raise ValueError(f"rank-1 zero-sum signs need even m, got {m}")
    rng = np.random.default_rng(seed)
    dvec = rng.normal(size=problem.d)
    dvec /= max(np.linalg.norm(dvec), 1e-12)
    dir_j = jnp.asarray(dvec, jnp.float32)
    sign = jnp.asarray(np.where(np.arange(m) % 2 == 0, 1.0, -1.0), jnp.float32)
    C_j = sign[:, None] * dir_j[None, :]
    cmax = float(np.linalg.norm(dvec))
    base = problem.stoch_grad

    def het_grad(key, x, skew, w):
        g = base(key, x)
        return jnp.where(skew != 0.0, g + skew * C_j[w], g)

    return problem._replace(
        V=problem.V + skew_max * cmax,
        het_grad=het_grad,
        het={"V0": float(problem.V), "cmax": cmax,
             "skew_max": float(skew_max)},
        gen=problem.gen._replace(het_dir=dir_j, het_sign=sign),
    )


def make_least_squares_problem(
    d: int = 16, n_data: int = 512, noise: float = 0.1, V: float | None = None,
    seed: int = 0,
) -> Problem:
    """f(x) = (1/2n) Σ (aᵢᵀx − bᵢ)²; f_s picks one row (the paper's
    one-sample-per-iteration model).  V is computed from the data so the
    a.s. bound genuinely holds."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_data, d)) / np.sqrt(d)
    x_true = rng.normal(size=(d,))
    b = A @ x_true + noise * rng.normal(size=(n_data,))
    A_j, b_j = jnp.asarray(A, jnp.float32), jnp.asarray(b, jnp.float32)

    H = (A.T @ A) / n_data
    eigs = np.linalg.eigvalsh(H)
    x_star_np = np.linalg.lstsq(A, b, rcond=None)[0]
    x_star = jnp.asarray(x_star_np, jnp.float32)
    x1 = jnp.zeros((d,), jnp.float32)
    D = float(2.0 * np.linalg.norm(x_star_np) + 1.0)

    def f(x):
        r = A_j @ x - b_j
        return 0.5 * jnp.mean(r * r)

    def grad(x):
        return A_j.T @ (A_j @ x - b_j) / n_data

    def stoch_grad(key, x):
        i = jax.random.randint(key, (), 0, n_data)
        a = A_j[i]
        return a * (a @ x - b_j[i])

    if V is None:
        # sup_x∈ball ‖∇f_s − ∇f‖ over rows, evaluated numerically on the ball boundary
        xs = x_star_np[None, :] + D * rng.normal(size=(64, d)) / np.sqrt(d)
        devs = []
        for x in xs:
            g = A @ x - b
            per_row = A * g[:, None]
            devs.append(np.abs(per_row - (A.T @ g / n_data)[None, :]).sum(-1).max())
        V = float(np.max(devs))

    return Problem(d=d, f=f, grad=grad, stoch_grad=stoch_grad, x1=x1,
                   x_star=x_star, D=D, V=V, L=float(eigs[-1]), sigma=float(max(eigs[0], 0.0)))


def make_logistic_problem(
    d: int = 16, n_data: int = 512, reg: float = 1e-2, seed: int = 0,
) -> Problem:
    """ℓ2-regularized logistic regression; f_s samples one example.
    σ = reg, L ≤ ‖a‖²/4 + reg, V ≤ 2·max‖aᵢ‖ (logistic grad bounded by ‖a‖)."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_data, d)) / np.sqrt(d)
    x_true = rng.normal(size=(d,))
    p = 1.0 / (1.0 + np.exp(-A @ x_true))
    y = (rng.uniform(size=n_data) < p).astype(np.float32) * 2.0 - 1.0
    A_j = jnp.asarray(A, jnp.float32)
    y_j = jnp.asarray(y, jnp.float32)

    def f(x):
        margins = y_j * (A_j @ x)
        return jnp.mean(jnp.logaddexp(0.0, -margins)) + 0.5 * reg * x @ x

    def grad(x):
        margins = y_j * (A_j @ x)
        s = -jax.nn.sigmoid(-margins) * y_j
        return A_j.T @ s / n_data + reg * x

    def stoch_grad(key, x):
        i = jax.random.randint(key, (), 0, n_data)
        a, yy = A_j[i], y_j[i]
        s = -jax.nn.sigmoid(-yy * (a @ x)) * yy
        return a * s + reg * x

    # minimize numerically for x*
    x = jnp.zeros((d,), jnp.float32)
    g = jax.jit(jax.grad(f))
    row_norms = np.linalg.norm(A, axis=1)
    L = float(np.max(row_norms) ** 2 / 4.0 + reg)
    for _ in range(2000):
        x = x - (1.0 / L) * g(x)
    x_star = x
    D = float(2.0 * np.linalg.norm(np.asarray(x_star)) + 1.0)
    V = float(2.0 * np.max(row_norms))

    return Problem(d=d, f=f, grad=grad, stoch_grad=stoch_grad,
                   x1=jnp.zeros((d,), jnp.float32), x_star=x_star,
                   D=D, V=V, L=L, sigma=reg)
