"""Adversary runtime: turns a :class:`repro.scenarios.spec.Scenario` into
the stateful, scheduled attacker the solver's scan body drives.

Three pieces (DESIGN.md §8):

* **per-step mask schedule** — :meth:`ScenarioAdversary.mask_at` re-derives
  good_k's complement from the run's random worker ranks: rotation by
  ``churn_stride`` every ``churn_period`` steps, activation at ``join_step``;
* **attack dispatch** — both coalition phases are evaluated through one
  ``lax.switch`` over :data:`ATTACK_TABLE` (ids, not Python branches, so a
  vmapped campaign traces the body exactly once);
* **feedback adaptation** — :class:`AdvState` is scan-carried next to the
  aggregator state and updated *after* each aggregation from exactly what
  Remark 2.3 grants the adversary: the previous filter decision
  (alive, n_alive) and the realized update ξ (observable from the broadcast
  iterates).  ``adapt_scale`` is a multiplicative-weights search for the
  largest magnitude the aggregator still accepts.

Every attack in the table is the *same function* as the static zoo in
:mod:`repro.core.attacks`, wrapped so one generic ``scale`` knob multiplies
its natural magnitude parameter — ``scale = 1`` reproduces the zoo's
defaults bit-for-bit, which is what the static-equivalence tests pin down.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import attacks as attack_lib

# (name, wrapper) — wrapper(key, grads, mask, ctx, scale) maps the generic
# scale onto the attack's own magnitude knob, scaled from its default.
# ALIE's knob is z_scale: a multiplier on the blades-calibrated z_max
# (z=None in repro.core.attacks — the supporter-count norm-ppf calibration,
# computed in-trace), so scale = 1 sweeps the *calibrated* attack and the
# adaptive multiplicative-weights search probes around it.  New entries
# append at the END: Scenario pytrees store attack *ids*, and id stability
# keeps previously-built campaign grids comparable across versions.
_SCALE_KNOBS: dict[str, tuple[str, float] | None] = {
    "none": None,
    "sign_flip": ("scale", 3.0),
    "random_gaussian": ("scale", 100.0),
    "constant_drift": ("scale", 10.0),
    "alie": ("z_scale", 1.0),
    "inner_product": ("scale", 1.0),
    "hidden_shift": ("c", 0.9),
    "retreat_on_filter": ("scale", 1.0),
    "alie_update": ("z_scale", 1.0),
}

ATTACK_TABLE: tuple[str, ...] = tuple(_SCALE_KNOBS)


def attack_id(name: str) -> int:
    """Integer id of ``name`` in the ``lax.switch`` dispatch table."""
    try:
        return ATTACK_TABLE.index(name)
    except ValueError:
        raise KeyError(
            f"attack {name!r} is not scenario-dispatchable; have {ATTACK_TABLE}"
        ) from None


def _wrap(name: str):
    fn = attack_lib.get_attack(name)
    knob = _SCALE_KNOBS[name]
    if knob is None:
        return lambda key, grads, mask, ctx, scale: fn(key, grads, mask, ctx)
    kwarg, default = knob

    def wrapped(key, grads, mask, ctx, scale):
        return fn(key, grads, mask, ctx, **{kwarg: default * scale})

    return wrapped


_BRANCHES = tuple(_wrap(name) for name in ATTACK_TABLE)

# default magnitude knob per table id ("none" has no knob — 1.0 pads the
# table); the generated path multiplies these by the per-step scale exactly
# as _wrap does, so kernel-side attack rows reproduce the dispatch
# bit-for-bit (repro.kernels.gradgen, DESIGN.md §14)
_KNOB_DEFAULTS = tuple(
    1.0 if knob is None else knob[1] for knob in _SCALE_KNOBS.values()
)


def _dispatch(aid, key, grads, mask, ctx, scale):
    # every branch returns in the *input* gradient dtype: attacks compute
    # in whatever precision their zoo definition uses, but lax.switch
    # needs identical branch types — and under the stats_dtype axis the
    # trainer hands this bf16 rows (an attack's f32 intermediates would
    # otherwise silently promote one branch and not another)
    return jax.lax.switch(
        aid,
        [functools.partial(lambda f, op: f(*op).astype(op[1].dtype), b)
         for b in _BRANCHES],
        (key, grads, mask, ctx, scale),
    )


# bounds of the multiplicative-weights magnitude search
ADAPT_MIN, ADAPT_MAX = 0.1, 8.0
# cosine threshold deciding "the previous update moved our way"
_WIN_COS = 0.3


class AdvState(NamedTuple):
    """Adversary memory, scan-carried next to the aggregator state."""

    adapt_scale: jax.Array   # () multiplicative magnitude multiplier


class ScenarioAdversary(NamedTuple):
    """A Scenario bound to its Byzantine fraction; the solver's ``adversary``
    runtime.  A NamedTuple of (possibly traced) leaves, so constructing it
    *inside* a vmapped function from grid rows is free.

    ``profile`` (optional :class:`repro.scenarios.spec.WorkerProfile`) is
    the per-worker-state axis of DESIGN.md §13: it parameterizes the
    *honest* side of the run (data skew, staleness schedule, participation
    probability), while the Scenario keeps parameterizing the Byzantine
    side.  ``None`` (the default) is the homogeneous iid fleet — no extra
    pytree leaves, the pre-profile trace.

    ``faults`` (optional :class:`repro.scenarios.faults.FaultPlan`) is the
    machine-fault axis of DESIGN.md §15: NaN/Inf rows, garbage strips, and
    bit flips injected after the attack on a schedule independent of the
    Byzantine mask.  ``None`` (the default) keeps the fault machinery out
    of the trace entirely (off-state jaxpr byte-identical, same static
    gating as profiles).
    """

    scenario: "spec.Scenario"  # Scenario pytree of scalar leaves
    alpha: jax.Array           # () f32
    profile: "spec.WorkerProfile | None" = None  # (m,)-leaf pytree or None
    faults: "faults_mod.FaultPlan | None" = None  # scalar-leaf pytree or None

    def n_byz(self, m: int) -> jax.Array:
        # match int(alpha * m): floor, with an epsilon against f32 round-down
        return jnp.floor(self.alpha * m + 1e-6).astype(jnp.int32)

    # -- per-worker schedules (profile-aware; DESIGN.md §13) ----------------
    def stale_period(self, max_delay: int) -> jax.Array:
        """(m,) int32 — worker w refreshes its gradient every ``period[w]``
        steps; the static ``max_delay`` gate caps the schedule."""
        return jnp.minimum(self.profile.delay, max_delay) + 1

    def refresh_at(self, k: jax.Array, max_delay: int) -> jax.Array:
        """(m,) bool — workers recomputing a fresh gradient at step k
        (periodic-refresh staleness model; delay 0 ⇒ refresh every step)."""
        return (k % self.stale_period(max_delay)) == 0

    def staleness_at(self, k: jax.Array, max_delay: int) -> jax.Array:
        """(m,) int32 — age (in steps) of the gradient worker w reports at
        step k under the periodic-refresh schedule."""
        return k % self.stale_period(max_delay)

    def report_at(self, key: jax.Array, mask_k: jax.Array) -> jax.Array:
        """(m,) bool — who reports at step k.  Honest worker w reports with
        probability ``p_report[w]``; Byzantine workers *always* report (the
        worst-case Remark-2.3 adversary never skips a chance to inject —
        this also keeps the ever-Byzantine accounting a pure schedule
        union, the oracle the property tests check against)."""
        p = self.profile.p_report
        return (jax.random.uniform(key, p.shape) < p) | mask_k

    # -- mask schedule -----------------------------------------------------
    def mask_at(self, rank: jax.Array, k: jax.Array) -> jax.Array:
        """(m,) bool Byzantine set at step k from the per-worker ranks."""
        s = self.scenario
        m = rank.shape[0]
        rot = jnp.where(
            s.churn_period > 0,
            (k // jnp.maximum(s.churn_period, 1)) * s.churn_stride,
            0,
        )
        mask = ((rank - rot) % m) < self.n_byz(m)
        return mask & (k >= s.join_step)

    # -- attack ------------------------------------------------------------
    def init_state(self, m: int, d: int) -> AdvState:
        return AdvState(adapt_scale=jnp.ones((), jnp.float32))

    def attack(self, key, grads, mask_k, ctx, state: AdvState) -> jax.Array:
        """Corrupt Byzantine rows per the scenario's per-step rule."""
        s = self.scenario
        scale = s.attack_scale * jnp.where(
            s.adapt_rate > 0, state.adapt_scale, 1.0
        )
        ka, kb = jax.random.split(key)
        ga = _dispatch(s.attack_a, ka, grads, mask_k, ctx, scale)
        gb = _dispatch(s.attack_b, kb, grads, mask_k, ctx, scale)
        n_byz_k = jnp.sum(mask_k)
        crank = jnp.cumsum(mask_k) - 1  # 0-based rank within the byz set
        use_b = (ctx["step"] >= s.switch_step) | (
            crank >= jnp.ceil(s.coalition_frac * n_byz_k)
        )
        # Per-row select = the combinator composition
        # coalition(phase_switch(a, b, switch_step), b, frac) from
        # repro.core.attacks, collapsed to two dispatches instead of three
        # (tests pin the equivalence); honest rows are identical in ga/gb.
        return jnp.where((mask_k & use_b)[:, None], gb, ga)

    def gen_attack_ctx(self, mask_k, ctx, state: AdvState, noise_scale):
        """O(m) attack parameterization for the in-kernel generated path
        (DESIGN.md §14) — the per-worker data :meth:`attack` would need if
        it could not materialize the (m, d) batch.

        Returns ``(slot, params, w_byz)``: per-worker slot (0 honest / 1
        phase-a / 2 phase-b — the same ``mask_k & use_b`` row select the
        dispatch applies), the :data:`repro.kernels.gradgen` parameter
        vector (each phase's effective attack id + precomputed magnitude
        knobs, matching ``_wrap``'s ``default·scale`` convention
        expression-for-expression), and the f32 Byzantine mask for the
        feedback row-sum.  ``retreat_on_filter`` (id 7) is remapped here —
        its coalition-intact condition is a scalar, so it collapses to
        inner_product or none before the kernel ever sees it.
        ``random_gaussian`` (id 2) consumes a PRNG key per row and is not
        generatable; the solver's gate rejects it when the scenario is
        concrete, and a traced id 2 falls through to the honest row.
        """
        s = self.scenario
        m = mask_k.shape[0]
        scale = s.attack_scale * jnp.where(
            s.adapt_rate > 0, state.adapt_scale, 1.0
        )
        n_byz_k = jnp.sum(mask_k)
        crank = jnp.cumsum(mask_k) - 1
        use_b = (ctx["step"] >= s.switch_step) | (
            crank >= jnp.ceil(s.coalition_frac * n_byz_k)
        )
        slot = jnp.where(mask_k, jnp.where(use_b, 2, 1), 0).astype(jnp.int32)

        tg = ctx["true_grad"]
        tg_nrm = jnp.maximum(jnp.linalg.norm(tg), 1e-12)
        zz = attack_lib.alie_z_max(m, n_byz_k)
        V = ctx["V"]
        # per-coordinate value of the zoo's ones(d)/√d direction — the same
        # 1/√d division constant_drift / hidden_shift compute elementwise
        inv_sqrt_d = 1.0 / jnp.sqrt(tg.shape[0])
        # retreat_on_filter's scalar condition, hoisted out of the kernel
        intact = jnp.sum(ctx["alive"] & mask_k) >= jnp.maximum(n_byz_k, 1)
        knob_table = jnp.asarray(_KNOB_DEFAULTS, jnp.float32)

        def pgroup(aid):
            knob = knob_table[aid] * scale
            aid_eff = jnp.where(
                aid == 7, jnp.where(intact, 5, 0), aid
            ).astype(jnp.float32)
            return (aid_eff,
                    -knob,                      # sign_flip factor
                    knob * zz,                  # alie deviation z·z_max
                    knob * V * inv_sqrt_d,      # drift / hidden constant
                    (1.0 + knob) * V)           # inner_product pull

        pa = pgroup(s.attack_a)
        pb = pgroup(s.attack_b)
        params = jnp.stack(
            [*pa, *pb, tg_nrm, jnp.asarray(noise_scale, jnp.float32)]
        ).astype(jnp.float32)
        return slot, params, mask_k.astype(jnp.float32)

    # -- feedback ----------------------------------------------------------
    def update_state(
        self, state: AdvState, mask_k, grads_out, xi, alive, n_alive, ctx
    ) -> AdvState:
        """Multiplicative-weights response to the aggregation outcome.

        ``xi`` was aggregated from exactly the rows in ``grads_out``, so the
        injected direction is judged against the *current* coalition row.
        "Win" = the realized update's residual (ξ minus the honest-mean
        prediction (n_alive/m)·∇f) points along that direction AND the
        coalition is still mostly alive.  On win the magnitude escalates by
        (1+rate); on loss it backs off by 1/(1+rate), clipped to
        [ADAPT_MIN, ADAPT_MAX] — an online probe of the largest deviation
        the aggregator accepts.  No-op when adapt_rate == 0 or no worker is
        currently Byzantine (e.g. before a late join).
        """
        n_byz_k = jnp.sum(mask_k)
        w = mask_k.astype(jnp.float32)[:, None]
        byz_row = jnp.sum(grads_out * w, axis=0) / jnp.maximum(n_byz_k, 1)
        return self.update_state_from_byz_row(
            state, mask_k, byz_row, xi, alive, n_alive, ctx
        )

    def update_state_from_byz_row(
        self, state: AdvState, mask_k, byz_row, xi, alive, n_alive, ctx
    ) -> AdvState:
        """:meth:`update_state` from a precomputed coalition mean row —
        the entry point of the generated path (DESIGN.md §14), where the
        guard's ξ pass returns ``Σ mask·∇ᵢ`` directly and the (m, d) batch
        never exists to reduce over.  Identical trace from the row on."""
        s = self.scenario
        m = mask_k.shape[0]
        n_byz_k = jnp.sum(mask_k)

        dev = byz_row - ctx["true_grad"]
        resid = xi - (n_alive.astype(jnp.float32) / m) * ctx["true_grad"]
        cos = jnp.vdot(resid, dev) / jnp.maximum(
            jnp.linalg.norm(resid) * jnp.linalg.norm(dev), 1e-12
        )
        byz_alive_frac = jnp.sum(alive & mask_k) / jnp.maximum(n_byz_k, 1)
        win = (cos > _WIN_COS) & (byz_alive_frac > 0.5)
        factor = jnp.where(win, 1.0 + s.adapt_rate, 1.0 / (1.0 + s.adapt_rate))
        new_scale = jnp.clip(state.adapt_scale * factor, ADAPT_MIN, ADAPT_MAX)
        adaptive = (s.adapt_rate > 0) & (n_byz_k > 0)
        return AdvState(
            adapt_scale=jnp.where(adaptive, new_scale, state.adapt_scale)
        )
