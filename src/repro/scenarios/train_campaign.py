"""Train campaigns — a grid of *LM training runs* under one ``jit``
(DESIGN.md §10).

The scenario campaign runner (:mod:`repro.scenarios.campaign`) sweeps the
convex harness; this module lifts the same (scenario × α × seed) grid to
**model training**: every grid row is a full ``build_train_step`` run — real
per-worker gradients from a (reduced) LM, the tree-harness flat view, any
guard backend, the scan-carried adversary state — and the whole grid
compiles once and executes as a single ``jit(vmap)``.  The (small, static)
variant axis (aggregator × guard backend, via
:func:`repro.scenarios.campaign.expand_variants`) unrolls inside the same
trace, exactly like the flat campaigns, so ``BENCH_train.json`` gets a
dense-vs-dp leaderboard from one compilation.

Memory note: vmapping N runs replicates params/optimizer/guard state N
times — use reduced configs (the CI smoke runs mamba2-130m at d_model=64
with N ≤ 8 rows).
"""
from __future__ import annotations

import time
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.solver import SolverConfig, byz_rank
from repro.data.synthetic import SyntheticTokens, make_worker_batch
from repro.distributed.trainer import build_train_step, init_train_state
from repro.scenarios.adversary import ScenarioAdversary
from repro.scenarios.campaign import expand_variants
from repro.scenarios.spec import CampaignGrid


class TrainRunStats(NamedTuple):
    """Per-run training summaries; every leaf has leading axis N (the grid)."""

    loss_first: jax.Array        # loss_good_workers at step 0
    loss_final: jax.Array        # loss_good_workers at the last step
    n_alive_final: jax.Array     # |good_T|
    byz_alive_final: jax.Array   # last step's *instantaneous* Byzantine
    #                              survivors (the trainer's byz_alive metric
    #                              — under churn a reformed worker correctly
    #                              staying alive does not count)
    n_byz_ever: jax.Array        # |{workers ever Byzantine}|
    ever_filtered_good: jax.Array  # did the filter ever drop an honest worker


class TrainCampaignResult(NamedTuple):
    stats: dict[str, TrainRunStats]  # variant name → stacked per-run stats
    entries: list[dict]              # grid row metadata (scenario, α, seed)
    wall_s: float                    # steady-state wall-clock of the one jit
    compile_s: float                 # trace + compile overhead (AOT split)
    n_runs: int                      # grid rows per variant
    steps: int


def build_train_campaign_fn(
    model,
    optimizer,
    base_cfg: SolverConfig,
    aggregators: Sequence[str],
    *,
    steps: int,
    stream: SyntheticTokens,
    per_worker_batch: int = 1,
    backends: Sequence[str] | None = None,
    V: float = 0.0,
    D: float = 10.0,
):
    """The jittable ``campaign(grid) -> {variant: TrainRunStats}`` function.
    Adversary leaves are traced (constructed inside the vmapped row from
    grid entries), so one trace covers every scenario/α/seed — and, when
    ``grid.profiles`` carries a stacked :class:`~repro.scenarios.spec.
    WorkerProfile`, every heterogeneous / straggling / partially-
    participating row (DESIGN.md §13): the data skew feeds
    :func:`~repro.data.synthetic.make_worker_batch`, the delay and
    participation schedules feed ``build_train_step``'s gates."""
    cfgs = expand_variants(base_cfg, aggregators, backends)
    W = base_cfg.m

    def campaign(grid: CampaignGrid):
        out = {}
        for name, cfg in cfgs.items():  # static unroll — one trace total

            def one(scn, a, seed, prof, cfg=cfg):
                adv = ScenarioAdversary(scenario=scn, alpha=a, profile=prof)
                train_step = build_train_step(
                    model, optimizer, cfg, V=V, D=D, adversary=adv
                )
                init_key, mask_key, loop_key = jax.random.split(
                    jax.random.PRNGKey(seed), 3
                )
                state = init_train_state(model, optimizer, cfg, init_key,
                                         V=V, D=D, adversary=adv)
                rank = byz_rank(mask_key, W)

                def body(st, i):
                    batch = make_worker_batch(
                        stream, W, per_worker_batch, i,
                        skew=None if prof is None else prof.skew,
                    )
                    st, m = train_step(
                        st, batch, rank, jax.random.fold_in(loop_key, i)
                    )
                    return st, (m["loss_good_workers"], m["good_filtered"],
                                m["byz_alive"])

                st, (losses, goodf, byz_alive) = jax.lax.scan(
                    body, state, jnp.arange(steps)
                )
                return TrainRunStats(
                    loss_first=losses[0],
                    loss_final=losses[-1],
                    n_alive_final=st.prev_n_alive,
                    byz_alive_final=byz_alive[-1].astype(jnp.int32),
                    n_byz_ever=jnp.sum(st.ever_byz).astype(jnp.int32),
                    ever_filtered_good=jnp.any(goodf > 0),
                )

            out[name] = jax.vmap(one)(grid.scenarios, grid.alpha,
                                      grid.seeds, grid.profiles)
        return out

    return campaign


def run_train_campaign(
    model,
    optimizer,
    base_cfg: SolverConfig,
    grid: CampaignGrid,
    *,
    steps: int,
    stream: SyntheticTokens,
    per_worker_batch: int = 1,
    aggregators: Sequence[str] = ("byzantine_sgd",),
    backends: Sequence[str] | None = None,
    V: float = 0.0,
    D: float = 10.0,
) -> TrainCampaignResult:
    """Execute the training grid for every (aggregator × backend) variant
    under one jit; compile and steady-state execution measured separately
    via the AOT lowering split (same convention as
    :func:`repro.scenarios.campaign.run_campaign`)."""
    fn = jax.jit(build_train_campaign_fn(
        model, optimizer, base_cfg, aggregators, steps=steps, stream=stream,
        per_worker_batch=per_worker_batch, backends=backends, V=V, D=D,
    ))
    t0 = time.perf_counter()
    compiled = fn.lower(grid).compile()
    t1 = time.perf_counter()
    out = jax.block_until_ready(compiled(grid))
    t2 = time.perf_counter()
    return TrainCampaignResult(
        stats=out,
        entries=grid.entries,
        wall_s=t2 - t1,
        compile_s=t1 - t0,
        n_runs=grid.n_runs,
        steps=steps,
    )


def summarize_train_campaign(result: TrainCampaignResult,
                             base_cfg: SolverConfig) -> dict:
    """Reduce the stacked per-run stats into the ``BENCH_train.json``
    campaign leaderboard: one row per (scenario, α, variant, seed-median)."""
    import numpy as np

    from repro.scenarios.report import _entry_label

    variants = sorted(result.stats)
    groups: dict[tuple[str, float], list[int]] = {}
    for i, e in enumerate(result.entries):
        groups.setdefault((_entry_label(e), e["alpha"]), []).append(i)

    rows = []
    for (scn, alpha), idx in sorted(groups.items()):
        for name in variants:
            st = result.stats[name]
            rows.append({
                "scenario": scn,
                "alpha": alpha,
                "variant": name,
                "n_seeds": len(idx),
                "loss_first_med": float(np.median(np.asarray(st.loss_first)[idx])),
                "loss_final_med": float(np.median(np.asarray(st.loss_final)[idx])),
                "n_alive_final_min": int(np.asarray(st.n_alive_final)[idx].min()),
                "byz_alive_final_max": int(np.asarray(st.byz_alive_final)[idx].max()),
                "n_byz_ever_max": int(np.asarray(st.n_byz_ever)[idx].max()),
                "ever_filtered_good": bool(
                    np.asarray(st.ever_filtered_good)[idx].any()
                ),
            })
    return {
        "config": {"m": base_cfg.m, "steps": result.steps},
        "variants": variants,
        "n_runs_per_variant": result.n_runs,
        "wall_clock": {"batched_s": result.wall_s,
                       "compile_s": result.compile_s,
                       "runs_total": result.n_runs * len(variants)},
        "leaderboard": rows,
    }
