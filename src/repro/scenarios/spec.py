"""Scenario specs — data-parameterized adversary dynamics.

The paper's Remark-2.3 adversary is *adaptive*: Byzantine workers may
collude, change identity over time, and condition on everything observed so
far.  A :class:`Scenario` captures one point in that space as a pytree of
**scalars only** — every scenario has the same structure and differs only in
leaf values, which is what lets an entire campaign of scenarios stack along
one leading axis and run under a single ``jit(vmap)`` with zero per-run
re-tracing (DESIGN.md §8).

One uniform rule generates the whole family.  At step k, a Byzantine worker
with coalition rank r (its 0-based index within the current Byzantine set)
plays::

    attack_b  if  (k >= switch_step) or (r >= ceil(coalition_frac · n_byz))
    attack_a  otherwise

and the Byzantine *identity* set itself is a schedule: workers join only at
``join_step``, and rotate to the next ``churn_stride`` workers every
``churn_period`` steps.  Special cases of that rule:

* static attack             — attack_a = attack_b, everything else neutral;
* lie-low-then-strike       — attack_a = none, switch_step past the
                              𝔗_A/𝔗_B warmup;
* coalition split           — coalition_frac ∈ (0, 1), switch_step = NEVER;
* churn / late join         — churn_period > 0 / join_step > 0;
* feedback-adaptive         — adapt_rate > 0: the attack magnitude is a
                              multiplicative-weights response to the guard's
                              previous filter decision (see
                              :mod:`repro.scenarios.adversary`).

Attacks are referenced by integer id into
:data:`repro.scenarios.adversary.ATTACK_TABLE` so dispatch is a
``lax.switch`` (vmappable), not a Python branch.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

# sentinel for "this schedule never fires" — any step count in practice is
# far below 2^30 and int32 arithmetic on it cannot overflow when compared
NEVER = 1 << 30


class Scenario(NamedTuple):
    """One adversary dynamic, as a pytree of scalar arrays (vmap-stackable).

    See the module docstring for the per-step rule these parameters feed.
    ``attack_scale`` multiplies each attack's *default* magnitude (so 1.0
    reproduces the static zoo exactly); ``adapt_rate`` > 0 turns on the
    multiplicative feedback response, which further scales the magnitude by
    the scan-carried ``AdvState.adapt_scale``.
    """

    attack_a: jax.Array       # () int32 — id into ATTACK_TABLE
    attack_b: jax.Array       # () int32
    switch_step: jax.Array    # () int32 — k ≥ switch → coalition A plays b
    coalition_frac: jax.Array # () f32 — fraction of byz in coalition A
    churn_period: jax.Array   # () int32 — 0 = static membership
    churn_stride: jax.Array   # () int32 — workers rotated per churn event
    join_step: jax.Array      # () int32 — byz honest before this step
    attack_scale: jax.Array   # () f32 — multiplier on the attack's default
    adapt_rate: jax.Array     # () f32 — 0 = no feedback adaptation


def make_scenario(
    attack: str | None = None,
    *,
    attack_a: str | None = None,
    attack_b: str | None = None,
    switch_step: int = NEVER,
    coalition_frac: float = 1.0,
    churn_period: int = 0,
    churn_stride: int = 1,
    join_step: int = 0,
    attack_scale: float = 1.0,
    adapt_rate: float = 0.0,
) -> Scenario:
    """General constructor; the ``scenario_*`` helpers below name the common
    dynamics.  ``attack`` is shorthand for attack_a = attack_b = attack."""
    from repro.scenarios.adversary import attack_id  # avoid import cycle

    a = attack_a if attack_a is not None else attack
    b = attack_b if attack_b is not None else a
    if a is None:
        raise ValueError("make_scenario needs `attack` or `attack_a`")
    return Scenario(
        attack_a=jnp.asarray(attack_id(a), jnp.int32),
        attack_b=jnp.asarray(attack_id(b), jnp.int32),
        switch_step=jnp.asarray(switch_step, jnp.int32),
        coalition_frac=jnp.asarray(coalition_frac, jnp.float32),
        churn_period=jnp.asarray(churn_period, jnp.int32),
        churn_stride=jnp.asarray(churn_stride, jnp.int32),
        join_step=jnp.asarray(join_step, jnp.int32),
        attack_scale=jnp.asarray(attack_scale, jnp.float32),
        adapt_rate=jnp.asarray(adapt_rate, jnp.float32),
    )


def scenario_static(attack: str, attack_scale: float = 1.0) -> Scenario:
    """The stateless zoo, unchanged — the baseline every dynamic is compared
    against in the campaign report."""
    return make_scenario(attack, attack_scale=attack_scale)


def scenario_lie_low_then_strike(
    attack: str, switch_step: int, attack_scale: float = 1.0
) -> Scenario:
    """Behave honestly until ``switch_step``, then strike — exploits the
    √k growth of the 𝔗_A/𝔗_B thresholds (the longer the wait, the more
    drift the martingale checks tolerate)."""
    return make_scenario(attack_a="none", attack_b=attack,
                         switch_step=switch_step, attack_scale=attack_scale)


def scenario_churn(
    attack: str, period: int, stride: int, attack_scale: float = 1.0
) -> Scenario:
    """Byzantine identity rotates by ``stride`` workers every ``period``
    steps — fresh attackers arrive with clean martingales while previous
    ones go quiet.  The *ever-Byzantine* fraction grows with each rotation;
    keep period·stride sized so it stays below 1/2 if the Theorem-3.8
    regime is to apply (the campaign report checks this per run)."""
    return make_scenario(attack, churn_period=period, churn_stride=stride,
                         attack_scale=attack_scale)


def scenario_late_join(
    attack: str, join_step: int, attack_scale: float = 1.0
) -> Scenario:
    """Workers are honest until ``join_step``, Byzantine afterwards."""
    return make_scenario(attack, join_step=join_step, attack_scale=attack_scale)


def scenario_coalition(
    attack_a: str, attack_b: str, frac: float = 0.5
) -> Scenario:
    """Split coalition: ⌈frac·n_byz⌉ workers play ``attack_a``, the rest
    simultaneously play ``attack_b``."""
    return make_scenario(attack_a=attack_a, attack_b=attack_b,
                         coalition_frac=frac)


def scenario_adaptive(
    attack: str, adapt_rate: float = 0.5, attack_scale: float = 1.0
) -> Scenario:
    """Filter-feedback adaptive magnitude: each step the coalition observes
    (alive, n_alive, prev ξ) and multiplies its magnitude by (1+rate) when
    the previous step's aggregate moved in the attack direction with the
    coalition intact, by 1/(1+rate) otherwise — an online search for the
    largest deviation the aggregator still accepts."""
    return make_scenario(attack, adapt_rate=adapt_rate,
                         attack_scale=attack_scale)


class CampaignGrid:
    """A stacked cartesian product of (scenario × α × seed) runs.

    ``scenarios``/``alpha``/``seeds`` are pytrees/arrays with leading axis
    N = len(entries); ``entries`` keeps the human-readable (name, alpha,
    seed) triple per row for reporting.  Not a pytree — pass the three
    array members into jitted code separately.
    """

    def __init__(self, scenarios: Scenario, alpha: jax.Array,
                 seeds: jax.Array, entries: list[dict]):
        self.scenarios = scenarios
        self.alpha = alpha
        self.seeds = seeds
        self.entries = entries

    @property
    def n_runs(self) -> int:
        return len(self.entries)


def expand_grid(
    named_scenarios: Sequence[tuple[str, Scenario]],
    alphas: Sequence[float],
    seeds: Sequence[int],
) -> CampaignGrid:
    """Cartesian product (scenario × α × seed) → one stacked grid."""
    rows, entries = [], []
    for name, scn in named_scenarios:
        for alpha in alphas:
            for seed in seeds:
                rows.append((scn, float(alpha), int(seed)))
                entries.append({"scenario": name, "alpha": float(alpha),
                                "seed": int(seed)})
    if not rows:
        raise ValueError("empty grid")
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[r[0] for r in rows])
    alpha = jnp.asarray([r[1] for r in rows], jnp.float32)
    seed = jnp.asarray([r[2] for r in rows], jnp.int32)
    return CampaignGrid(stacked, alpha, seed, entries)
