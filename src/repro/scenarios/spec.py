"""Scenario specs — data-parameterized adversary dynamics.

The paper's Remark-2.3 adversary is *adaptive*: Byzantine workers may
collude, change identity over time, and condition on everything observed so
far.  A :class:`Scenario` captures one point in that space as a pytree of
**scalars only** — every scenario has the same structure and differs only in
leaf values, which is what lets an entire campaign of scenarios stack along
one leading axis and run under a single ``jit(vmap)`` with zero per-run
re-tracing (DESIGN.md §8).

One uniform rule generates the whole family.  At step k, a Byzantine worker
with coalition rank r (its 0-based index within the current Byzantine set)
plays::

    attack_b  if  (k >= switch_step) or (r >= ceil(coalition_frac · n_byz))
    attack_a  otherwise

and the Byzantine *identity* set itself is a schedule: workers join only at
``join_step``, and rotate to the next ``churn_stride`` workers every
``churn_period`` steps.  Special cases of that rule:

* static attack             — attack_a = attack_b, everything else neutral;
* lie-low-then-strike       — attack_a = none, switch_step past the
                              𝔗_A/𝔗_B warmup;
* coalition split           — coalition_frac ∈ (0, 1), switch_step = NEVER;
* churn / late join         — churn_period > 0 / join_step > 0;
* feedback-adaptive         — adapt_rate > 0: the attack magnitude is a
                              multiplicative-weights response to the guard's
                              previous filter decision (see
                              :mod:`repro.scenarios.adversary`).

Attacks are referenced by integer id into
:data:`repro.scenarios.adversary.ATTACK_TABLE` so dispatch is a
``lax.switch`` (vmappable), not a Python branch.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

# sentinel for "this schedule never fires" — any step count in practice is
# far below 2^30 and int32 arithmetic on it cannot overflow when compared
NEVER = 1 << 30


class WorkerProfile(NamedTuple):
    """Per-worker state as a first-class axis: fixed-shape ``(m,)`` leaves.

    Rides alongside :class:`Scenario` in the campaign pytree — same
    stacking invariant (every profile has the same structure, only leaf
    values differ), so heterogeneous campaigns still lower in one
    ``jit(vmap)``.  The three leaves parameterize the honest-worker side
    of a run:

    * ``skew``      — per-worker data-skew magnitude; workers draw from a
                      gradient distribution biased by ``skew[w] · C[w]``
                      for a fixed zero-sum direction matrix C (see
                      :func:`repro.data.problems.heterogenize_problem`),
                      so the *global* optimum is unchanged and Theorem 3.8
                      stays checkable at the inflated V.
    * ``delay``     — staleness period: worker w refreshes its reported
                      gradient only on steps with ``k % (delay[w]+1) == 0``
                      (delay 0 = fresh every step), capped by the static
                      ``SolverConfig.max_delay`` gate.
    * ``p_report``  — per-step participation probability; on steps where a
                      worker does not report, the guard must not score it
                      (reporting mask ≠ Byzantine alive mask, DESIGN.md §13).

    The degenerate profile (skew 0, delay 0, p_report 1) is required to be
    bit-identical to a run with no profile at all — pinned by test.
    """

    skew: jax.Array      # (m,) f32 — data-skew magnitude per worker
    delay: jax.Array     # (m,) int32 — staleness period - 1 per worker
    p_report: jax.Array  # (m,) f32 — per-step participation probability


def worker_profile(
    m: int,
    *,
    skew=0.0,
    delay=0,
    p_report=1.0,
) -> WorkerProfile:
    """General constructor — scalars broadcast to ``(m,)``, sequences are
    taken per-worker.  Defaults give the degenerate (iid, fresh, fully
    participating) profile."""

    def vec(x, dtype):
        arr = jnp.asarray(x, dtype)
        if arr.ndim == 0:
            return jnp.full((m,), arr, dtype)
        return arr.reshape((m,)).astype(dtype)

    return WorkerProfile(
        skew=vec(skew, jnp.float32),
        delay=vec(delay, jnp.int32),
        p_report=vec(p_report, jnp.float32),
    )


def profile_iid(m: int) -> WorkerProfile:
    """The degenerate profile — bit-identical semantics to ``profile=None``."""
    return worker_profile(m)


def profile_linear_skew(m: int, skew_max: float) -> WorkerProfile:
    """Heterogeneous data: worker w's gradient bias ramps linearly from 0
    to ``skew_max`` across the fleet."""
    return worker_profile(m, skew=jnp.linspace(0.0, skew_max, m))


def profile_stragglers(m: int, frac: float, delay: int) -> WorkerProfile:
    """The last ``ceil(frac·m)`` workers refresh their gradient only every
    ``delay+1`` steps (periodic staleness)."""
    n_slow = min(max(int(round(frac * m)), 1 if frac > 0 else 0), m)
    delays = jnp.zeros((m,), jnp.int32)
    if n_slow:
        delays = delays.at[m - n_slow:].set(delay)
    return worker_profile(m, delay=delays)


def profile_partial(m: int, p: float) -> WorkerProfile:
    """Every worker reports independently with probability ``p`` per step."""
    return worker_profile(m, p_report=p)


def profile_knobs(profile: WorkerProfile | None) -> dict:
    """Human-readable summary knobs for grid ``entries`` rows."""
    if profile is None:
        return {"skew": 0.0, "max_delay": 0, "participation": 1.0}
    return {
        "skew": float(jnp.max(profile.skew)),
        "max_delay": int(jnp.max(profile.delay)),
        "participation": float(jnp.min(profile.p_report)),
    }


class Scenario(NamedTuple):
    """One adversary dynamic, as a pytree of scalar arrays (vmap-stackable).

    See the module docstring for the per-step rule these parameters feed.
    ``attack_scale`` multiplies each attack's *default* magnitude (so 1.0
    reproduces the static zoo exactly); ``adapt_rate`` > 0 turns on the
    multiplicative feedback response, which further scales the magnitude by
    the scan-carried ``AdvState.adapt_scale``.
    """

    attack_a: jax.Array       # () int32 — id into ATTACK_TABLE
    attack_b: jax.Array       # () int32
    switch_step: jax.Array    # () int32 — k ≥ switch → coalition A plays b
    coalition_frac: jax.Array # () f32 — fraction of byz in coalition A
    churn_period: jax.Array   # () int32 — 0 = static membership
    churn_stride: jax.Array   # () int32 — workers rotated per churn event
    join_step: jax.Array      # () int32 — byz honest before this step
    attack_scale: jax.Array   # () f32 — multiplier on the attack's default
    adapt_rate: jax.Array     # () f32 — 0 = no feedback adaptation


def make_scenario(
    attack: str | None = None,
    *,
    attack_a: str | None = None,
    attack_b: str | None = None,
    switch_step: int = NEVER,
    coalition_frac: float = 1.0,
    churn_period: int = 0,
    churn_stride: int = 1,
    join_step: int = 0,
    attack_scale: float = 1.0,
    adapt_rate: float = 0.0,
) -> Scenario:
    """General constructor; the ``scenario_*`` helpers below name the common
    dynamics.  ``attack`` is shorthand for attack_a = attack_b = attack."""
    from repro.scenarios.adversary import attack_id  # avoid import cycle

    a = attack_a if attack_a is not None else attack
    b = attack_b if attack_b is not None else a
    if a is None:
        raise ValueError("make_scenario needs `attack` or `attack_a`")
    return Scenario(
        attack_a=jnp.asarray(attack_id(a), jnp.int32),
        attack_b=jnp.asarray(attack_id(b), jnp.int32),
        switch_step=jnp.asarray(switch_step, jnp.int32),
        coalition_frac=jnp.asarray(coalition_frac, jnp.float32),
        churn_period=jnp.asarray(churn_period, jnp.int32),
        churn_stride=jnp.asarray(churn_stride, jnp.int32),
        join_step=jnp.asarray(join_step, jnp.int32),
        attack_scale=jnp.asarray(attack_scale, jnp.float32),
        adapt_rate=jnp.asarray(adapt_rate, jnp.float32),
    )


def scenario_static(attack: str, attack_scale: float = 1.0) -> Scenario:
    """The stateless zoo, unchanged — the baseline every dynamic is compared
    against in the campaign report."""
    return make_scenario(attack, attack_scale=attack_scale)


def scenario_lie_low_then_strike(
    attack: str, switch_step: int, attack_scale: float = 1.0
) -> Scenario:
    """Behave honestly until ``switch_step``, then strike — exploits the
    √k growth of the 𝔗_A/𝔗_B thresholds (the longer the wait, the more
    drift the martingale checks tolerate)."""
    return make_scenario(attack_a="none", attack_b=attack,
                         switch_step=switch_step, attack_scale=attack_scale)


def scenario_churn(
    attack: str, period: int, stride: int, attack_scale: float = 1.0
) -> Scenario:
    """Byzantine identity rotates by ``stride`` workers every ``period``
    steps — fresh attackers arrive with clean martingales while previous
    ones go quiet.  The *ever-Byzantine* fraction grows with each rotation;
    keep period·stride sized so it stays below 1/2 if the Theorem-3.8
    regime is to apply (the campaign report checks this per run)."""
    return make_scenario(attack, churn_period=period, churn_stride=stride,
                         attack_scale=attack_scale)


def scenario_late_join(
    attack: str, join_step: int, attack_scale: float = 1.0
) -> Scenario:
    """Workers are honest until ``join_step``, Byzantine afterwards."""
    return make_scenario(attack, join_step=join_step, attack_scale=attack_scale)


def scenario_coalition(
    attack_a: str, attack_b: str, frac: float = 0.5
) -> Scenario:
    """Split coalition: ⌈frac·n_byz⌉ workers play ``attack_a``, the rest
    simultaneously play ``attack_b``."""
    return make_scenario(attack_a=attack_a, attack_b=attack_b,
                         coalition_frac=frac)


def scenario_adaptive(
    attack: str, adapt_rate: float = 0.5, attack_scale: float = 1.0
) -> Scenario:
    """Filter-feedback adaptive magnitude: each step the coalition observes
    (alive, n_alive, prev ξ) and multiplies its magnitude by (1+rate) when
    the previous step's aggregate moved in the attack direction with the
    coalition intact, by 1/(1+rate) otherwise — an online search for the
    largest deviation the aggregator still accepts."""
    return make_scenario(attack, adapt_rate=adapt_rate,
                         attack_scale=attack_scale)


class GridEntry(NamedTuple):
    """Human-readable row metadata for one campaign run — hashable (lives
    in the grid's pytree aux data) and dict-convertible for reports."""

    scenario: str
    alpha: float
    seed: int
    profile: str = "iid"
    skew: float = 0.0
    max_delay: int = 0
    participation: float = 1.0
    fault: str = "none"
    fault_frac: float = 0.0


@dataclasses.dataclass
class CampaignGrid:
    """A stacked cartesian product of (scenario × α × seed × profile ×
    fault) runs.

    ``scenarios``/``alpha``/``seeds``/``profiles``/``faults`` are
    pytrees/arrays with leading axis N = n_runs; ``rows`` keeps one hashable
    :class:`GridEntry` per run for reporting.  Registered as a pytree — the
    array members are children and ``rows`` is aux data, so a grid passes
    into jitted code directly (``jit(campaign)(grid)``) and stacks/indexes
    under ``jax.tree.map``.  ``profiles``/``faults`` are ``None`` for a
    homogeneous / fault-free grid (no pytree leaves — the degenerate case
    adds nothing to the trace).
    """

    scenarios: Scenario
    alpha: jax.Array
    seeds: jax.Array
    rows: tuple
    profiles: WorkerProfile | None = None
    faults: "faults_mod.FaultPlan | None" = None

    def __init__(self, scenarios: Scenario, alpha: jax.Array,
                 seeds: jax.Array, entries,
                 profiles: WorkerProfile | None = None, faults=None):
        self.scenarios = scenarios
        self.alpha = alpha
        self.seeds = seeds
        self.rows = tuple(
            e if isinstance(e, GridEntry) else GridEntry(**e) for e in entries
        )
        self.profiles = profiles
        self.faults = faults

    @property
    def entries(self) -> list[dict]:
        """Backward-compatible list-of-dicts view of :attr:`rows`."""
        return [e._asdict() for e in self.rows]

    @property
    def n_runs(self) -> int:
        return len(self.rows)


def _grid_flatten(grid: CampaignGrid):
    children = (grid.scenarios, grid.alpha, grid.seeds, grid.profiles,
                grid.faults)
    return children, grid.rows


def _grid_unflatten(rows, children):
    scenarios, alpha, seeds, profiles, faults = children
    return CampaignGrid(scenarios, alpha, seeds, rows, profiles, faults)


jax.tree_util.register_pytree_node(CampaignGrid, _grid_flatten, _grid_unflatten)


def _stack_axis(axis: str, trees):
    """Stack one grid axis' per-run pytrees along a new leading dim,
    failing loudly — naming the axis and the offending run — when the
    members disagree in structure or leaf shape (``jnp.stack``'s own error
    names neither, which made a mis-sized profile in a mega-grid a
    needle-in-a-haystack)."""
    treedef0 = jax.tree.structure(trees[0])
    paths0 = jax.tree_util.tree_leaves_with_path(trees[0])
    for i, tree in enumerate(trees[1:], start=1):
        treedef = jax.tree.structure(tree)
        if treedef != treedef0:
            raise ValueError(
                f"expand_grid: axis {axis!r} member {i} has pytree "
                f"structure {treedef}, but member 0 has {treedef0} — every "
                f"member of a grid axis must share one structure")
        for (path, leaf0), (_, leaf) in zip(
                paths0, jax.tree_util.tree_leaves_with_path(tree)):
            if jnp.shape(leaf) != jnp.shape(leaf0):
                raise ValueError(
                    f"expand_grid: axis {axis!r} stacks disagree in "
                    f"leading shape: member {i} leaf "
                    f"{jax.tree_util.keystr(path)!r} has shape "
                    f"{jnp.shape(leaf)}, but member 0 has "
                    f"{jnp.shape(leaf0)} (e.g. WorkerProfiles built for "
                    f"different m)")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def expand_grid(
    named_scenarios: Sequence[tuple[str, Scenario]],
    alphas: Sequence[float],
    seeds: Sequence[int],
    profiles: Sequence[tuple[str, WorkerProfile]] | None = None,
    faults: Sequence[tuple[str, "faults_mod.FaultPlan"]] | None = None,
) -> CampaignGrid:
    """Cartesian product (scenario × α × seed [× profile] [× fault]) → one
    stacked grid.  ``profiles`` is an optional named axis of
    :class:`WorkerProfile` values; when given, every entry row records the
    profile's heterogeneity knobs (max skew / max delay / min
    participation).  ``faults`` is an optional named axis of
    :class:`repro.scenarios.faults.FaultPlan` values (DESIGN.md §15);
    entry rows record the fault mode + fraction."""
    from repro.scenarios import faults as faults_mod

    prof_axis: Sequence[tuple[str, WorkerProfile | None]]
    prof_axis = profiles if profiles is not None else [("iid", None)]
    # a None member of an explicit faults axis is the control cell — it
    # canonicalizes to the inert plan so the axis stacks (every member of a
    # stacked axis must share one pytree structure)
    fault_axis = ([(n, p if p is not None else faults_mod.fault_none())
                   for n, p in faults]
                  if faults is not None else [("none", None)])
    rows, entries, profs, plans = [], [], [], []
    for name, scn in named_scenarios:
        for alpha in alphas:
            for seed in seeds:
                for pname, prof in prof_axis:
                    for fname, plan in fault_axis:
                        rows.append((scn, float(alpha), int(seed)))
                        profs.append(prof)
                        plans.append(plan)
                        entries.append(GridEntry(
                            scenario=name, alpha=float(alpha), seed=int(seed),
                            profile=pname, **profile_knobs(prof),
                            **faults_mod.fault_knobs(plan)))
    if not rows:
        raise ValueError("empty grid")
    stacked = _stack_axis("scenarios", [r[0] for r in rows])
    alpha = jnp.asarray([r[1] for r in rows], jnp.float32)
    seed = jnp.asarray([r[2] for r in rows], jnp.int32)
    stacked_prof = None
    if profiles is not None:
        stacked_prof = _stack_axis("profiles", profs)
    stacked_fault = None
    if faults is not None:
        stacked_fault = _stack_axis("faults", plans)
    return CampaignGrid(stacked, alpha, seed, entries, stacked_prof,
                        stacked_fault)
