"""Scenario engine: adaptive adversaries + the one-jit campaign runner.

The paper's Remark-2.3 adversary may collude, change identity over time,
and condition on everything observed so far; this package makes that class
executable and sweepable (DESIGN.md §8):

* :mod:`repro.scenarios.spec` — :class:`Scenario`, a scalar-leaf pytree
  describing one adversary dynamic (phase switches, coalition splits,
  churn/late-join mask schedules, feedback adaptation), plus the
  ``scenario_*`` constructors and :func:`expand_grid`;
* :mod:`repro.scenarios.adversary` — the runtime the solver's scan body
  drives: per-step mask schedule, ``lax.switch`` attack dispatch, and the
  scan-carried :class:`AdvState` feedback loop;
* :mod:`repro.scenarios.campaign` — :func:`run_campaign`, lowering a whole
  (scenario × α × seed × aggregator) grid into one jitted ``vmap``;
* :mod:`repro.scenarios.report` — seed-aggregated leaderboard /
  degradation / Theorem-3.8-bound records → ``BENCH_scenarios.json``;
* :mod:`repro.scenarios.train_campaign` — the same grid lifted to LM
  training (DESIGN.md §10): :func:`run_train_campaign` vmaps full
  reduced-LM training runs, variants included, under one jit →
  ``BENCH_train.json``.
"""
from repro.scenarios.adversary import (
    ATTACK_TABLE,
    AdvState,
    ScenarioAdversary,
    attack_id,
)
from repro.scenarios.faults import (
    FAULT_TABLE,
    FaultPlan,
    apply_fault_plan,
    fault_bitflip,
    fault_garbage,
    fault_id,
    fault_inf_rows,
    fault_knobs,
    fault_nan_rows,
    fault_none,
    fault_rows,
    make_fault_plan,
)
from repro.scenarios.campaign import (
    GUARD_AGGREGATOR,
    CampaignResult,
    RunStats,
    build_campaign_fn,
    expand_variants,
    run_campaign,
    run_campaign_looped,
)
from repro.scenarios.report import (
    degraded_pairs,
    summarize_campaign,
    theorem38_bound,
    write_report,
)
from repro.scenarios.spec import (
    NEVER,
    CampaignGrid,
    GridEntry,
    Scenario,
    WorkerProfile,
    expand_grid,
    make_scenario,
    profile_iid,
    profile_knobs,
    profile_linear_skew,
    profile_partial,
    profile_stragglers,
    worker_profile,
    scenario_adaptive,
    scenario_churn,
    scenario_coalition,
    scenario_late_join,
    scenario_lie_low_then_strike,
    scenario_static,
)
from repro.scenarios.train_campaign import (
    TrainCampaignResult,
    TrainRunStats,
    build_train_campaign_fn,
    run_train_campaign,
    summarize_train_campaign,
)

__all__ = [
    "ATTACK_TABLE",
    "AdvState",
    "CampaignGrid",
    "CampaignResult",
    "FAULT_TABLE",
    "FaultPlan",
    "GUARD_AGGREGATOR",
    "GridEntry",
    "NEVER",
    "RunStats",
    "Scenario",
    "ScenarioAdversary",
    "WorkerProfile",
    "apply_fault_plan",
    "attack_id",
    "fault_bitflip",
    "fault_garbage",
    "fault_id",
    "fault_inf_rows",
    "fault_knobs",
    "fault_nan_rows",
    "fault_none",
    "fault_rows",
    "make_fault_plan",
    "build_campaign_fn",
    "degraded_pairs",
    "expand_grid",
    "expand_variants",
    "make_scenario",
    "profile_iid",
    "profile_knobs",
    "profile_linear_skew",
    "profile_partial",
    "profile_stragglers",
    "run_campaign",
    "run_campaign_looped",
    "worker_profile",
    "scenario_adaptive",
    "scenario_churn",
    "scenario_coalition",
    "scenario_late_join",
    "scenario_lie_low_then_strike",
    "scenario_static",
    "summarize_campaign",
    "theorem38_bound",
    "write_report",
    "TrainCampaignResult",
    "TrainRunStats",
    "build_train_campaign_fn",
    "run_train_campaign",
    "summarize_train_campaign",
]
