"""Campaign reporting — structured ``BENCH_scenarios.json`` records.

Aggregates a :class:`repro.scenarios.campaign.CampaignResult` across seeds
into the tables the scenario engine exists to produce:

* the **leaderboard** — median + IQR suboptimality and detection-latency
  percentiles per (scenario, α, aggregator);
* the **degradation table** — each dynamic adversary paired with its static
  counterpart, per aggregator: does a rule that survives the static attack
  break under the dynamic one?
* the **guard bound check** — ByzantineSGD's measured gap against the
  Theorem-3.8 prediction, using each run's realized *ever-Byzantine*
  fraction (churn schedules corrupt more workers than the instantaneous α);
* the **aggregator ranking** — the blades-style cross table: mean rank,
  worst-case gap and break count per aggregator over every
  (scenario × α) cell of the leaderboard;
* the **filter timelines** (when the campaign ran with the flight
  recorder armed, DESIGN.md §12) — per (scenario, α, guard variant):
  byzantine-vs-good first-filter-step medians and the Byzantine
  survival curve, the per-step count of corrupted workers the filter
  has not yet caught.

``scripts/render_scenarios.py`` renders the JSON as a console/markdown
table; ``scripts/render_trace.py`` renders the flight-recorder side.
"""
from __future__ import annotations

import json
import math
from typing import Sequence

import numpy as np

from repro.core.solver import Problem, SolverConfig
from repro.obs.provenance import provenance_meta
from repro.scenarios.campaign import CampaignResult

# "survives" / "breaks" default thresholds on f(x̄) − f*, in units of the
# Theorem-3.8 α-term DVα/√T — scale-free across problems
_SURVIVE_MULT = 2.0
_BREAK_MULT = 6.0


def theorem38_bound(
    problem: Problem, cfg: SolverConfig, alpha: float, c: float = 3.0,
    V: float | None = None, m_eff: float | None = None,
) -> float:
    """Empirical form of the Theorem-3.8 guarantee on E[f(x̄)] − f*:

        c · ( DVα/√T  +  DV/√(mT)  +  D²L/T )

    — the Byzantine-perturbation, statistical, and bias/smoothness terms
    with a modest constant (c = 3, the slack ``tests/test_convergence.py``
    already holds the guard to on the logistic problem).

    ``V`` overrides ``problem.V`` — the *realized* heterogeneity-inflated
    deviation bound of a non-iid row (V0 + skew·cmax, usually below the
    worst-case V the problem was built with).  ``m_eff`` overrides ``cfg.m``
    in the statistical term — under partial participation only
    ``report_frac · m`` gradients are averaged per step, so the variance
    term shrinks like 1/√(m_eff·T) (DESIGN.md §13).
    """
    D, L, T = problem.D, problem.L, cfg.T
    V = problem.V if V is None else V
    m = cfg.m if m_eff is None else max(m_eff, 1.0)
    return c * (
        D * V * alpha / math.sqrt(T)
        + D * V / math.sqrt(m * T)
        + D * D * max(L, 1.0) / T
    )


def _entry_label(e: dict) -> str:
    """Leaderboard label for a grid entry: the scenario name, suffixed with
    the worker-profile name for heterogeneous rows (``"alie+stragglers"``)
    so non-iid / straggler / partial-participation cells never collapse
    into their iid counterparts (DESIGN.md §13)."""
    prof = e.get("profile", "iid")
    return e["scenario"] if prof == "iid" else f"{e['scenario']}+{prof}"


def _percentile(xs: np.ndarray, q: float) -> float:
    return float(np.percentile(xs, q)) if xs.size else float("nan")


def _survival_curve(series: np.ndarray, max_points: int = 64) -> list[list[int]]:
    """Subsample a (T,) step series to ≤max_points ``[step, value]``
    change-points (1-based steps, endpoints always kept) — exact under
    step interpolation unless the series changes more often than the
    budget, in which case change-points are strided uniformly."""
    series = np.asarray(series)
    keep = np.flatnonzero(np.diff(series, prepend=series[0] + 1))
    keep = np.union1d(keep, [0, series.size - 1])
    if keep.size > max_points:
        keep = keep[np.linspace(0, keep.size - 1, max_points).astype(int)]
    return [[int(k) + 1, int(series[k])] for k in keep]


def filter_timelines(result: CampaignResult, max_curve_points: int = 64) -> list[dict]:
    """Flight-recorder reduction (DESIGN.md §12): one row per
    (scenario, α, variant) cell of an armed campaign.

    Splits each worker's first-filter step by its ever-Byzantine flag —
    the "first-filter-step" forensics: how fast the guard catches
    corrupted workers, and whether it ever spent a good one — and attaches
    a Byzantine survival curve (surviving-corrupted count per step,
    change-point compressed) from the cell's first seed.  Empty when the
    campaign ran without telemetry.
    """
    groups: dict[tuple[str, float], list[int]] = {}
    for i, e in enumerate(result.entries):
        groups.setdefault((_entry_label(e), e["alpha"]), []).append(i)

    rows = []
    for agg in sorted(result.stats):
        tel = result.stats[agg].telemetry
        if tel is None:
            continue
        ffs = np.asarray(tel["first_filter_step"])   # (N, m), -1 = never
        byz = np.asarray(tel["byz_mask"]).astype(bool)  # (N, m)
        surv = np.asarray(tel["byz_alive"])          # (N, T)
        for (scn, alpha), idx in sorted(groups.items()):
            ii = np.asarray(idx)
            byz_ffs = ffs[ii][byz[ii]]
            good_ffs = ffs[ii][~byz[ii]]
            caught = byz_ffs[byz_ffs > 0].astype(float)
            rep = ii[0]  # representative seed for the curve
            rows.append({
                "scenario": scn,
                "alpha": alpha,
                "aggregator": agg,
                "n_seeds": len(idx),
                "n_byz_workers": int(byz[ii].sum()),
                "n_byz_caught": int((byz_ffs > 0).sum()),
                "first_filter_byz_med": (_percentile(caught, 50)
                                         if caught.size else -1.0),
                "first_filter_byz_p90": (_percentile(caught, 90)
                                         if caught.size else -1.0),
                "n_good_filtered": int((good_ffs > 0).sum()),
                "byz_survival": _survival_curve(surv[rep], max_curve_points),
                "survival_seed": int(result.entries[rep]["seed"]),
            })
    return rows


def campaign_trace_events(result: CampaignResult, log, select=None) -> int:
    """Drain an armed campaign's per-cell rings into an ``EventLog``.

    Emits one ``guard_step`` event per retained ring frame plus a
    ``timeline`` event (first-filter steps + Byzantine mask) per selected
    cell, labeled ``<scenario>/a<alpha>/<variant>/s<seed>``.  ``select``
    filters grid rows (``select(entry) -> bool``, e.g. adaptive scenarios
    only) — an unfiltered large campaign is a lot of JSONL.  Returns the
    number of cells exported.
    """
    import jax

    from repro.obs.telemetry import ring_read

    n_cells = 0
    for agg in sorted(result.stats):
        tel = result.stats[agg].telemetry
        if tel is None:
            continue
        for i, e in enumerate(result.entries):
            if select is not None and not select(e):
                continue
            run = f"{_entry_label(e)}/a{e['alpha']:g}/{agg}/s{e['seed']}"
            row_ring = jax.tree.map(lambda x, i=i: x[i], tel["ring"])
            for frame in ring_read(row_ring):
                log.guard_step(frame, run=run)
            log.event(
                "timeline",
                run=run,
                first_filter_step=np.asarray(tel["first_filter_step"][i]),
                byz_mask=np.asarray(tel["byz_mask"][i]),
                # full-horizon survival curve (the ring only holds the
                # last ring_size frames), change-point compressed
                byz_survival=_survival_curve(np.asarray(tel["byz_alive"][i])),
            )
            n_cells += 1
    return n_cells


def summarize_campaign(
    result: CampaignResult,
    problem: Problem,
    base_cfg: SolverConfig,
    static_of: dict[str, str] | None = None,
    guard_name: str = "byzantine_sgd",
) -> dict:
    """Reduce per-run stats across seeds into the report record.

    ``static_of`` maps each dynamic scenario name to the static scenario it
    should be compared against in the degradation table.
    """
    entries = result.entries
    aggregators = sorted(result.stats)
    groups: dict[tuple[str, float], list[int]] = {}
    for i, e in enumerate(entries):
        groups.setdefault((_entry_label(e), e["alpha"]), []).append(i)

    def _eps(alpha: float) -> tuple[float, float]:
        # per-α thresholds in units of the Theorem-3.8 α-term DVα/√T
        # (floored at one Byzantine worker's worth so α = 0 grids don't
        # degenerate to zero-width bands)
        t = (problem.D * problem.V * max(alpha, 1.0 / base_cfg.m)
             / math.sqrt(base_cfg.T))
        return _SURVIVE_MULT * t, _BREAK_MULT * t

    table = []
    med: dict[tuple[str, float, str], float] = {}
    for (scn, alpha), idx in sorted(groups.items()):
        _, break_eps = _eps(alpha)
        for agg in aggregators:
            st = result.stats[agg]
            g = np.asarray(st.gap_avg)[idx]
            lat = np.asarray(st.detect_latency)[idx]
            lat_hit = lat[lat > 0]
            row = {
                "scenario": scn,
                "alpha": alpha,
                "aggregator": agg,
                "n_seeds": len(idx),
                "gap_med": _percentile(g, 50),
                "gap_p25": _percentile(g, 25),
                "gap_p75": _percentile(g, 75),
                "detect_p50": _percentile(lat_hit, 50) if lat_hit.size else -1,
                "detect_p90": _percentile(lat_hit, 90) if lat_hit.size else -1,
                "detect_rate": float((lat > 0).mean()) if lat.size else 0.0,
                "n_byz_ever_max": int(np.asarray(st.n_byz_ever)[idx].max()),
                "ever_filtered_good": bool(
                    np.asarray(st.ever_filtered_good)[idx].any()
                ),
            }
            row["breaks"] = bool(row["gap_med"] > break_eps)
            table.append(row)
            med[(scn, alpha, agg)] = row["gap_med"]

    # every guard variant ("byzantine_sgd" or "byzantine_sgd@<backend>")
    # gets its own Theorem-3.8 bound check — the bound is realization-
    # agnostic, so a backend that violates it while dense holds is a bug
    guard_keys = [a for a in aggregators
                  if a == guard_name or a.startswith(guard_name + "@")]
    guard_bound = []
    for gk in guard_keys:
        st = result.stats[gk]
        for (scn, alpha), idx in sorted(groups.items()):
            e0 = entries[idx[0]]  # heterogeneity knobs are per group
            alpha_ever = float(
                np.asarray(st.n_byz_ever)[idx].max() / base_cfg.m
            )
            # the theorem's regime is α_ever < 1/2 — churn/late-join
            # schedules can corrupt past it, in which case the bound
            # simply does not apply and the row must say so rather than
            # rendering as a spurious pass/fail (scenario_churn promises
            # this check in its docstring)
            in_regime = alpha_ever < 0.5
            # realized heterogeneity-inflated V: the problem's V was
            # inflated to the worst skew any profile may request; this
            # row's bound uses its own skew via the het provenance triple
            skew = float(e0.get("skew", 0.0))
            v_real = (problem.het["V0"] + skew * problem.het["cmax"]
                      if problem.het is not None else problem.V)
            # realized participation: only report_frac·m gradients are
            # averaged per step, so the statistical term sees m_eff
            m_eff = None
            if st.report_frac is not None:
                m_eff = float(
                    np.asarray(st.report_frac)[idx].mean() * base_cfg.m
                )
            bound = theorem38_bound(problem, base_cfg, alpha_ever,
                                    V=v_real, m_eff=m_eff)
            gap_med = med[(scn, alpha, gk)]
            guard_bound.append({
                "scenario": scn,
                "alpha": alpha,
                "aggregator": gk,
                "alpha_ever": alpha_ever,
                "in_regime": in_regime,
                "profile": e0.get("profile", "iid"),
                "skew": skew,
                "max_delay": int(e0.get("max_delay", 0)),
                "participation": float(e0.get("participation", 1.0)),
                "V_realized": v_real,
                **({"m_eff": m_eff} if m_eff is not None else {}),
                "bound": bound,
                "gap_med": gap_med,
                # None out of regime — the theorem makes no claim there
                "within": bool(gap_med <= bound) if in_regime else None,
            })

    # blades-style cross ranking: collapse the (scenario × α) leaderboard
    # into one row per aggregator — mean rank (1 = best) across every grid
    # cell, worst-case median gap, and break count.  Ranks compare rules at
    # identical adversary settings, so they are scale-free across scenarios
    # of very different absolute difficulty.
    ranking = []
    ranks: dict[str, list[float]] = {a: [] for a in aggregators}
    for (scn, alpha), _ in sorted(groups.items()):
        cell_gaps = sorted(med[(scn, alpha, a)] for a in aggregators)
        for a in aggregators:
            ranks[a].append(1 + cell_gaps.index(med[(scn, alpha, a)]))
    for a in aggregators:
        gaps = [med[k] for k in med if k[2] == a]
        ranking.append({
            "aggregator": a,
            "mean_rank": float(np.mean(ranks[a])),
            "gap_med_median": float(np.median(gaps)),
            "gap_med_worst": float(np.max(gaps)),
            "n_breaks": sum(1 for r in table
                            if r["aggregator"] == a and r["breaks"]),
            "n_cells": len(ranks[a]),
        })
    ranking.sort(key=lambda r: r["mean_rank"])

    degradation = []
    for dyn, stat in (static_of or {}).items():
        for alpha in sorted({e["alpha"] for e in entries}):
            survive_eps, break_eps = _eps(alpha)
            for agg in aggregators:
                gd = med.get((dyn, alpha, agg))
                gs = med.get((stat, alpha, agg))
                if gd is None or gs is None:
                    continue
                degradation.append({
                    "aggregator": agg,
                    "dynamic": dyn,
                    "static": stat,
                    "alpha": alpha,
                    "gap_dynamic": gd,
                    "gap_static": gs,
                    "ratio": gd / max(gs, 1e-12),
                    "survives_static": bool(gs < survive_eps),
                    "degraded": bool(gs < survive_eps and gd > break_eps),
                })

    timelines = filter_timelines(result)

    return {
        "problem": {"d": problem.d, "D": problem.D, "V": problem.V,
                    "L": problem.L, "sigma": problem.sigma},
        "config": {"m": base_cfg.m, "T": base_cfg.T, "eta": base_cfg.eta},
        "aggregators": aggregators,
        "n_runs_per_aggregator": result.n_runs,
        "thresholds": {
            str(alpha): dict(zip(("survive_eps", "break_eps"), _eps(alpha)))
            for alpha in sorted({e["alpha"] for e in entries})
        },
        "wall_clock": {
            "batched_s": result.wall_s,
            "compile_s": result.compile_s,
            "runs_total": result.n_runs * len(aggregators),
        },
        "leaderboard": table,
        "aggregator_ranking": ranking,
        "guard_bound": guard_bound,
        "degradation": degradation,
        **({"filter_timelines": timelines} if timelines else {}),
    }


def write_report(record: dict, path: str = "BENCH_scenarios.json") -> None:
    """Write the record with a provenance ``meta`` block (commit, library
    versions, device, timestamp — DESIGN.md §12); an existing ``meta`` is
    kept (the caller may have stamped richer fields)."""
    record.setdefault("meta", provenance_meta())
    with open(path, "w") as f:
        json.dump(record, f, indent=2)


def degraded_pairs(record: dict) -> Sequence[dict]:
    """Rows of the degradation table where a baseline that survives the
    static attack breaks under the dynamic counterpart — the acceptance
    evidence for the adaptive-adversary claim."""
    return [r for r in record["degradation"] if r["degraded"]]
