"""Campaign runner — a whole grid of runs under one ``jit``.

Every sweep in this repo used to be a Python loop re-tracing
``run_sgd`` once per configuration.  :func:`run_campaign` lowers the entire
(scenario × α × seed) grid for every requested aggregator into a *single*
jitted computation: one ``jax.vmap`` over the stacked grid per aggregator,
the (small, static) aggregator axis unrolled inside the same trace.  One
compile, zero per-run re-traces, and the vmapped scan bodies batch the
per-worker gradient math into (N, m, d) contractions the backend actually
likes (DESIGN.md §8).

Per-run summaries (gap of the averaged iterate, detection latency, …) are
computed in-graph so the host transfer is O(N), not O(N·T); pass
``return_gaps=True`` when the full (N, T) gap traces are needed (e.g. the
multi-seed iterations-to-ε quantiles of ``bench_table1``).

**Guard-backend axis** (DESIGN.md §9).  Next to the aggregator axis the
campaign sweeps guard *realizations*: pass ``backends=("dense", "fused",
"dp_sketch")`` and every ``byzantine_sgd`` entry expands into one variant
per backend, keyed ``"byzantine_sgd@<backend>"`` in the stats dict — still
unrolled inside the same single trace, so one jit produces the
dense-vs-fused-vs-sketch leaderboard.  Explicit ``"byzantine_sgd@fused"``
strings in ``aggregators`` are honored as-is.

A backend entry may carry a statistics-precision suffix —
``"fused@bf16"`` selects the fused realization with
``SolverConfig.stats_dtype='bf16'`` (DESIGN.md §5 Numerics), keyed
``"byzantine_sgd@fused@bf16"`` — so one campaign records the accuracy
cost of the halved guard traffic next to the f32 rows instead of
assuming it.  The pseudo-backend ``"gen"`` (``"gen@bf16"``) selects the
fused realization with in-kernel gradient generation
(``SolverConfig.generate='kernel'``, DESIGN.md §14): worker strips are
regenerated from the counter-based PRNG inside the guard sweep, so the
(N, m, d) gradient batch never materializes.

**Run-axis chunking** (DESIGN.md §14).  ``chunk_size=c`` maps the grid
through ``lax.map`` over ⌈N/c⌉ chunks of a c-wide ``vmap`` instead of one
N-wide ``vmap`` — still a single trace and a single compile, but peak
device memory scales with c, not N, which is what lets ``bench_scenarios``
grow to tens of thousands of rows.  Chunking is bit-transparent: any
``chunk_size`` (including 1 and N) produces bit-identical
:class:`RunStats`, telemetry rings included — pinned by test.
"""
from __future__ import annotations

import time
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.guard_backends import parse_backend_spec
from repro.core.solver import Problem, SolverConfig, run_sgd
from repro.scenarios.adversary import ScenarioAdversary
from repro.scenarios.spec import CampaignGrid


class RunStats(NamedTuple):
    """Per-run summaries; every leaf has leading axis N (the grid)."""

    gap_avg: jax.Array        # f(x̄) − f*   (Theorem-3.8 average iterate)
    gap_final: jax.Array      # f(x_T) − f*
    n_alive_final: jax.Array  # |good_T|
    n_byz_ever: jax.Array     # |{workers ever Byzantine}|
    detect_latency: jax.Array # first k with |good_k| ≤ m − n_byz_ever; -1 = never
    ever_filtered_good: jax.Array  # did the filter ever drop a never-Byzantine worker
    gaps: jax.Array | None = None  # (N, T) traces, only when return_gaps
    telemetry: dict | None = None  # flight-recorder payload (DESIGN.md §12)
    #                                when armed: ring frames / first_filter_step
    #                                / byz_alive / byz_mask, each with leading
    #                                grid axis N; None keeps the historical
    #                                pytree structure
    report_frac: jax.Array | None = None  # mean per-step reporter fraction
    #                                under partial participation (DESIGN.md
    #                                §13); None when everyone reports


class CampaignResult(NamedTuple):
    stats: dict[str, RunStats]   # aggregator name → stacked per-run stats
    entries: list[dict]          # grid row metadata (scenario name, α, seed)
    wall_s: float                # steady-state wall-clock of the one-jit call
    compile_s: float             # first-call (trace + compile) overhead
    n_runs: int                  # grid rows per aggregator
    memory: dict | None = None   # compiled-program memory analysis (arg /
    #                              output / temp bytes) when the backend
    #                              exposes it; the temp term is what run-axis
    #                              chunking bounds (DESIGN.md §14)


def _summarize(problem: Problem, cfg: SolverConfig, res, return_gaps: bool):
    gap_avg = problem.f(res.x_avg) - problem.f(problem.x_star)
    gap_final = problem.f(res.x_final) - problem.f(problem.x_star)
    n_byz_ever = jnp.sum(res.byz_mask)
    hit = res.n_alive <= (cfg.m - n_byz_ever)
    detect = jnp.where(
        jnp.any(hit) & (n_byz_ever > 0),
        jnp.argmax(hit).astype(jnp.int32) + 1,
        jnp.asarray(-1, jnp.int32),
    )
    return RunStats(
        gap_avg=gap_avg,
        gap_final=gap_final,
        n_alive_final=jnp.asarray(res.n_alive[-1], jnp.int32),
        n_byz_ever=n_byz_ever.astype(jnp.int32),
        detect_latency=detect,
        ever_filtered_good=res.ever_filtered_good,
        gaps=res.gaps if return_gaps else None,
        telemetry=None if res.telemetry is None else {
            "ring": res.telemetry.ring,
            "first_filter_step": res.telemetry.first_filter_step,
            "byz_alive": res.telemetry.byz_alive,
            # byz_mask rides along so the report can split timelines into
            # byzantine vs good workers without re-deriving ranks
            "byz_mask": res.byz_mask,
        },
        report_frac=None if res.n_reporting is None else (
            jnp.mean(res.n_reporting.astype(jnp.float32)) / cfg.m
        ),
    )


GUARD_AGGREGATOR = "byzantine_sgd"


def expand_variants(
    base_cfg: SolverConfig,
    aggregators: Sequence[str],
    backends: Sequence[str] | None = None,
) -> dict[str, SolverConfig]:
    """Variant name → SolverConfig for the (aggregator × guard-backend ×
    stats-dtype) axes.

    ``"byzantine_sgd"`` expands to one ``"byzantine_sgd@<backend>"`` variant
    per entry of ``backends`` (when given); ``"agg@backend"`` spellings pass
    through verbatim; stateless aggregators ignore the backend axis.  A
    backend may carry a ``@<stats_dtype>`` suffix (``"fused@bf16"``), which
    sets ``SolverConfig.stats_dtype`` for that variant.  The pseudo-backend
    ``"gen"`` is spelled like a backend on the campaign axis but resolves to
    the fused realization with ``generate='kernel'`` — on-device strip
    generation is a property of how the fused guard sources its rows, not a
    separate step contract, so it is not a registry entry (DESIGN.md §14).
    """
    def _guard_cfg(spec: str) -> SolverConfig:
        be, sdt = parse_backend_spec(spec)
        generate = "kernel" if be == "gen" else base_cfg.generate
        be = "fused" if be == "gen" else be
        return base_cfg._replace(
            aggregator=GUARD_AGGREGATOR, guard_backend=be, generate=generate,
            stats_dtype=sdt if sdt is not None else base_cfg.stats_dtype,
        )

    cfgs: dict[str, SolverConfig] = {}
    for name in aggregators:
        agg, _, be = name.partition("@")
        if be:
            if agg != GUARD_AGGREGATOR:
                raise ValueError(
                    f"{name!r}: only {GUARD_AGGREGATOR!r} has guard backends"
                )
            cfgs[name] = _guard_cfg(be)
        elif agg == GUARD_AGGREGATOR and backends:
            for b in backends:
                cfgs[f"{agg}@{b}"] = _guard_cfg(b)
        else:
            cfgs[name] = base_cfg._replace(aggregator=agg)
    return cfgs


def _chunked_vmap(one, axes, n: int, chunk_size: int | None):
    """``vmap(one)`` over the leading grid axis, optionally through
    ``lax.map`` over ⌈n/chunk_size⌉ chunks so only one chunk of runs is
    live on device at a time.

    The grid is padded up to a whole number of chunks by *repeating the
    last run* (never zeros — a zero Scenario is a real, different run and
    padding must not invent work the trace could diverge on), and the
    padded rows are sliced off the result.  Per-run math is untouched —
    each run sees exactly the leaves it would under a flat vmap — which is
    why any chunk size is bit-identical to the unchunked campaign.
    """
    if chunk_size is None or chunk_size >= n:
        return jax.vmap(lambda t: one(*t))(axes)
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    pad = (-n) % chunk_size
    n_chunks = (n + pad) // chunk_size

    def prep(x):
        if pad:
            x = jnp.concatenate(
                [x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])])
        return x.reshape((n_chunks, chunk_size) + x.shape[1:])

    chunked = jax.lax.map(lambda t: jax.vmap(lambda u: one(*u))(t),
                          jax.tree.map(prep, axes))
    return jax.tree.map(
        lambda x: x.reshape((n_chunks * chunk_size,) + x.shape[2:])[:n],
        chunked)


def build_campaign_fn(
    problem: Problem,
    base_cfg: SolverConfig,
    aggregators: Sequence[str],
    return_gaps: bool = False,
    backends: Sequence[str] | None = None,
    telemetry=None,
    chunk_size: int | None = None,
):
    """The jittable ``campaign(grid) -> {variant: RunStats}`` function.

    ``base_cfg`` supplies everything static: m, T, η, thresholds, and the
    *nominal* α that sizes Krum's f and the trimmed-mean fraction (baselines
    are configured for the nominal fraction; the realized per-run fraction
    is a grid axis the adversary owns).  ``backends`` expands the guard
    aggregator across guard realizations (see :func:`expand_variants`).
    ``telemetry`` (a :class:`repro.obs.TelemetryConfig`) arms the flight
    recorder in every run — the per-cell rings vmap like any other carry,
    so one armed campaign yields an (N, ring_size, …) forensics block per
    variant at the cost of the extra device memory.  ``chunk_size`` bounds
    peak memory by running the grid as ``lax.map`` over chunks of a
    ``chunk_size``-wide vmap (DESIGN.md §14) — still one trace, and
    bit-identical to the unchunked campaign for any chunk size.
    """
    cfgs = expand_variants(base_cfg, aggregators, backends)

    def campaign(grid: CampaignGrid):
        # the grid is a registered pytree (spec.CampaignGrid) — the whole
        # object crosses the jit boundary; row metadata rides the treedef.
        # grid.profiles / grid.faults are either None (homogeneous /
        # fault-free fleet, zero extra leaves) or a stacked
        # WorkerProfile / FaultPlan vmapped like every other axis.
        axes = (grid.scenarios, grid.alpha, grid.seeds, grid.profiles,
                grid.faults)
        n = grid.alpha.shape[0]
        out = {}
        for name, cfg in cfgs.items():  # static unroll — one trace total

            def one(scn, a, seed, prof, plan, cfg=cfg):
                adv = ScenarioAdversary(scenario=scn, alpha=a, profile=prof,
                                        faults=plan)
                res = run_sgd(problem, cfg, jax.random.PRNGKey(seed),
                              adversary=adv, telemetry=telemetry)
                return _summarize(problem, cfg, res, return_gaps)

            out[name] = _chunked_vmap(one, axes, n, chunk_size)
        return out

    return campaign


def compiled_memory(compiled) -> dict | None:
    """Byte-level memory analysis of a compiled campaign — argument/output
    footprint plus the XLA temp allocation, which is the term run-axis
    chunking bounds.  ``None`` when the backend does not expose the
    analysis (the field stays a no-op on such platforms)."""
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_size_in_bytes": int(ma.argument_size_in_bytes),
            "output_size_in_bytes": int(ma.output_size_in_bytes),
            "temp_size_in_bytes": int(ma.temp_size_in_bytes),
            "generated_code_size_in_bytes": int(
                ma.generated_code_size_in_bytes),
        }
    except Exception:
        return None
    mem["peak_bytes"] = (mem["argument_size_in_bytes"]
                         + mem["output_size_in_bytes"]
                         + mem["temp_size_in_bytes"])
    return mem


def run_campaign(
    problem: Problem,
    base_cfg: SolverConfig,
    grid: CampaignGrid,
    aggregators: Sequence[str],
    return_gaps: bool = False,
    backends: Sequence[str] | None = None,
    telemetry=None,
    chunk_size: int | None = None,
) -> CampaignResult:
    """Execute the full grid for every (aggregator × backend) variant under
    one jit.

    Trace + compile are paid once for the whole campaign and measured
    separately via AOT lowering (``compile_s``); ``wall_s`` is the pure
    execution of all ``n_variants × grid.n_runs`` runs.  ``chunk_size``
    caps how many runs are in flight at once (:func:`_chunked_vmap`);
    the resulting peak-memory profile is recorded in ``memory``.
    """
    fn = jax.jit(build_campaign_fn(problem, base_cfg, aggregators,
                                   return_gaps, backends, telemetry,
                                   chunk_size))
    t0 = time.perf_counter()
    compiled = fn.lower(grid).compile()
    t1 = time.perf_counter()
    out = jax.block_until_ready(compiled(grid))
    t2 = time.perf_counter()
    return CampaignResult(
        stats=out,
        entries=grid.entries,
        wall_s=t2 - t1,
        compile_s=t1 - t0,
        n_runs=grid.n_runs,
        memory=compiled_memory(compiled),
    )


def run_campaign_looped(
    problem: Problem,
    base_cfg: SolverConfig,
    grid: CampaignGrid,
    aggregators: Sequence[str],
    backends: Sequence[str] | None = None,
) -> tuple[dict[str, list[float]], float]:
    """The pre-campaign baseline: one eager ``run_sgd`` per grid row per
    variant, re-tracing the scan every call — exactly how the sweeps in
    ``examples/`` and ``benchmarks/`` used to run.  Returns per-variant
    gap lists and total wall-clock, for the batched-vs-looped comparison
    recorded in ``BENCH_scenarios.json``."""
    t0 = time.perf_counter()
    cfgs = expand_variants(base_cfg, aggregators, backends)
    gaps: dict[str, list[float]] = {name: [] for name in cfgs}
    f_star = problem.f(problem.x_star)
    for name, cfg in cfgs.items():
        for i in range(grid.n_runs):
            scn = jax.tree.map(lambda x, i=i: x[i], grid.scenarios)
            prof = (None if grid.profiles is None
                    else jax.tree.map(lambda x, i=i: x[i], grid.profiles))
            plan = (None if grid.faults is None
                    else jax.tree.map(lambda x, i=i: x[i], grid.faults))
            adv = ScenarioAdversary(scenario=scn, alpha=grid.alpha[i],
                                    profile=prof, faults=plan)
            res = run_sgd(problem, cfg, jax.random.PRNGKey(grid.seeds[i]),
                          adversary=adv)
            gaps[name].append(float(problem.f(res.x_avg) - f_star))
    return gaps, time.perf_counter() - t0
