"""Fault plans — adversarial *values* as a first-class campaign axis.

The paper's threat model lets Byzantine machines "behave arbitrarily", and
arbitrary includes machine-level garbage the attack zoo never emits: NaN
rows from a diverged replica, Inf rows from an overflow, huge-magnitude
strips from a desynced parameter server, silent bit flips from faulty HBM
(Chen, Su & Xu 2017 treat exactly these as the Byzantine baseline case).
A :class:`FaultPlan` injects them into the worker gradient batch *after*
the scenario attack, on a schedule, hitting workers independently of the
Byzantine mask — i.e. mostly *honest* workers, which is what makes the
sanitize gate (DESIGN.md §15) a separate mechanism from the filter: the
filter bounds adversarial statistics, the sanitizer bounds non-finite
poison that would otherwise NaN every median and Gram product regardless
of which worker emitted it.

Same stacking contract as :class:`repro.scenarios.spec.Scenario`: a plan
is a pytree of **scalar leaves only**, so a campaign stacks a faults axis
along the grid's leading dim and the whole sweep still lowers in one
``jit(vmap)``.  Fault modes dispatch through one ``lax.switch`` over
:data:`FAULT_TABLE` (append new modes at the END — plans store ids).

Which rows, when::

    faulty  = rank >= m - floor(frac · m)          # top ranks; the Byzantine
                                                   # set is the BOTTOM ranks,
                                                   # so faults land on honest
                                                   # workers until the two
                                                   # regions overlap
    active  = (k >= start_step) and ((k - start_step) % period == 0)

Note ``garbage`` is *finite* corruption — enormous but representable
values that the Algorithm-1 filter itself must catch; only ``nan_rows``,
``inf_rows``, and (probabilistically) ``bitflip`` produce the non-finite
values the sanitize stage quarantines.  The chaos harness sweeps both
kinds on purpose.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

FAULT_TABLE: tuple[str, ...] = (
    "none", "nan_rows", "inf_rows", "garbage", "bitflip",
)

# deterministic sub-key tag for fault randomness (prime, same convention as
# the participation fold-in 7919 in the solver)
FAULT_KEY_TAG = 104729


def fault_id(name: str) -> int:
    try:
        return FAULT_TABLE.index(name)
    except ValueError:
        raise KeyError(
            f"fault mode {name!r} unknown; have {FAULT_TABLE}"
        ) from None


class FaultPlan(NamedTuple):
    """One fault-injection schedule, as a pytree of scalar arrays."""

    mode: jax.Array        # () int32 — id into FAULT_TABLE
    frac: jax.Array        # () f32 — fraction of the fleet hit
    start_step: jax.Array  # () int32 — first step faults can fire
    period: jax.Array      # () int32 — fire every `period` steps (≥ 1)
    magnitude: jax.Array   # () f32 — garbage amplitude (mode-specific)


def make_fault_plan(
    mode: str = "none",
    *,
    frac: float = 0.0,
    start_step: int = 0,
    period: int = 1,
    magnitude: float = 1e30,
) -> FaultPlan:
    return FaultPlan(
        mode=jnp.asarray(fault_id(mode), jnp.int32),
        frac=jnp.asarray(frac, jnp.float32),
        start_step=jnp.asarray(start_step, jnp.int32),
        period=jnp.asarray(max(int(period), 1), jnp.int32),
        magnitude=jnp.asarray(magnitude, jnp.float32),
    )


def fault_none() -> FaultPlan:
    """Armed-but-inert plan: mode 0 leaves every gradient bit-identical
    (pinned by test) — the control cell of a fault sweep."""
    return make_fault_plan("none")


def fault_nan_rows(frac: float, *, start_step: int = 0, period: int = 1) -> FaultPlan:
    """Affected workers report all-NaN rows (diverged replica)."""
    return make_fault_plan("nan_rows", frac=frac, start_step=start_step,
                           period=period)


def fault_inf_rows(frac: float, *, start_step: int = 0, period: int = 1) -> FaultPlan:
    """Affected workers report ±Inf rows (overflowed accumulator)."""
    return make_fault_plan("inf_rows", frac=frac, start_step=start_step,
                           period=period)


def fault_garbage(
    frac: float, *, magnitude: float = 1e30, start_step: int = 0, period: int = 1,
) -> FaultPlan:
    """Affected workers report finite garbage of amplitude ``magnitude`` on
    a coordinate strip — the filter's job, not the sanitizer's."""
    return make_fault_plan("garbage", frac=frac, magnitude=magnitude,
                           start_step=start_step, period=period)


def fault_bitflip(frac: float, *, start_step: int = 0, period: int = 1) -> FaultPlan:
    """One random bit of each affected element flips (faulty memory) —
    silent corruption that is sometimes huge, sometimes non-finite,
    sometimes a rounding-level nudge."""
    return make_fault_plan("bitflip", frac=frac, start_step=start_step,
                           period=period)


def fault_knobs(plan: FaultPlan | None) -> dict:
    """Human-readable summary knobs for grid ``entries`` rows (host-side
    concrete plans only)."""
    if plan is None:
        return {"fault": "none", "fault_frac": 0.0}
    return {
        "fault": FAULT_TABLE[int(plan.mode)],
        "fault_frac": float(plan.frac),
    }


def n_faulty(plan: FaultPlan, m: int) -> jax.Array:
    # floor with the same epsilon convention as ScenarioAdversary.n_byz
    return jnp.floor(plan.frac * m + 1e-6).astype(jnp.int32)


def fault_rows(plan: FaultPlan, rank: jax.Array, k: jax.Array) -> jax.Array:
    """(m,) bool — workers whose row is corrupted at step ``k``.  The
    solver folds this into its ever-Byzantine accounting; mode 0 injects
    nothing and contributes nothing."""
    m = rank.shape[0]
    faulty = rank >= (m - n_faulty(plan, m))
    active = (k >= plan.start_step) & (
        ((k - plan.start_step) % jnp.maximum(plan.period, 1)) == 0
    )
    return (plan.mode != 0) & faulty & active


def _uint_dtype(dtype) -> jnp.dtype:
    return jnp.dtype(f"uint{jnp.dtype(dtype).itemsize * 8}")


def apply_fault_plan(
    plan: FaultPlan, key: jax.Array, grads: jax.Array,
    rank: jax.Array, k: jax.Array,
) -> jax.Array:
    """Corrupt ``grads`` (m, d) per the plan at step ``k``; pure and
    vmappable.  Mode 0 (and any inactive step) returns the input values
    unchanged."""
    m, d = grads.shape
    dtype = grads.dtype
    faulty = rank >= (m - n_faulty(plan, m))
    active = (k >= plan.start_step) & (
        ((k - plan.start_step) % jnp.maximum(plan.period, 1)) == 0
    )
    row = (faulty & active)[:, None]

    def _none(op):
        key, grads, row, mag = op
        return grads

    def _nan(op):
        key, grads, row, mag = op
        return jnp.where(row, jnp.asarray(jnp.nan, dtype), grads)

    def _inf(op):
        key, grads, row, mag = op
        # alternate ±Inf by coordinate parity so the row has no well-defined
        # direction even before sanitization
        sign = jnp.where(jnp.arange(d) % 2 == 0, jnp.inf, -jnp.inf)
        return jnp.where(row, sign.astype(dtype)[None, :], grads)

    def _garbage(op):
        key, grads, row, mag = op
        strip = (jnp.arange(d) % 4 == 0)[None, :]
        noise = jax.random.uniform(
            key, (m, d), jnp.float32, minval=-1.0, maxval=1.0
        ) * mag
        return jnp.where(row & strip, noise.astype(dtype), grads)

    def _bitflip(op):
        key, grads, row, mag = op
        udt = _uint_dtype(dtype)
        nbits = jnp.dtype(udt).itemsize * 8
        bits = jax.lax.bitcast_convert_type(grads, udt)
        which = jax.random.randint(key, (m, d), 0, nbits, jnp.int32)
        flipped = bits ^ (jnp.asarray(1, udt) << which.astype(udt))
        return jnp.where(row, jax.lax.bitcast_convert_type(flipped, dtype), grads)

    return jax.lax.switch(
        plan.mode,
        (_none, _nan, _inf, _garbage, _bitflip),
        (key, grads, row, plan.magnitude),
    )
