"""Guard flight recorder — the in-trace half of the observability layer
(DESIGN.md §12).

Algorithm 1's value is *which* workers it filters and *when*: the
martingale deviations |A_i − A_med|, ‖B_i − B_med‖, ‖∇_i − ∇_med‖ crossing
their thresholds 𝔗_A / 𝔗_B / 4V.  The solver and trainer only surface
post-hoc aggregates (gap_med, byz_alive), so per-step forensics used to
require hand-rolled trajectory diffing.  This module captures them *inside*
the jitted scan with zero host round-trips:

* **frame** — one step's filter forensics as a flat dict with a fixed key
  set (:data:`FRAME_SCHEMA`): per-worker martingale deviations vs their
  thresholds, the alive mask, ξ norm, Gram-resync drift, the auto-V
  estimate, and the adaptive adversary's feedback scale.  Every guard
  backend and every baseline aggregator emits the *same* schema — keys a
  producer cannot know carry a NaN sentinel, so stacked frames have stable
  pytree structure on every branch of every campaign.
* **ring buffer** — :class:`TelemetryRing`, a fixed-size on-device buffer
  of frames written with one ``dynamic_update_index_in_dim`` per step and
  transferred once at the end of the scan (or once per ``log_every`` chunk
  in the trainer, riding the existing stacked-metrics transfer).

Everything is gated on :class:`TelemetryConfig` at *trace time*: with
``enabled=False`` (or ``telemetry=None``) no ring is carried, no frame is
built, and the jaxpr is identical to the pre-telemetry program — the
off-state is free, which is what lets the flag default into every entry
point (``run_sgd``, ``run_campaign``, ``build_train_step``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class TelemetryConfig(NamedTuple):
    """Static switch + ring sizing for the flight recorder.

    A hashable NamedTuple of Python scalars, so it closes over traced
    functions (and feeds ``functools.partial``/``static_argnames``) without
    retracing surprises.  ``ring_size`` bounds device memory: the ring
    keeps the *last* ``ring_size`` frames, which is the window every
    debugging session so far actually needed (the steps around a filter
    firing), at O(ring · m) floats instead of O(T · m).
    """

    enabled: bool = True
    ring_size: int = 128


def telemetry_on(telemetry: TelemetryConfig | None) -> bool:
    """None-safe static gate — the one expression every producer checks."""
    return telemetry is not None and telemetry.enabled


# the event schema (DESIGN.md §12): per-worker series + per-step scalars.
# One schema for every producer — guard backends fill the filter keys,
# the solver/trainer fill step/xi_norm/adapt_scale, baselines fill only
# alive/n_alive; everything else is jnp.nan.  Keys are stable API: the
# JSONL events, the ring pytree, and the trainer's tel/<key> metrics all
# spell them identically.
PER_WORKER_KEYS = (
    "dev_a",    # |A_i − A_med| — scalar-martingale deviation (vs thr_a)
    "dist_b",   # ‖B_i − B_med‖ — vector-martingale distance (vs thr_b)
    "dist_g",   # ‖∇_i − ∇_med‖ — fresh-gradient distance   (vs thr_g)
    "alive",    # good_k membership (1.0 / 0.0)
)
SCALAR_KEYS = (
    "step",        # 1-based iteration the frame describes
    "thr_a",       # 𝔗_A = 4DV√(kC)
    "thr_b",       # 𝔗_B = 4V√(kC)
    "thr_g",       # the 4V fresh-gradient radius
    "n_alive",     # |good_k|
    "xi_norm",     # ‖ξ_k‖ — the realized update magnitude
    "v_est",       # online auto-V (dp backends; NaN elsewhere)
    "gram_drift",  # ‖G_inc − B Bᵀ‖_F at resync steps (fused; NaN between)
    "adapt_scale", # AdvState feedback magnitude (NaN for static attacks)
    # per-worker-state axis (DESIGN.md §13) — appended so historical
    # packed rings stay decodable by schema length; NaN when the run has
    # no WorkerProfile (everyone reports, nothing is stale)
    "n_reporting", # |{workers delivering this step}| under partial participation
    "staleness",   # mean gradient age in steps under the delay schedule
    # fault-domain axis (DESIGN.md §15) — appended last, same decodability
    # rule; NaN when the sanitize gate is off
    "n_nonfinite", # |{workers whose row held NaN/Inf this step}| under sanitize
)
FRAME_SCHEMA = PER_WORKER_KEYS + SCALAR_KEYS


def empty_frame(m: int) -> dict:
    """A full-schema frame of NaN sentinels (f32 leaves, stable keys)."""
    frame = {k: jnp.full((m,), jnp.nan, jnp.float32) for k in PER_WORKER_KEYS}
    frame.update({k: jnp.full((), jnp.nan, jnp.float32) for k in SCALAR_KEYS})
    return frame


def baseline_frame(m: int, alive: jax.Array, n_alive: jax.Array) -> dict:
    """What a stateless/stateful baseline can report: who survived."""
    frame = empty_frame(m)
    frame["alive"] = alive.astype(jnp.float32)
    frame["n_alive"] = jnp.asarray(n_alive, jnp.float32)
    return frame


def guard_frame(m: int, diag: dict, alive: jax.Array) -> dict:
    """A guard backend's frame from its ``filter_update`` diagnostics.

    All four backends route through
    :func:`repro.core.byzantine_sgd.filter_update`, whose diag carries the
    per-worker deviations and thresholds — so one converter keeps the four
    backends on one schema by construction.  ``v_est`` / ``gram_drift``
    are filled when the producing backend computes them (dp auto-V, the
    fused incremental-Gram resync) and stay NaN otherwise.
    """
    frame = baseline_frame(m, alive, diag["n_alive"])
    frame["dev_a"] = diag["dev_a"].astype(jnp.float32)
    frame["dist_b"] = diag["dist_b"].astype(jnp.float32)
    frame["dist_g"] = diag["dist_g"].astype(jnp.float32)
    frame["thr_a"] = jnp.asarray(diag["threshold_A"], jnp.float32)
    frame["thr_b"] = jnp.asarray(diag["threshold_B"], jnp.float32)
    frame["thr_g"] = jnp.asarray(diag["threshold_grad"], jnp.float32)
    for opt in ("v_est", "gram_drift", "n_nonfinite"):
        if opt in diag:
            frame[opt] = jnp.asarray(diag[opt], jnp.float32)
    return frame


# ---------------------------------------------------------------------------
# on-device ring buffer
# ---------------------------------------------------------------------------

class TelemetryRing(NamedTuple):
    """Fixed-size frame buffer, scan-carried and vmap-able.

    Frames are stored *packed*: the whole schema flattens to one
    ``(|PER_WORKER_KEYS|·m + |SCALAR_KEYS|,)`` lane (worker blocks first,
    scalar lanes after), so a push is one concatenate + **one** dynamic
    update regardless of schema width.  (The obvious one-buffer-per-key
    layout costs one update op per key per step, which at campaign shapes
    is more in-scan work than the guard step it observes; the packed
    layout keeps the recorder's footprint flat as the schema grows.)
    ``head`` counts total pushes (monotonic), so slot validity and order
    are recoverable on the host: slot ``head % ring_size`` is the oldest
    once the ring has wrapped.
    """

    lanes: jax.Array    # (ring, |PER_WORKER_KEYS|·m + |SCALAR_KEYS|) f32
    head: jax.Array     # () int32 — total frames ever pushed

    @property
    def m(self) -> int:
        return (self.lanes.shape[-1] - len(SCALAR_KEYS)) // len(PER_WORKER_KEYS)


def ring_init(m: int, ring_size: int) -> TelemetryRing:
    width = len(PER_WORKER_KEYS) * m + len(SCALAR_KEYS)
    return TelemetryRing(
        lanes=jnp.full((ring_size, width), jnp.nan, jnp.float32),
        head=jnp.zeros((), jnp.int32),
    )


def ring_push(ring: TelemetryRing, frame: dict) -> TelemetryRing:
    """Write ``frame`` at slot ``head % ring_size`` — one packed lane,
    one in-place dynamic update: the whole per-step telemetry cost."""
    idx = ring.head % ring.lanes.shape[0]
    lane = jnp.concatenate(
        [frame[k].astype(jnp.float32) for k in PER_WORKER_KEYS]
        + [jnp.asarray(frame[k], jnp.float32)[None] for k in SCALAR_KEYS]
    )
    return TelemetryRing(
        lanes=jax.lax.dynamic_update_index_in_dim(ring.lanes, lane, idx, 0),
        head=ring.head + 1,
    )


def ring_read(ring: TelemetryRing) -> list[dict]:
    """Host-side drain: the valid frames in push order (oldest first),
    unpacked back into full-schema dicts.

    Accepts device or already-transferred numpy leaves; one run's ring
    only (index the run axis out of a vmapped campaign ring first).
    """
    lanes = np.asarray(ring.lanes)
    size = lanes.shape[0]
    m = (lanes.shape[-1] - len(SCALAR_KEYS)) // len(PER_WORKER_KEYS)
    head = int(ring.head)
    n = min(head, size)
    start = head - n
    out = []
    for i in range(n):
        lane = lanes[(start + i) % size]
        frame = {k: lane[kk * m:(kk + 1) * m]
                 for kk, k in enumerate(PER_WORKER_KEYS)}
        base = len(PER_WORKER_KEYS) * m
        frame.update({k: lane[base + kk]
                      for kk, k in enumerate(SCALAR_KEYS)})
        out.append(frame)
    return out


class Telemetry(NamedTuple):
    """What one telemetry-enabled ``run_sgd`` returns next to its result:
    the ring (last ``ring_size`` full frames) plus two full-horizon
    summaries cheap enough to keep for every step — the per-worker
    first-filter step and the Byzantine survival curve the campaign
    report's timeline section aggregates."""

    ring: TelemetryRing
    first_filter_step: jax.Array   # (m,) int32 — first k worker left good_k; -1 = never
    byz_alive: jax.Array           # (T,) int32 — |{byz ∩ good_k}| per step
