"""Measured-vs-roofline comparator (DESIGN.md §12).

``roofline/guard_cost.py`` predicts every guard backend's steady-state
per-step wall-clock from bytes moved; the flight recorder measures the
realized per-step time (campaign wall-clock / steps, or ``guard/*`` span
durations from an event log).  This module joins the two so drift between
the model and the machine is a first-class, recorded quantity instead of
a manual comparison across two JSON files.

The ratio column is diagnostic, not pass/fail: on CPU the fused backend
runs the Pallas interpreter and ratios are meaningless (the ``backend``
field in the surrounding meta says so); on TPU a ratio far above 1 means
the kernel is leaving bandwidth on the table, far below 1 means the model
is miscounting passes.
"""
from __future__ import annotations


def roofline_rows(measured_step_us: dict[str, float], m: int, d: int) -> list[dict]:
    """Join measured per-step µs (keyed by backend spec, ``@dtype``
    suffixes honored) against the guard_cost prediction at (m, d)."""
    # deferred: guard_backends itself imports repro.obs (the telemetry
    # probe), so a module-level import here would be circular
    from repro.core.guard_backends import parse_backend_spec
    from repro.roofline.guard_cost import backend_cost, steady_state_us

    rows = []
    for spec, meas in sorted(measured_step_us.items()):
        name, sdt = parse_backend_spec(spec)
        cost = backend_cost(name, m, d, sdt or "f32")
        model = steady_state_us(cost)
        rows.append({
            "backend": spec,
            "m": m,
            "d": d,
            "stats_dtype": sdt or "f32",
            "measured_step_us": float(meas),
            "modeled_step_us": model,
            "model_step_bytes": cost.step_bytes,
            "measured_over_model": float(meas) / max(model, 1e-12),
        })
    return rows


def spans_by_name(events: list[dict]) -> dict[str, dict]:
    """Aggregate ``span`` events → name → {count, total_s, mean_s} —
    the measured side when the input is an event log rather than a
    benchmark's own timing dict."""
    acc: dict[str, dict] = {}
    for ev in events:
        if ev.get("type") != "span":
            continue
        rec = acc.setdefault(ev["name"], {"count": 0, "total_s": 0.0})
        rec["count"] += 1
        rec["total_s"] += float(ev.get("dur_s", 0.0))
    for rec in acc.values():
        rec["mean_s"] = rec["total_s"] / max(rec["count"], 1)
    return acc
