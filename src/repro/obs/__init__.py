"""Observability layer — guard flight recorder, event log, spans
(DESIGN.md §12).

In-trace: :class:`TelemetryConfig` gates a scan-carried telemetry pytree
(per-worker martingale deviations vs thresholds, alive deltas, ξ norm,
resync drift, adversary feedback) written into an on-device ring buffer;
off-state is trace-identical to a build without this package.

Host: :class:`EventLog` (structured JSONL + Perfetto/chrome-trace export),
:func:`trace_span` / :func:`guard_scope` profiler spans, provenance meta,
and the measured-vs-roofline comparator.  Rendered by
``scripts/render_trace.py``.
"""
from repro.obs.events import EventLog, write_chrome_trace
from repro.obs.provenance import provenance_meta
from repro.obs.roofline_compare import roofline_rows, spans_by_name
from repro.obs.spans import guard_scope, trace_span
from repro.obs.telemetry import (
    FRAME_SCHEMA,
    PER_WORKER_KEYS,
    SCALAR_KEYS,
    Telemetry,
    TelemetryConfig,
    TelemetryRing,
    baseline_frame,
    empty_frame,
    guard_frame,
    ring_init,
    ring_push,
    ring_read,
    telemetry_on,
)

__all__ = [
    "EventLog",
    "FRAME_SCHEMA",
    "PER_WORKER_KEYS",
    "SCALAR_KEYS",
    "Telemetry",
    "TelemetryConfig",
    "TelemetryRing",
    "baseline_frame",
    "empty_frame",
    "guard_frame",
    "guard_scope",
    "provenance_meta",
    "ring_init",
    "ring_push",
    "ring_read",
    "roofline_rows",
    "spans_by_name",
    "telemetry_on",
    "trace_span",
    "write_chrome_trace",
]
