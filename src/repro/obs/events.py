"""Structured event log — the host half of the flight recorder
(DESIGN.md §12).

One writer for every observability surface in the repo: telemetry frames
drained from the on-device ring (``type: "guard_step"``), campaign filter
timelines (``type: "timeline"``), host wall-clock spans (``type: "span"``,
see :mod:`repro.obs.spans`), roofline comparator rows (``type:
"roofline"``), and serve counters (``type: "counter"``).  The format is
line-delimited JSON: line 1 is the ``meta`` record (provenance +
caller-supplied fields such as the measured telemetry overhead), every
following line one event with a ``type`` discriminator — greppable,
appendable, diffable.

:meth:`EventLog.write_chrome_trace` re-projects the same events into the
Chrome trace-event format Perfetto / ``chrome://tracing`` load directly:
spans become complete (``ph: "X"``) slices on per-track threads, scalar
step series (``n_alive``, ``xi_norm``, ``adapt_scale``) become counter
(``ph: "C"``) tracks, so a campaign's filter history sits on a zoomable
timeline next to the host phases that produced it.
"""
from __future__ import annotations

import json
import math
from typing import Iterable

import numpy as np

from repro.obs.provenance import provenance_meta

# chrome-trace counter tracks exported per guard_step event
_COUNTER_KEYS = ("n_alive", "xi_norm", "adapt_scale", "v_est")


def _jsonable(v):
    """numpy/jax scalars and arrays → plain JSON values (floats rounded to
    6 significant digits — telemetry is forensics, not reproduction, and
    the committed example traces should stay reviewably small)."""
    if isinstance(v, (str, bool, int, type(None))):
        return v
    if isinstance(v, float):
        return None if math.isnan(v) else float(f"{v:.6g}")
    arr = np.asarray(v)
    if arr.ndim == 0:
        if arr.dtype.kind in "iub":
            return int(arr)
        return _jsonable(float(arr))
    return [_jsonable(x) for x in arr.tolist()]


class EventLog:
    """Append-only structured log with a provenance meta header."""

    def __init__(self, **meta):
        self.meta = provenance_meta()
        self.meta.update(meta)
        self.events: list[dict] = []

    def add_meta(self, **fields) -> None:
        """Merge fields into the meta header (e.g. the measured
        telemetry-enabled overhead fraction, recorded where the trace
        itself lives)."""
        self.meta.update({k: _jsonable(v) for k, v in fields.items()})

    def event(self, type_: str, **fields) -> dict:
        ev = {"type": type_}
        ev.update({k: _jsonable(v) for k, v in fields.items()})
        self.events.append(ev)
        return ev

    def guard_step(self, frame: dict, run: str, **fields) -> dict:
        """One drained telemetry frame (see ``repro.obs.telemetry``
        FRAME_SCHEMA) as an event; ``run`` labels the producing cell —
        '<scenario>/a<alpha>/<variant>/s<seed>' for campaigns."""
        return self.event("guard_step", run=run, **frame, **fields)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(json.dumps({"type": "meta", **self.meta}) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")

    # -- reading -----------------------------------------------------------

    @staticmethod
    def read_jsonl(path: str) -> tuple[dict, list[dict]]:
        """→ (meta, events); tolerates a missing meta line (first event
        wins the position) so hand-truncated traces still render."""
        meta: dict = {}
        events: list[dict] = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                if rec.get("type") == "meta":
                    meta = rec
                else:
                    events.append(rec)
        return meta, events

    # -- chrome trace / Perfetto export ------------------------------------

    def write_chrome_trace(self, path: str) -> None:
        write_chrome_trace(self.meta, self.events, path)


def write_chrome_trace(meta: dict, events: Iterable[dict], path: str) -> None:
    """Project (meta, events) onto the Chrome trace-event JSON format.

    * ``span`` events → complete slices (``ph: "X"``, µs timebase) on a
      thread per span ``track`` (default: the span name's first segment);
    * ``guard_step`` events → counter tracks (``ph: "C"``) per run for the
      scalar series in ``_COUNTER_KEYS``, placed at ``step`` µs on a
      synthetic timebase (steps, not wall-clock — the filter timeline is
      an iteration-domain object);
    * everything else → instant events carrying their payload as args.
    """
    pids = {"spans": 1, "steps": 2}
    tids: dict[str, int] = {}

    def tid(track: str) -> int:
        return tids.setdefault(track, len(tids) + 1)

    out: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": pid,
         "args": {"name": label}}
        for label, pid in pids.items()
    ]
    t0 = None
    for ev in events:
        kind = ev.get("type")
        if kind == "span":
            start = float(ev.get("t0", 0.0))
            t0 = start if t0 is None else min(t0, start)
    for ev in events:
        kind = ev.get("type")
        if kind == "span":
            track = ev.get("track") or str(ev.get("name", "span")).split("/")[0]
            out.append({
                "name": ev.get("name", "span"),
                "ph": "X",
                "pid": pids["spans"],
                "tid": tid(track),
                "ts": (float(ev.get("t0", 0.0)) - (t0 or 0.0)) * 1e6,
                "dur": float(ev.get("dur_s", 0.0)) * 1e6,
                "args": {k: v for k, v in ev.items()
                         if k not in ("type", "name", "t0", "dur_s")},
            })
        elif kind == "guard_step":
            run = ev.get("run", "run")
            step = ev.get("step")
            if step is None:
                continue
            for key in _COUNTER_KEYS:
                val = ev.get(key)
                if val is None:
                    continue
                out.append({
                    "name": f"{run}/{key}",
                    "ph": "C",
                    "pid": pids["steps"],
                    "tid": tid(run),
                    "ts": float(step),
                    "args": {key: float(val)},
                })
        else:
            out.append({
                "name": kind or "event",
                "ph": "i",
                "s": "g",
                "pid": pids["spans"],
                "tid": tid("events"),
                "ts": 0.0,
                "args": {k: v for k, v in ev.items() if k != "type"},
            })
    with open(path, "w") as f:
        json.dump({"traceEvents": out, "metadata": meta}, f)
