"""Run provenance — the attribution block every emitted artifact carries.

Perf numbers and traces are only comparable run-to-run when each record
says what produced it: the commit, the jax/jaxlib pair (XLA changes move
wall-clock), the device kind (CPU-interpret Pallas numbers are not TPU
numbers), and when.  :func:`provenance_meta` is the single source of that
block — ``benchmarks/common.write_json`` stamps it into every
``BENCH_*.json`` and :class:`repro.obs.events.EventLog` into every trace
(DESIGN.md §12).
"""
from __future__ import annotations

import os
import subprocess
from datetime import datetime, timezone


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def provenance_meta() -> dict:
    """Commit SHA, jax/jaxlib versions, device kind/platform, ISO timestamp.

    Imports jax lazily and degrades to ``"unknown"`` fields rather than
    raising — provenance must never be the reason a benchmark fails.
    """
    meta = {
        "commit": _git_sha(),
        "timestamp": datetime.now(timezone.utc).isoformat(timespec="seconds"),
    }
    try:
        import jax
        import jaxlib

        dev = jax.devices()[0]
        meta.update(
            jax_version=jax.__version__,
            jaxlib_version=jaxlib.__version__,
            backend=jax.default_backend(),
            device_kind=getattr(dev, "device_kind", "unknown"),
            n_devices=jax.device_count(),
        )
    except Exception:  # noqa: BLE001 — provenance is best-effort by design
        meta.update(jax_version="unknown", jaxlib_version="unknown",
                    backend="unknown", device_kind="unknown", n_devices=0)
    return meta
