"""Profiler spans — phase attribution for XLA profiles and host traces
(DESIGN.md §12).

Two instruments with one naming convention (``<layer>/<phase>``, e.g.
``guard/fused_sweep``, ``train/chunk``, ``serve/prefill``):

* :func:`guard_scope` — a ``jax.named_scope`` wrapper used *inside* traced
  code.  Pure HLO metadata: op names gain the ``guard/<phase>`` prefix so
  an XLA profile (``jax.profiler.trace`` + Perfetto) attributes device
  time to guard phases instead of one anonymous fusion soup.  Zero ops,
  zero numerics — safe to leave on unconditionally, which is why the four
  guard backends and the fused kernel carry their scopes always.
* :func:`trace_span` — a host-side context manager combining
  ``jax.profiler.TraceAnnotation`` (so the span also lands on the device
  profile's host track when a profiler session is active) with a
  perf-counter measurement appended to an :class:`~repro.obs.events.
  EventLog` as a ``span`` event.  These are the measured timings the
  roofline comparator joins against ``roofline/guard_cost`` predictions.
"""
from __future__ import annotations

import contextlib
import time

import jax

# span naming convention: '<layer>/<phase>' — the layer segment becomes
# the chrome-trace thread, so phases of one layer share a track
GUARD_PHASES = ("stats_sweep", "filter", "aggregate", "resync")


def guard_scope(phase: str):
    """``jax.named_scope('guard/<phase>')`` — in-trace metadata only."""
    return jax.named_scope(f"guard/{phase}")


@contextlib.contextmanager
def trace_span(name: str, log=None, **args):
    """Measure a host-side phase; annotate it onto any active profiler
    session and (when ``log`` is given) append a ``span`` event.

    The measured duration includes device sync only if the wrapped block
    itself blocks (callers time complete units of work — a compiled call
    + ``block_until_ready``, a chunk drain — not async dispatches).
    """
    t0 = time.perf_counter()
    try:
        with jax.profiler.TraceAnnotation(name):
            yield
    finally:
        dur = time.perf_counter() - t0
        if log is not None:
            log.event("span", name=name, t0=t0, dur_s=dur, **args)
