"""Jamba-v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    source="Jamba [arXiv:2403.19887]",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    d_ff_expert=14336,
    moe_every=2,
    attn_period=8,
    attn_offset=4,         # 1 attention layer per 8; rest mamba
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
)
