"""Llama-3.2-3B — small dense llama3 family. [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    arch_type="dense",
    source="Llama 3.2 [hf:meta-llama/Llama-3.2-1B family]",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
)
