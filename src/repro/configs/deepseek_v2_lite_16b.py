"""DeepSeek-V2-Lite 16B — MLA (kv_lora=512) + MoE (2 shared + 64 routed,
top-6). [arXiv:2405.04434]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    source="DeepSeek-V2(-Lite) [arXiv:2405.04434]",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,            # first dense layer FFN
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    first_dense_layers=1,
    use_mla=True,
    kv_lora_rank=512,
    rope_head_dim=64,
)
