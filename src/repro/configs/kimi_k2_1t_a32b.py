"""Kimi K2 — trillion-parameter MoE, 32B active (paper-table geometry).
[arXiv:2501.kimi2]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    source="Kimi K2 [arXiv:2501.kimi2]",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=18432,            # dense FFN of the first layer
    vocab_size=163840,
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    d_ff_expert=2048,
    first_dense_layers=1,
    rope_theta=50000.0,
)
