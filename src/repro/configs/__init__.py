"""Config registry: ``get_config(name)`` / ``list_configs()``.

Each assigned architecture has one module exporting ``CONFIG``; the exact
dimensions follow the assignment table (source papers cited per config).
"""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, BlockSpec, InputShape, ModelConfig

ARCH_IDS = [
    "internvl2-76b",
    "mamba2-130m",
    "kimi-k2-1t-a32b",
    "llama3.2-3b",
    "phi3-mini-3.8b",
    "starcoder2-3b",
    "seamless-m4t-large-v2",
    "internlm2-1.8b",
    "deepseek-v2-lite-16b",
    "jamba-v0.1-52b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).CONFIG


def list_configs() -> list[str]:
    return list(ARCH_IDS)


__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "BlockSpec",
    "InputShape",
    "ModelConfig",
    "get_config",
    "list_configs",
]
