"""Model / run configuration schema.

One :class:`ModelConfig` describes any architecture in the assigned pool:
dense decoder (GQA / MLA / sliding-window), MoE, SSM (Mamba2/SSD), hybrid
interleave (Jamba), encoder–decoder (Seamless backbone), and the VLM/audio
variants (backbone + embedding frontstub). ``layer_plan()`` compiles the
config into homogeneous layer groups so model code can ``lax.scan`` over
stacked per-group parameters (essential to keep HLO small for 512-device
AOT compiles).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One homogeneous group of transformer blocks.

    mixer:  'attn' | 'mla' | 'swa' (sliding-window attn) | 'mamba'
    ff:     'mlp' | 'moe' | 'none'
    count:  how many consecutive layers share this spec (scanned together).
    """

    mixer: str
    ff: str
    count: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio
    source: str                       # citation for the config
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0                # 0 = dense FFN
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    first_dense_layers: int = 0       # leading dense layers before MoE starts
    moe_every: int = 1                # MoE in every k-th layer (jamba: 2)

    # --- MLA (deepseek) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0              # 0 = full-rank q projection
    rope_head_dim: int = 64

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0                # N; 0 = no ssm layers
    ssm_expand: int = 2
    ssm_head_dim: int = 64            # P
    ssm_conv_width: int = 4
    ssm_chunk: int = 256              # SSD chunk length
    ssm_groups: int = 1               # G groups for B/C

    # --- hybrid (jamba) ---
    attn_period: int = 0              # 1 attention layer per `attn_period` layers
    attn_offset: int = 0              # which index in the period is attention

    # --- attention variants ---
    sliding_window: Optional[int] = None   # None = full causal
    attn_chunk: int = 1024                 # KV-chunk size for online-softmax attention
    kv_cache_dtype: str = "bfloat16"       # 'int8' = quantized serving cache (§Perf)

    # --- encoder-decoder ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 4096            # encoder memory length for decode shapes

    # --- modality frontend (stub: input_specs provide embeddings) ---
    frontend: str = "none"             # none | vision | audio
    frontend_seq: int = 0              # patches / frames prepended or encoded
    frontend_dim: int = 0              # embedding dim delivered by the stub

    # --- numerics ---
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    def mixer_for_layer(self, i: int) -> str:
        if self.ssm_state > 0 and self.attn_period == 0:
            return "mamba"                      # pure SSM
        if self.attn_period > 0:                # hybrid interleave
            if i % self.attn_period == self.attn_offset:
                return "swa" if self.sliding_window else "attn"
            return "mamba"
        if self.use_mla:
            return "mla"
        return "swa" if self.sliding_window else "attn"

    def ff_for_layer(self, i: int) -> str:
        if not self.is_moe:
            return "mlp" if self.d_ff > 0 else "none"
        if i < self.first_dense_layers:
            return "mlp"
        if (i - self.first_dense_layers) % self.moe_every == 0:
            return "moe"
        return "mlp"

    def layer_plan(self) -> list[BlockSpec]:
        """Compress the per-layer (mixer, ff) sequence into homogeneous,
        scannable groups. Repeating patterns (e.g. jamba's period-8
        interleave) produce a short list of groups cycled in order."""
        kinds = [(self.mixer_for_layer(i), self.ff_for_layer(i)) for i in range(self.n_layers)]
        groups: list[BlockSpec] = []
        for mixer, ff in kinds:
            if groups and (groups[-1].mixer, groups[-1].ff) == (mixer, ff):
                groups[-1] = dataclasses.replace(groups[-1], count=groups[-1].count + 1)
            else:
                groups.append(BlockSpec(mixer=mixer, ff=ff, count=1))
        return groups

    # ------------------------------------------------------------------
    def reduced(self, max_d_model: int = 256, n_layers: int = 2, max_experts: int = 4,
                max_vocab: int = 512) -> "ModelConfig":
        """CPU-smoke-test variant of the same family (2 layers, tiny dims)."""
        d_model = min(self.d_model, max_d_model)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, n_heads)
        head_dim = max(d_model // n_heads, 8)
        changes = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 2 * d_model) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, max_vocab),
            attn_chunk=64,
            param_dtype="float32",
            activation_dtype="float32",
        )
        if self.is_moe:
            changes.update(
                n_experts=min(self.n_experts, max_experts),
                top_k=min(self.top_k, 2),
                d_ff_expert=min(self.d_ff_expert, d_model),
                n_shared_experts=min(self.n_shared_experts, 1),
                first_dense_layers=min(self.first_dense_layers, 1),
            )
        if self.use_mla:
            changes.update(kv_lora_rank=min(self.kv_lora_rank, 64),
                           q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
                           rope_head_dim=min(self.rope_head_dim, 16))
        if self.ssm_state > 0:
            changes.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=16,
                           ssm_chunk=32)
        if self.attn_period > 0:
            changes.update(attn_period=2, attn_offset=1, n_layers=max(n_layers, 2))
        if self.enc_dec:
            changes.update(n_enc_layers=2, enc_seq_len=64)
        if self.frontend != "none":
            changes.update(frontend_seq=min(self.frontend_seq, 16), frontend_dim=d_model)
        if self.sliding_window:
            changes.update(sliding_window=min(self.sliding_window, 32))
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the 4 assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
