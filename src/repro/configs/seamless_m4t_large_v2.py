"""SeamlessM4T-large-v2 — encoder-decoder multimodal (audio frontend
stubbed to frame embeddings per assignment). [arXiv:2308.11596]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    source="SeamlessM4T v2 [arXiv:2308.11596]",
    n_layers=24,           # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    enc_dec=True,
    n_enc_layers=24,
    enc_seq_len=4096,      # speech frames after the (stubbed) conv frontend
    frontend="audio",
    frontend_seq=4096,
    frontend_dim=1024,     # w2v-BERT frame embedding dim (stub delivers these)
)
