"""InternVL2-Llama3-76B — VLM: InternViT-6B vision frontend (stubbed to
patch embeddings per assignment) + Llama3-70B-class language backbone.
[arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    source="InternVL2 [arXiv:2404.16821]; backbone Llama3-70B geometry",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    frontend="vision",
    frontend_seq=256,      # ViT patch embeddings delivered by the stub
    frontend_dim=3200,     # InternViT-6B hidden size
)
