"""StarCoder2-3B — dense, GQA kv=2, RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    source="StarCoder2 [arXiv:2402.19173]",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    sliding_window=4096,    # starcoder2 uses sliding-window attention
)
