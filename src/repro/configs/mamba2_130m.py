"""Mamba2-130M — pure SSM (SSD / state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    source="Mamba2 / SSD [arXiv:2405.21060]",
    n_layers=24,
    d_model=768,
    n_heads=12,            # unused by the mamba mixer; kept for schema
    n_kv_heads=12,
    d_ff=0,                # attn-free, no MLP blocks: mixer-only layers
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,       # d_inner=1536 → 24 SSD heads
    ssm_chunk=256,
)
