"""Roofline terms from compiled AOT artifacts (no hardware required).

* ``compiled.cost_analysis()`` → per-device HLO FLOPs and bytes accessed.
* collective bytes are NOT in cost_analysis: we parse the partitioned HLO
  (``compiled.as_text()``) and sum the operand sizes of every all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute (counting
  async ``-start`` forms once, skipping ``-done``).

Terms (seconds, per device — the HLO is already partitioned):

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes / HBM_bw
    collective = collective_bytes / ICI_bw

plus MODEL_FLOPS = 6·N_active·D (train) so the useful-compute ratio
MODEL_FLOPS / (chips × HLO_FLOPs) exposes remat/attention/dispatch overhead.
"""
from __future__ import annotations

import re
from typing import NamedTuple

from repro.configs.base import InputShape, ModelConfig
from repro.models.common import ParamDef, is_def
from repro.roofline.hw import HwSpec, TPU_V5E

import jax
import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"                        # result type (maybe tuple)
    r"((?:all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?)\("                                 # op name + open paren
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class CollectiveStats(NamedTuple):
    total_bytes: int
    by_kind: dict


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    """Estimate per-device wire bytes of every collective in (partitioned)
    HLO text.  Operands print without types in modern HLO, so we size each
    op from its RESULT type with a kind-specific ring-algorithm factor
    (g = replica group size, parsed from ``replica_groups=[n,g]``):

      all-gather          result·(g−1)/g   (receive all shards but your own)
      all-reduce          2·result·(g−1)/g (reduce-scatter + all-gather ring)
      reduce-scatter      result·(g−1)     (input = g·result, wire (g−1)/g)
      all-to-all          result·(g−1)/g
      collective-permute  result           (one send per device)
    """
    by_kind: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        rtype, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        kind = op.replace("-start", "")
        rbytes = _shape_bytes(rtype)
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else 2
        g = max(g, 2)
        if kind == "all-gather":
            wire = rbytes * (g - 1) // g
        elif kind == "all-reduce":
            wire = 2 * rbytes * (g - 1) // g
        elif kind == "reduce-scatter":
            wire = rbytes * (g - 1)
        elif kind == "all-to-all":
            wire = rbytes * (g - 1) // g
        else:  # collective-permute
            wire = rbytes
        by_kind[kind] = by_kind.get(kind, 0) + wire
    return CollectiveStats(total_bytes=sum(by_kind.values()), by_kind=by_kind)


class RooflineReport(NamedTuple):
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float              # per device
    hlo_bytes: float              # per device
    collective_bytes: float       # per device
    collective_by_kind: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float            # global useful FLOPs (6·N_active·D etc.)
    useful_ratio: float           # model_flops / (chips · hlo_flops)
    peak_memory_bytes: float      # per-device peak from memory_analysis
    fits_hbm: bool

    def row(self) -> str:
        return (
            f"{self.arch:24s} {self.shape:12s} {self.mesh:10s} "
            f"c={self.t_compute*1e3:9.3f}ms m={self.t_memory*1e3:9.3f}ms "
            f"n={self.t_collective*1e3:9.3f}ms [{self.bottleneck:10s}] "
            f"useful={self.useful_ratio:6.1%} mem={self.peak_memory_bytes/1e9:7.2f}GB"
        )


def active_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) parameter counts. Routed-expert leaves scale by
    top_k / n_experts in the active count."""
    from repro.models.model import model_defs

    defs = model_defs(cfg)
    total = active = 0
    for leaf in jax.tree_util.tree_leaves(defs, is_leaf=is_def):
        n = int(np.prod(leaf.shape))
        total += n
        if cfg.is_moe and "experts" in (leaf.axes or ()):
            active += n * cfg.top_k // cfg.n_experts
        else:
            active += n
    return total, active


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Useful FLOPs per step: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill), 2·N_active·batch (decode: one token per sequence)."""
    _, act = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * act * shape.global_batch * shape.seq_len
    return 2.0 * act * shape.global_batch


def roofline_from_compiled(
    compiled, arch: str, shape: InputShape, mesh_desc: str, n_chips: int,
    cfg: ModelConfig, hw: HwSpec = TPU_V5E,
) -> RooflineReport:
    # loop-aware costs from the partitioned HLO text: XLA's cost_analysis()
    # counts while bodies once, so scanned models would undercount by the
    # trip counts (see repro.roofline.hlo_cost)
    from repro.roofline.hlo_cost import cost_from_hlo_text

    hlo_text = compiled.as_text()
    lc = cost_from_hlo_text(hlo_text)
    flops = float(lc.flops)
    byts = float(lc.bytes_accessed)
    coll = CollectiveStats(
        total_bytes=int(lc.collective_bytes), by_kind=lc.collective_by_kind
    )

    mem = compiled.memory_analysis()
    peak = float(
        getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )

    t_c = flops / hw.peak_flops_bf16
    t_m = byts / hw.hbm_bw
    t_n = coll.total_bytes / (hw.ici_bw_per_link * hw.ici_links)
    bottleneck = max(
        [("compute", t_c), ("memory", t_m), ("collective", t_n)], key=lambda kv: kv[1]
    )[0]
    mf = model_flops(cfg, shape)
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_desc, n_chips=n_chips,
        hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=float(coll.total_bytes), collective_by_kind=coll.by_kind,
        t_compute=t_c, t_memory=t_m, t_collective=t_n, bottleneck=bottleneck,
        model_flops=mf, useful_ratio=mf / max(n_chips * flops, 1.0),
        peak_memory_bytes=peak, fits_hbm=peak <= hw.hbm_bytes,
    )
