"""Analytic HBM-traffic / FLOP model for the guard step (DESIGN.md §5).

The guard is memory-bound on every realistic shape (arithmetic intensity
≈ m/2 flops per byte with m ≤ a few hundred, far under the TPU ridge
point), so the quantity that predicts wall-clock is bytes moved per step.
This module is the accounting used by ``benchmarks/bench_filtering.py``
and quoted in DESIGN.md; only O(m·d) terms are counted (the (m, m) Grams,
(m,) vectors, and (d,) iterate reads are noise at d ≫ m).

Dense reference (:class:`repro.core.byzantine_sgd.ByzantineGuard`,
``use_fused=False``), e = element bytes (4 for f32):

    A += g·Δ          read g                      1·m·d·e
    B += g            read B, read g, write B     3·m·d·e
    G_B = B Bᵀ        read B                      1·m·d·e
    G_g = g gᵀ        read g                      1·m·d·e
    ─────────────────────────── statistics total  6·m·d·e
    ξ  = mask·g/denom read g                      1·m·d·e
    ─────────────────────────── step total        7·m·d·e

Fused pipeline (``use_fused=True``): one sweep of
:mod:`repro.kernels.fused_guard` reads each g and B strip once and writes
the new B strip (G_B is updated incrementally from the sweep's outputs —
nothing re-reads B):

    fused sweep       read g, read B, write B     3·m·d·e
    ─────────────────────────── statistics total  3·m·d·e   (2.0× less)
    ξ (filtered-mean kernel)                      1·m·d·e
    ─────────────────────────── step total        4·m·d·e   (1.75× less)

ξ cannot join the sweep: good_k depends on the Grams the sweep produces.
"""
from __future__ import annotations

from typing import NamedTuple


class GuardStepCost(NamedTuple):
    """Per-step cost of one guard variant (bytes/flops, leading order)."""

    stats_bytes: int    # martingale + Gram production (what the kernel fuses)
    xi_bytes: int       # the filtered-mean aggregation pass
    flops: int          # dominated by the two (m, m, d) contractions

    @property
    def step_bytes(self) -> int:
        return self.stats_bytes + self.xi_bytes


def dense_guard_cost(m: int, d: int, elem_bytes: int = 4) -> GuardStepCost:
    """Three-pass dense reference: 6 m·d reads/writes for the statistics."""
    mde = m * d * elem_bytes
    return GuardStepCost(
        stats_bytes=6 * mde,
        xi_bytes=1 * mde,
        flops=2 * m * m * d * 2 + 2 * m * d,   # B Bᵀ + g gᵀ, A + ξ dots
    )


def fused_guard_cost(m: int, d: int, elem_bytes: int = 4) -> GuardStepCost:
    """One-pass fused pipeline: 3 m·d for the statistics sweep."""
    mde = m * d * elem_bytes
    return GuardStepCost(
        stats_bytes=3 * mde,
        xi_bytes=1 * mde,
        flops=2 * m * m * d * 2 + 2 * m * d,   # same math, fewer bytes
    )
