"""Analytic HBM-traffic / FLOP model for the guard step (DESIGN.md §5).

The guard is memory-bound on every realistic shape (arithmetic intensity
≈ m/2 flops per byte with m ≤ a few hundred, far under the TPU ridge
point), so the quantity that predicts wall-clock is bytes moved per step.
This module is the accounting used by ``benchmarks/bench_filtering.py``
and quoted in DESIGN.md; only O(m·d) terms are counted (the (m, m) Grams,
(m,) vectors, and (d,) iterate reads are noise at d ≫ m).

Every model below is parameterized on ``e = element bytes`` of the
streamed statistics — the ``stats_dtype`` axis (4 for f32, 2 for bf16,
:data:`STATS_DTYPE_BYTES`): the guard is bandwidth-bound, so halving
``e`` halves the modeled wall-clock of every O(m·d) pass.  The (m, m)
Grams and (m,) vectors stay f32 accumulators at either precision and are
O(m²)/O(m) — noise at d ≫ m, excluded as before.

Dense reference (:class:`repro.core.byzantine_sgd.ByzantineGuard`,
``use_fused=False``), e = element bytes (4 for f32):

    A += g·Δ          read g                      1·m·d·e
    B += g            read B, read g, write B     3·m·d·e
    G_B = B Bᵀ        read B                      1·m·d·e
    G_g = g gᵀ        read g                      1·m·d·e
    ─────────────────────────── statistics total  6·m·d·e
    ξ  = mask·g/denom read g                      1·m·d·e
    ─────────────────────────── step total        7·m·d·e

Fused pipeline (``use_fused=True``): one sweep of
:mod:`repro.kernels.fused_guard` reads each g and B strip once and writes
the new B strip (G_B is updated incrementally from the sweep's outputs —
nothing re-reads B):

    fused sweep       read g, read B, write B     3·m·d·e
    ─────────────────────────── statistics total  3·m·d·e   (2.0× less)
    ξ (filtered-mean kernel)                      1·m·d·e
    ─────────────────────────── step total        4·m·d·e   (1.75× less)

ξ cannot join the sweep: good_k depends on the Grams the sweep produces.

The distributed guard modes (DESIGN.md §3, swept as guard *backends* on the
flat harness — DESIGN.md §9) follow the same pass-count accounting:

    dp_exact (incremental Gram): A (read g) + B += g (read B, read g,
    write B) + g gᵀ (read g) + cross B gᵀ (read B, read g)   7·m·d·e
    dp_sketch: A (read g) + mean-center (read g ×2) + fused
    sketch/norms fold (read g); all B-side work is O(m·k ≪ m·d)   4·m·d·e

``BACKEND_COSTS`` maps every registered guard-backend name to its model,
and :func:`steady_state_us` converts bytes to the bandwidth-bound
steady-state wall-clock on the target hardware — the per-backend number
``benchmarks/bench_scenarios.py`` records at the m = 32, d = 2²⁰ headline
shape.
"""
from __future__ import annotations

from typing import NamedTuple

from repro.roofline.hw import TPU_V5E, HwSpec


class GuardStepCost(NamedTuple):
    """Per-step cost of one guard variant (bytes/flops, leading order)."""

    stats_bytes: int    # martingale + Gram production (what the kernel fuses)
    xi_bytes: int       # the filtered-mean aggregation pass
    flops: int          # dominated by the two (m, m, d) contractions

    @property
    def step_bytes(self) -> int:
        return self.stats_bytes + self.xi_bytes


def dense_guard_cost(m: int, d: int, elem_bytes: int = 4) -> GuardStepCost:
    """Three-pass dense reference: 6 m·d reads/writes for the statistics."""
    mde = m * d * elem_bytes
    return GuardStepCost(
        stats_bytes=6 * mde,
        xi_bytes=1 * mde,
        flops=2 * m * m * d * 2 + 2 * m * d,   # B Bᵀ + g gᵀ, A + ξ dots
    )


def fused_guard_cost(m: int, d: int, elem_bytes: int = 4) -> GuardStepCost:
    """One-pass fused pipeline: 3 m·d for the statistics sweep."""
    mde = m * d * elem_bytes
    return GuardStepCost(
        stats_bytes=3 * mde,
        xi_bytes=1 * mde,
        flops=2 * m * m * d * 2 + 2 * m * d,   # same math, fewer bytes
    )


def dp_exact_guard_cost(m: int, d: int, elem_bytes: int = 4) -> GuardStepCost:
    """Distributed exact guard with incremental Gram: the B Bᵀ re-contraction
    is gone, but the cross term B gᵀ re-reads both operands — 7 m·d passes.
    (Its win is *collective* volume, not local HBM traffic: B shards never
    travel; see byzantine_dp.)"""
    mde = m * d * elem_bytes
    return GuardStepCost(
        stats_bytes=7 * mde,
        xi_bytes=1 * mde,
        flops=2 * m * m * d * 2 + 2 * m * d,
    )


# FLOPs to regenerate one gradient element in-kernel: 20 threefry rounds
# (XOR + rotate + add ≈ 3 flops on 2 lanes) plus key-schedule injections,
# uniform conversion, and the attack-row selects — ~128 flop/elem is the
# model constant the measured-vs-modeled band in bench_filtering is checked
# against.  Deliberately coarse: generation is *compute* traffic that
# replaces the g-strip's HBM reads, and the roofline question is only
# whether it fits under the bandwidth roof (it does: the fused-gen sweep's
# arithmetic intensity rises ~(128+2m)/(2e) flops/byte vs the materialized
# sweep's m/e — still under typical ridge points at small m, so the bytes
# term below keeps predicting wall-clock).
GEN_FLOPS_PER_ELEM = 128


def gen_guard_cost(m: int, d: int, elem_bytes: int = 4) -> GuardStepCost:
    """Fused pipeline with in-kernel generation (DESIGN.md §14): the g strip
    is regenerated from the counter-based PRNG inside both the statistics
    sweep and the ξ pass, so *no* pass reads or writes gradients — the only
    O(m·d) HBM traffic left is the B-strip read + write in the sweep:

        fused-gen sweep   read B, write B              2·m·d·e
        ─────────────────────────── statistics total   2·m·d·e  (3.0× less)
        ξ (regenerates its own rows; O(d) out)         ~0
        ─────────────────────────── step total         2·m·d·e  (3.5× less)

    The generation itself costs FLOPs, not bytes — counted once per pass
    (sweep + ξ) at :data:`GEN_FLOPS_PER_ELEM` each."""
    mde = m * d * elem_bytes
    return GuardStepCost(
        stats_bytes=2 * mde,
        xi_bytes=0,
        flops=2 * m * m * d * 2 + 2 * m * d
        + 2 * GEN_FLOPS_PER_ELEM * m * d,  # regenerate rows in sweep + ξ
    )


def dp_sketch_guard_cost(m: int, d: int, elem_bytes: int = 4) -> GuardStepCost:
    """CountSketch guard: the only O(m·d) passes are the A dot, the two-pass
    mean-centering, and the fused sketch/norm fold; every Gram contraction
    runs in sketch space (O(m·k), dropped here as k ≪ d)."""
    mde = m * d * elem_bytes
    return GuardStepCost(
        stats_bytes=4 * mde,
        xi_bytes=1 * mde,
        flops=2 * m * d * 3,   # dots + fold; Grams are O(m²k) — negligible
    )


# guard-backend name (repro.core.guard_backends) → per-step cost model.
# "gen" is the campaign's pseudo-backend spelling for fused + generate
# = 'kernel' (repro.scenarios.campaign.expand_variants) — a cost point on
# this axis even though it is not a guard_backends registry entry.
BACKEND_COSTS = {
    "dense": dense_guard_cost,
    "fused": fused_guard_cost,
    "gen": gen_guard_cost,
    "dp_exact": dp_exact_guard_cost,
    "dp_sketch": dp_sketch_guard_cost,
}

# SolverConfig.stats_dtype → bytes per streamed statistics element.
# Kept jax-free on purpose (this module is a pure cost model); the names
# mirror repro.core.byzantine_sgd.STATS_DTYPES and a registry-consistency
# test (tests/test_stats_dtype.py) pins byte widths to the jnp itemsizes
# so the two tables cannot drift apart.
STATS_DTYPE_BYTES = {"f32": 4, "bf16": 2}


def stats_elem_bytes(stats_dtype: str) -> int:
    """``'f32' | 'bf16'`` → element bytes; typos fail loudly."""
    try:
        return STATS_DTYPE_BYTES[stats_dtype]
    except KeyError:
        raise KeyError(
            f"unknown stats_dtype {stats_dtype!r}; "
            f"have {sorted(STATS_DTYPE_BYTES)}"
        ) from None


def backend_cost(backend: str, m: int, d: int,
                 stats_dtype: str = "f32") -> GuardStepCost:
    """Per-step cost of ``(guard backend, stats dtype)`` — the two axes the
    campaigns sweep (``"fused@bf16"`` spellings are split by
    ``repro.core.guard_backends.parse_backend_spec`` before reaching here)."""
    return BACKEND_COSTS[backend](m, d, elem_bytes=stats_elem_bytes(stats_dtype))


def steady_state_us(cost: GuardStepCost, hw: HwSpec = TPU_V5E) -> float:
    """Bandwidth-bound steady-state wall-clock of one guard step (µs): the
    guard's arithmetic intensity sits far under the ridge point on every
    realistic shape, so bytes / HBM bandwidth *is* the wall-clock model."""
    return cost.step_bytes / hw.hbm_bw * 1e6
