"""repro.roofline — three-term roofline analysis from compiled AOT
artifacts, plus the analytic guard-step traffic model (guard_cost)."""
from repro.roofline.hw import TPU_V5E
from repro.roofline.analysis import (
    collective_bytes_from_hlo,
    roofline_from_compiled,
    model_flops,
)
from repro.roofline.guard_cost import (
    GuardStepCost,
    dense_guard_cost,
    fused_guard_cost,
    gen_guard_cost,
)

__all__ = [
    "TPU_V5E",
    "collective_bytes_from_hlo",
    "roofline_from_compiled",
    "model_flops",
    "GuardStepCost",
    "dense_guard_cost",
    "fused_guard_cost",
    "gen_guard_cost",
]
