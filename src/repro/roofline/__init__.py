"""repro.roofline — three-term roofline analysis from compiled AOT artifacts."""
from repro.roofline.hw import TPU_V5E
from repro.roofline.analysis import (
    collective_bytes_from_hlo,
    roofline_from_compiled,
    model_flops,
)

__all__ = [
    "TPU_V5E",
    "collective_bytes_from_hlo",
    "roofline_from_compiled",
    "model_flops",
]
