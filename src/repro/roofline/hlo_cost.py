"""Loop-aware cost model over partitioned HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — our
models scan over layer groups / KV chunks / loss chunks, so FLOPs, bytes
and collective bytes would be undercounted by the trip counts (≈10–30×).
This module re-derives the three roofline inputs from ``compiled.as_text()``
with explicit call-graph multipliers:

* parse every computation into ops (name → result type, opcode, operands,
  raw attrs);
* resolve a ``while`` op's trip count from the constant bound in its
  condition computation (the canonical lowered-scan pattern ``lt(iv, K)``);
* walk the call graph from ENTRY with a multiplier: while bodies multiply
  by trips, fusions/calls keep the parent multiplier;
* FLOPs: 2·numel(result)·K for dot ops (K recovered from operand shapes via
  a per-computation symbol table — operand *names* are typed by their
  defining line), plus numel(result) for elementwise/reduce ops;
* bytes: fusion/top-level op boundary traffic (operands + result numel
  bytes), the standard materialization-point approximation of HBM traffic;
* collectives: wire bytes as in :mod:`repro.roofline.analysis`, scaled by
  the loop multiplier.
"""
from __future__ import annotations

import re
from typing import NamedTuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) \(.*\) -> .+ \{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT )?%([\w\.\-]+) = (\([^)]*\)|\S+) ([\w\-]+)\((.*)$"
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "rsqrt", "sqrt", "tanh", "power", "negate", "abs", "compare",
    "select", "and", "or", "xor", "reduce", "convert",
}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


class HloOp(NamedTuple):
    name: str
    rtype: str
    opcode: str
    rest: str          # everything after the open paren (operands + attrs)


def _strip_layout(type_str: str) -> str:
    """Normalize an HLO type string for alias comparison (drop layouts)."""
    return re.sub(r"\{[^}]*\}", "", type_str).replace(" ", "")


def _numel_bytes(type_str: str) -> tuple[int, int]:
    """(elements, bytes) summed over all shapes in a (possibly tuple) type."""
    n_el = n_by = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_el += n
        n_by += n * _DTYPE_BYTES[dt]
    return n_el, n_by


def parse_hlo_computations(text: str) -> dict[str, list[HloOp]]:
    comps: dict[str, list[HloOp]] = {}
    cur: list[HloOp] | None = None
    entry_marker = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            name = hdr.group(1)
            cur = comps.setdefault(name, [])
            if line.startswith("ENTRY"):
                entry_marker = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if m:
            cur.append(HloOp(m.group(1), m.group(2), m.group(3), m.group(4)))
    if entry_marker:
        comps["__entry__"] = comps[entry_marker]
    return comps


class HloCost(NamedTuple):
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_by_kind: dict
    collective_by_op: dict     # op_name metadata → wire bytes (attribution)
    bytes_by_op: dict          # op_name metadata → HBM bytes (attribution)


_OPNAME_RE = re.compile(r'op_name="([^"]+)"')


def _op_label(op: "HloOp") -> str:
    m = _OPNAME_RE.search(op.rest)
    if not m:
        return op.opcode
    name = m.group(1)
    # keep the jaxpr-level tail: "jit(f)/a/b/c" → last two segments
    parts = name.split("/")
    return "/".join(parts[-2:]) if len(parts) > 1 else name


def _trip_count(cond_ops: list[HloOp]) -> int:
    """Largest integer constant in the loop condition — the canonical
    lowered-scan bound. 1 if nothing found (conservative)."""
    best = 1
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.match(r"\s*(-?\d+)\s*\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: HloOp, symtab: dict[str, str]) -> float:
    out_el, _ = _numel_bytes(op.rtype)
    # contracted size = lhs elements / product(lhs batch+free dims in result)
    operands = _OPERAND_RE.findall(op.rest.split("metadata")[0])
    if not operands:
        return 2.0 * out_el
    lhs_type = symtab.get(operands[0], "")
    lhs_el, _ = _numel_bytes(lhs_type)
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if mm and lhs_type:
        dims_m = _SHAPE_RE.search(lhs_type)
        if dims_m:
            dims = [int(d) for d in dims_m.group(2).split(",") if d]
            for idx in mm.group(1).split(","):
                if idx and int(idx) < len(dims):
                    k *= dims[int(idx)]
    return 2.0 * out_el * k


def _fused_param_slice_bytes(fused_ops: list[HloOp]) -> dict[int, int]:
    """For a fused computation: parameter index → slice bytes, for
    parameters whose only use is a dynamic-slice (gather-one-step pattern)."""
    if not fused_ops:
        return {}
    param_idx: dict[str, int] = {}
    for op in fused_ops:
        if op.opcode == "parameter":
            m = re.match(r"\s*(\d+)\s*\)", op.rest)
            if m:
                param_idx[op.name] = int(m.group(1))
    uses: dict[str, list[HloOp]] = {}
    for op in fused_ops:
        for nm in _OPERAND_RE.findall(op.rest.split("metadata")[0]):
            if nm in param_idx:
                uses.setdefault(nm, []).append(op)
    out: dict[int, int] = {}
    for pname, users in uses.items():
        if users and all(u.opcode == "dynamic-slice" for u in users):
            total = 0
            for u in users:
                _, b = _numel_bytes(u.rtype)
                total += b
            out[param_idx[pname]] = total
    return out


def cost_from_hlo_text(text: str) -> HloCost:
    comps = parse_hlo_computations(text)
    if "__entry__" not in comps:
        return HloCost(0.0, 0.0, 0.0, {})

    flops = 0.0
    byts = 0.0
    coll: dict[str, float] = {}
    coll_by_op: dict[str, float] = {}
    bytes_by_op: dict[str, float] = {}

    def _acc(d, key, val):
        d[key] = d.get(key, 0.0) + val

    def symtab_of(ops: list[HloOp]) -> dict[str, str]:
        return {o.name: o.rtype for o in ops}

    seen_stack: set[tuple[str, float]] = set()

    def walk(comp_name: str, mult: float, top_level: bool):
        nonlocal flops, byts
        ops = comps.get(comp_name)
        if ops is None:
            return
        symtab = symtab_of(ops)
        for op in ops:
            code = op.opcode
            if code == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w\.\-]+)", op.rest)
                cm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    walk(body, mult * trips, top_level)
                continue
            if code in ("fusion", "call"):
                cm = _CALL_ATTR.search(op.rest)
                # boundary traffic: operands + result, refined two ways:
                # (a) in-place accumulators — an operand with exactly the
                #     result's type is aliased (scan-grad DUS accumulation):
                #     skip the full buffer, the real traffic is the slice;
                # (b) fused dynamic-slice reads — a fusion parameter whose
                #     only use inside the fused computation is a
                #     dynamic-slice only reads the slice, not the buffer
                #     (scan xs/cache slicing) — use the slice bytes.
                _, rb = _numel_bytes(op.rtype)
                operand_names = _OPERAND_RE.findall(
                    op.rest.split(", calls")[0].split("metadata")[0]
                )
                overrides = (
                    _fused_param_slice_bytes(comps.get(cm.group(1), []))
                    if cm else {}
                )
                aliased = False
                ob = 0
                for pi, nm in enumerate(operand_names):
                    t = symtab.get(nm, "")
                    if not aliased and t and _strip_layout(t) == _strip_layout(op.rtype):
                        aliased = True      # skip the aliased accumulator once
                        continue
                    _, b = _numel_bytes(t)
                    if pi in overrides:
                        b = min(b, overrides[pi])
                    ob += b
                contrib = mult * (ob if aliased else rb + ob)
                byts += contrib
                _acc(bytes_by_op, _op_label(op), contrib)
                if cm:
                    walk(cm.group(1), mult, False)
                continue
            if code in ("dot", "convolution"):
                flops += mult * _dot_flops(op, symtab)
                if top_level:
                    _, rb = _numel_bytes(op.rtype)
                    byts += mult * rb * 3  # lhs+rhs+out rough
                continue
            base = code.replace("-start", "")
            if base in _COLLECTIVES:
                if code.endswith("-done"):
                    continue
                _, rb = _numel_bytes(op.rtype)
                gm = _GROUPS_RE.search(op.rest)
                g = max(int(gm.group(2)) if gm else 2, 2)
                if base == "all-gather":
                    wire = rb * (g - 1) / g
                elif base == "all-reduce":
                    wire = 2 * rb * (g - 1) / g
                elif base == "reduce-scatter":
                    wire = rb * (g - 1)
                elif base == "all-to-all":
                    wire = rb * (g - 1) / g
                else:
                    wire = rb
                coll[base] = coll.get(base, 0.0) + mult * wire
                _acc(coll_by_op, f"{base}:{_op_label(op)}", mult * wire)
                continue
            if code in _ELEMENTWISE_FLOP_OPS:
                out_el, rb = _numel_bytes(op.rtype)
                flops += mult * out_el
                if top_level:
                    byts += mult * rb
                continue
            if code == "dynamic-update-slice":
                # in-place: traffic = the update slice (operand 1), not the buffer
                ops_n = _OPERAND_RE.findall(op.rest.split("metadata")[0])
                upd = symtab.get(ops_n[1], "") if len(ops_n) > 1 else ""
                _, ub = _numel_bytes(upd)
                byts += mult * 2 * ub
                continue
            if top_level and code in ("copy", "transpose", "concatenate",
                                      "gather", "scatter", "sort", "pad"):
                _, rb = _numel_bytes(op.rtype)
                byts += mult * 2 * rb

    walk("__entry__", 1.0, True)
    top = lambda d, n=20: dict(sorted(d.items(), key=lambda kv: -kv[1])[:n])
    return HloCost(
        flops=flops,
        bytes_accessed=byts,
        collective_bytes=sum(coll.values()),
        collective_by_kind={k: float(v) for k, v in coll.items()},
        collective_by_op=top(coll_by_op),
        bytes_by_op=top(bytes_by_op),
    )
