"""Target-hardware constants (TPU v5e) for the roofline terms."""
from __future__ import annotations

from typing import NamedTuple


class HwSpec(NamedTuple):
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_bw_per_link: float     # bytes/s per link
    ici_links: int             # links per chip participating in a collective
    hbm_bytes: float           # capacity per chip


TPU_V5E = HwSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    ici_links=1,               # conservative: one active link per chip
    hbm_bytes=16e9,
)
