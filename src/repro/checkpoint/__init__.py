"""repro.checkpoint — crash-safe npz-based pytree checkpointing."""
from repro.checkpoint.ckpt import (
    CheckpointCorruptError,
    clean_stale_tmp,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointCorruptError",
    "clean_stale_tmp",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
]
