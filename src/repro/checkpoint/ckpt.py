"""Crash-safe, dependency-free pytree checkpointing (DESIGN.md §15).

Leaves are stored in one ``.npz`` per step keyed by the flattened tree path
(``a/b/0/c``).  The manifest — step, key order, and a per-leaf SHA-256
checksum — is embedded *inside* the same ``.npz`` as the ``__manifest__``
entry, so arrays and manifest commit in a single ``os.replace``: a
checkpoint either exists completely or not at all.  (The historical v1
format wrote a sidecar ``ckpt_<step>.json`` *after* the ``os.replace``,
leaving a crash window in which ``latest_step`` advertised a step
``restore_checkpoint`` could not load; v1 checkpoints remain readable.)

Fault-domain invariants (the chaos harness in ``scripts/chaos.py`` pins
them end-to-end):

* **atomic commit** — writes go to a ``.tmp-<pid>`` file and are renamed
  into place; a crash mid-save leaves only a tmp file, never a partial
  checkpoint under the canonical name;
* **completeness** — :func:`latest_step` counts only steps whose unit is
  complete (embedded manifest present, or the legacy npz+json pair);
* **integrity + graceful degradation** — :func:`restore_checkpoint`
  verifies the zip container and every leaf checksum; a truncated or
  corrupt *latest* checkpoint is quarantined (renamed ``*.corrupt``, with
  a warning) and restore falls back to the newest valid one instead of
  raising.  Corruption is fatal only when the caller pinned an explicit
  ``step``;
* **hygiene** — stale ``*.tmp*`` files from crashed saves are removed on
  the next save or restore in that directory (single-writer convention),
  and ``keep_last`` bounds how many committed checkpoints are retained.

This intentionally targets the single-host CPU harness — a real multi-pod
deployment would swap in a tensor-store backend behind the same interface,
which is why the interface is (tree, step, dir) and nothing else.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import warnings
import zipfile
from typing import Any

import jax
import numpy as np

CKPT_VERSION = 2
_MANIFEST_KEY = "__manifest__"
_CKPT_RE = re.compile(r"ckpt_(\d+)\.npz$")
_TMP_RE = re.compile(r"\.tmp[^/]*$")


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file failed container or checksum verification.

    Raised to the caller only for an explicitly pinned ``step``; the
    latest-valid fallback path catches it, quarantines the file, and
    degrades to the previous checkpoint instead.
    """


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


def _leaf_sha256(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _npz_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")


def _json_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")


def clean_stale_tmp(ckpt_dir: str) -> list[str]:
    """Remove orphaned ``*.tmp*`` files a crashed save left behind.

    Called on every save and restore (single-writer convention: no other
    process is mid-save in this directory).  Returns the removed paths.
    """
    if not os.path.isdir(ckpt_dir):
        return []
    removed = []
    for f in os.listdir(ckpt_dir):
        if _TMP_RE.search(f):
            path = os.path.join(ckpt_dir, f)
            try:
                os.remove(path)
                removed.append(path)
            except OSError:  # pragma: no cover — racing delete
                pass
    return removed


def _is_complete(ckpt_dir: str, fname: str, step: int) -> bool:
    """A step is complete iff its manifest/arrays pair is one unit:
    v2 = embedded manifest inside an intact zip container; v1 (legacy) =
    the npz plus its sidecar json both present."""
    path = os.path.join(ckpt_dir, fname)
    try:
        with zipfile.ZipFile(path) as zf:
            if f"{_MANIFEST_KEY}.npy" in zf.namelist():
                return True
    except (zipfile.BadZipFile, OSError):
        return False
    return os.path.exists(_json_path(ckpt_dir, step))


def _complete_steps(ckpt_dir: str) -> list[int]:
    """Steps with a complete (restorable-in-principle) checkpoint unit,
    ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for f in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(f)
        if m and _is_complete(ckpt_dir, f, int(m.group(1))):
            steps.append(int(m.group(1)))
    return sorted(steps)


def save_checkpoint(
    ckpt_dir: str, step: int, tree: Any, keep_last: int | None = None,
) -> str:
    """Atomically write ``tree`` as the step-``step`` checkpoint.

    Arrays and the checksummed manifest land in one ``.npz`` committed by a
    single ``os.replace`` — there is no ordering hazard and no partial
    state under the canonical name.  ``keep_last`` (optional) prunes all
    but the newest N committed checkpoints after the write succeeds (the
    new checkpoint is only counted once it is durable).
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    clean_stale_tmp(ckpt_dir)
    items = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(v) for i, (k, v) in enumerate(items)}
    manifest = {
        "version": CKPT_VERSION,
        "step": int(step),
        "keys": [k for k, _ in items],
        "checksums": [_leaf_sha256(arrays[f"leaf_{i}"])
                      for i in range(len(items))],
    }
    arrays[_MANIFEST_KEY] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8
    )
    path = _npz_path(ckpt_dir, step)
    tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    if keep_last is not None and keep_last > 0:
        for old in _complete_steps(ckpt_dir)[:-keep_last]:
            for stale in (_npz_path(ckpt_dir, old), _json_path(ckpt_dir, old)):
                if os.path.exists(stale):
                    os.remove(stale)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a *complete* checkpoint unit — never a step whose
    manifest/arrays pair a crash left half-written (such a step would make
    a ``resume``-style caller raise on a checkpoint this function itself
    advertised)."""
    steps = _complete_steps(ckpt_dir)
    return steps[-1] if steps else None


def _read_unit(ckpt_dir: str, step: int) -> tuple[dict, Any]:
    """Load and integrity-check one checkpoint unit → (manifest, npz data).

    Raises :class:`CheckpointCorruptError` on any container, manifest, or
    checksum failure — the caller decides whether that is fatal (explicit
    step) or a fallback trigger (latest-valid walk).
    """
    path = _npz_path(ckpt_dir, step)
    try:
        data = np.load(path, allow_pickle=False)
        names = set(data.files)
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise CheckpointCorruptError(f"{path}: unreadable container: {e}") from e
    if _MANIFEST_KEY in names:
        try:
            manifest = json.loads(bytes(np.asarray(data[_MANIFEST_KEY])))
        except (ValueError, KeyError) as e:
            raise CheckpointCorruptError(f"{path}: bad manifest: {e}") from e
    else:
        # legacy v1: sidecar manifest, no checksums to verify
        try:
            with open(_json_path(ckpt_dir, step)) as f:
                manifest = json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointCorruptError(
                f"{path}: missing/bad legacy sidecar manifest: {e}") from e
        manifest.setdefault("version", 1)
    keys = manifest.get("keys")
    if not isinstance(keys, list):
        raise CheckpointCorruptError(f"{path}: manifest has no key list")
    checksums = manifest.get("checksums")
    for i, key in enumerate(keys):
        name = f"leaf_{i}"
        if name not in names:
            raise CheckpointCorruptError(f"{path}: missing array {name} ({key})")
        try:
            arr = data[name]
        except (zipfile.BadZipFile, OSError, ValueError) as e:
            raise CheckpointCorruptError(
                f"{path}: truncated array {name} ({key}): {e}") from e
        if checksums is not None and _leaf_sha256(arr) != checksums[i]:
            raise CheckpointCorruptError(
                f"{path}: checksum mismatch on {name} ({key}) — silent "
                "corruption (bit rot or a torn write)")
    return manifest, data


def _quarantine(ckpt_dir: str, step: int, reason: str) -> None:
    """Move a failed checkpoint unit aside (``*.corrupt``) so the fallback
    walk and future ``latest_step`` calls never see it again."""
    warnings.warn(
        f"checkpoint step {step} failed verification and was quarantined: "
        f"{reason}", RuntimeWarning, stacklevel=3,
    )
    for path in (_npz_path(ckpt_dir, step), _json_path(ckpt_dir, step)):
        if os.path.exists(path):
            try:
                os.replace(path, path + ".corrupt")
            except OSError:  # pragma: no cover — racing delete
                pass


def _build_tree(manifest: dict, data, template: Any, path: str):
    tmpl_items = _flatten_with_paths(template)
    tmpl_keys = [k for k, _ in tmpl_items]
    if tmpl_keys != manifest["keys"]:
        ckpt_keys = set(manifest["keys"])
        raise ValueError(
            "checkpoint structure mismatch:\n"
            f"  missing: {set(tmpl_keys) - ckpt_keys}\n"
            f"  extra:   {ckpt_keys - set(tmpl_keys)}"
        )
    leaves = []
    for i, (k, t) in enumerate(tmpl_items):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(np.shape(t)):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {np.shape(t)}")
        leaves.append(jax.numpy.asarray(arr, dtype=t.dtype))
    _, treedef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_checkpoint(
    ckpt_dir: str, template: Any, step: int | None = None
) -> tuple[Any, int]:
    """Restore into the structure (and dtypes) of ``template``.

    With ``step=None`` (the default) the newest checkpoint is verified and
    loaded; if it fails integrity checks it is quarantined with a warning
    and restore *degrades gracefully* to the next-newest valid checkpoint —
    a truncated or bit-rotted latest file costs progress since the previous
    checkpoint, never the run.  An explicit ``step`` pins one checkpoint:
    corruption there raises :class:`CheckpointCorruptError`.

    Structure/shape mismatch against ``template`` is always a
    ``ValueError`` (it is a caller bug, not file damage): ``missing`` lists
    template keys the checkpoint lacks, ``extra`` lists checkpoint keys the
    template does not expect.
    """
    clean_stale_tmp(ckpt_dir)
    if step is not None:
        manifest, data = _read_unit(ckpt_dir, step)
        return _build_tree(manifest, data, template, _npz_path(ckpt_dir, step)), step
    candidates = _complete_steps(ckpt_dir)
    if not candidates:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    for s in reversed(candidates):
        try:
            manifest, data = _read_unit(ckpt_dir, s)
        except CheckpointCorruptError as e:
            _quarantine(ckpt_dir, s, str(e))
            continue
        return _build_tree(manifest, data, template, _npz_path(ckpt_dir, s)), s
    raise FileNotFoundError(
        f"no valid checkpoints in {ckpt_dir} (all candidates quarantined)"
    )
