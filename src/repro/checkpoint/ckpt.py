"""Minimal, dependency-free pytree checkpointing.

Leaves are stored in one ``.npz`` per step keyed by the flattened tree path
(``a/b/0/c``), plus a tiny JSON manifest with the step and key order, so a
checkpoint restores into an identical pytree structure (the template tree
provides structure + dtypes; shapes are validated on restore).

This intentionally targets the single-host CPU harness — a real multi-pod
deployment would swap in a tensor-store backend behind the same interface,
which is why the interface is (tree, step, dir) and nothing else.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    items = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(v) for i, (k, v) in enumerate(items)}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    np.savez(path + ".tmp.npz", **arrays)
    os.replace(path + ".tmp.npz", path)
    manifest = {"step": step, "keys": [k for k, _ in items]}
    with open(os.path.join(ckpt_dir, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(ckpt_dir)
        if (m := re.match(r"ckpt_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template: Any, step: int | None = None) -> tuple[Any, int]:
    """Restore into the structure (and dtypes) of ``template``."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    with open(os.path.join(ckpt_dir, f"ckpt_{step:08d}.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz"))

    tmpl_items = _flatten_with_paths(template)
    tmpl_keys = [k for k, _ in tmpl_items]
    if tmpl_keys != manifest["keys"]:
        raise ValueError(
            "checkpoint structure mismatch:\n"
            f"  missing: {set(manifest['keys']) - set(tmpl_keys)}\n"
            f"  extra:   {set(tmpl_keys) - set(manifest['keys'])}"
        )
    leaves = []
    for i, (k, t) in enumerate(tmpl_items):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(np.shape(t)):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {np.shape(t)}")
        leaves.append(jax.numpy.asarray(arr, dtype=t.dtype))
    _, treedef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
