"""Pytree optimizers: SGD / momentum / AdamW + the paper's projected step.

Each optimizer is an ``Optimizer(init, update)`` pair:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = tree_add(params, updates)

``updates`` are *deltas* (already scaled by −lr), so optimizer composition
and the Byzantine-aggregated path stay uniform. Learning rates may be
floats or callables ``step → lr`` (schedules below).

``projected_sgd`` implements the paper's Fact-2.5 mirror-descent step: after
the SGD move, project onto the ball ‖x − x₁‖ ≤ D (global l2 over the whole
pytree) — used by the convex experiments and available for LM training.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.utils import (
    clip_by_global_norm,
    project_ball,
    tree_add,
    tree_map,
    tree_scale,
    tree_sub,
    tree_zeros_like,
)

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]   # (grads, state, params, step)


def _lr_at(lr: Schedule, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1) -> Callable:
    def lr(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)
    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1) -> Callable:
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)
    def lr(step):
        w = jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)
        return w * cos(jnp.maximum(step - warmup, 0))
    return lr


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def sgd(lr: Schedule, grad_clip: float | None = None) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params, step):
        if grad_clip:
            grads = clip_by_global_norm(grads, grad_clip)
        return tree_scale(grads, -_lr_at(lr, step)), state

    return Optimizer(init, update)


def momentum(lr: Schedule, beta: float = 0.9, nesterov: bool = False,
             grad_clip: float | None = None) -> Optimizer:
    def init(params):
        return {"m": tree_zeros_like(params)}

    def update(grads, state, params, step):
        if grad_clip:
            grads = clip_by_global_norm(grads, grad_clip)
        m = tree_map(lambda mi, gi: beta * mi + gi, state["m"], grads)
        d = tree_map(lambda mi, gi: beta * mi + gi, m, grads) if nesterov else m
        return tree_scale(d, -_lr_at(lr, step)), {"m": m}

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, grad_clip: float | None = None) -> Optimizer:
    """AdamW with f32 moments regardless of param dtype (bf16-safe)."""

    def init(params):
        f32 = lambda t: tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
        return {"m": f32(params), "v": f32(params)}

    def update(grads, state, params, step):
        if grad_clip:
            grads = clip_by_global_norm(grads, grad_clip)
        t = step.astype(jnp.float32) + 1.0
        m = tree_map(lambda mi, gi: b1 * mi + (1 - b1) * gi.astype(jnp.float32),
                     state["m"], grads)
        v = tree_map(lambda vi, gi: b2 * vi + (1 - b2) * jnp.square(gi.astype(jnp.float32)),
                     state["v"], grads)
        mh = tree_scale(m, 1.0 / (1 - b1 ** t))
        vh = tree_scale(v, 1.0 / (1 - b2 ** t))
        lr_t = _lr_at(lr, step)
        upd = tree_map(
            lambda mi, vi, pi: (-lr_t * (mi / (jnp.sqrt(vi) + eps)
                                         + weight_decay * pi.astype(jnp.float32))
                                ).astype(pi.dtype),
            mh, vh, params,
        )
        return upd, {"m": m, "v": v}

    return Optimizer(init, update)


def projected_sgd(lr: Schedule, x1: Any, D: float,
                  grad_clip: float | None = None) -> Optimizer:
    """The paper's update: x ← Proj_{‖·−x₁‖≤D}(x − η ξ). ``update`` returns
    the delta that lands exactly on the projected point."""
    base = sgd(lr, grad_clip)

    def update(grads, state, params, step):
        delta, state2 = base.update(grads, state, params, step)
        x_proj = project_ball(tree_add(params, delta), x1, D)
        return tree_sub(x_proj, params), state2

    return Optimizer(base.init, update)
