"""repro.optim — pytree optimizers built in-repo (no optax dependency)."""
from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum,
    adamw,
    projected_sgd,
    cosine_schedule,
    linear_warmup_cosine,
)

__all__ = [
    "Optimizer",
    "sgd",
    "momentum",
    "adamw",
    "projected_sgd",
    "cosine_schedule",
    "linear_warmup_cosine",
]
