"""Render the scenario-campaign leaderboard from ``BENCH_scenarios.json``
(produced by ``python -m benchmarks.bench_scenarios``) as markdown tables:
which aggregator breaks under which dynamic adversary, the guard's
Theorem-3.8 bound check, detection-latency percentiles, and the
batched-vs-looped wall-clock.

    PYTHONPATH=src python scripts/render_scenarios.py [BENCH_scenarios.json]
"""
from __future__ import annotations

import json
import sys


def _fmt_gap(row: dict) -> str:
    mark = " ✗" if row["breaks"] else ""
    return f"{row['gap_med']:.5f}{mark}"


# mega-campaign grids put hundreds of rows behind every table; render at
# most this many and close the table with a summary footer pointing at
# the JSON record (which always carries the full data)
MAX_TABLE_ROWS = 40


def _cap(rows: list, what: str) -> tuple[list, str | None]:
    """First ``MAX_TABLE_ROWS`` rows + a footer naming how many were cut."""
    if len(rows) <= MAX_TABLE_ROWS:
        return rows, None
    return rows[:MAX_TABLE_ROWS], (
        f"\n… {len(rows) - MAX_TABLE_ROWS} more {what} rows not shown "
        f"({len(rows)} total); see the JSON record for the full table.")


def _guard_bound_lines(guard_bound: list[dict]) -> list[str]:
    lines = []
    lines.append("\n## ByzantineSGD vs the Theorem-3.8 bound\n")
    lines.append("(bound evaluated at the realized ever-Byzantine "
                 "fraction, heterogeneity-adjusted V and effective "
                 "reporter count; one row per guard backend variant; "
                 "`—` marks rows outside the α_ever < 1/2 regime, "
                 "where the theorem makes no claim)\n")
    lines.append("| guard | scenario | α | α_ever | V | m_eff "
                 "| gap med | bound | within |")
    lines.append("|---" * 9 + "|")
    guard_bound, footer = _cap(guard_bound, "guard-bound")
    for g in guard_bound:
        if g.get("in_regime", True):
            mark = "✓" if g["within"] else "✗"
        else:
            mark = "— (α_ever ≥ ½)"
        v = g.get("V_realized")
        m_eff = g.get("m_eff")
        lines.append(
            f"| {g.get('aggregator', 'byzantine_sgd')} "
            f"| {g['scenario']} | {g['alpha']} | {g['alpha_ever']:.3f} "
            f"| {'' if v is None else f'{v:.3f}'} "
            f"| {'' if m_eff is None else f'{m_eff:.1f}'} "
            f"| {g['gap_med']:.5f} | {g['bound']:.4f} "
            f"| {mark} |"
        )
    if footer:
        lines.append(footer)
    return lines


def render(rec: dict) -> str:
    aggs = rec["aggregators"]
    lines = []
    cfg, thr = rec["config"], rec["thresholds"]
    lines.append("## Scenario leaderboard — median f(x̄)−f(x*) across seeds\n")
    lines.append(
        f"m={cfg['m']}, T={cfg['T']}, η={cfg['eta']}; "
        f"✗ = broken (median gap above that α's break threshold); "
        f"{rec['n_runs_per_aggregator']} runs per aggregator, one jit.\n"
    )
    alphas = sorted({r["alpha"] for r in rec["leaderboard"]})
    for alpha in alphas:
        rows = [r for r in rec["leaderboard"] if r["alpha"] == alpha]
        scenarios = sorted({r["scenario"] for r in rows})
        cell = {(r["scenario"], r["aggregator"]): r for r in rows}
        lines.append(f"\n### α = {alpha} "
                     f"(break > {thr[str(alpha)]['break_eps']:.3f})\n")
        lines.append("| scenario | " + " | ".join(aggs) + " |")
        lines.append("|---" * (len(aggs) + 1) + "|")
        for scn in scenarios:
            vals = [_fmt_gap(cell[(scn, a)]) for a in aggs]
            lines.append(f"| {scn} | " + " | ".join(vals) + " |")

    if rec.get("aggregator_ranking"):
        lines.append("\n## Aggregator ranking — mean rank over every "
                     "(scenario × α) cell\n")
        lines.append("| aggregator | mean rank | median gap | worst gap "
                     "| breaks | cells |")
        lines.append("|---" * 6 + "|")
        for r in rec["aggregator_ranking"]:
            lines.append(
                f"| {r['aggregator']} | {r['mean_rank']:.2f} "
                f"| {r['gap_med_median']:.5f} | {r['gap_med_worst']:.5f} "
                f"| {r['n_breaks']} | {r['n_cells']} |"
            )

    if rec.get("degradation"):
        lines.append("\n## Dynamic-vs-static degradation\n")
        lines.append("| aggregator | dynamic | static | α | gap dyn | gap static "
                     "| ratio | degraded |")
        lines.append("|---" * 8 + "|")
        for d in sorted(rec["degradation"],
                        key=lambda d: -d["ratio"])[:12]:
            lines.append(
                f"| {d['aggregator']} | {d['dynamic']} | {d['static']} "
                f"| {d['alpha']} | {d['gap_dynamic']:.5f} "
                f"| {d['gap_static']:.5f} | {d['ratio']:.1f}x "
                f"| {'**yes**' if d['degraded'] else 'no'} |"
            )

    if rec.get("guard_bound"):
        lines.extend(_guard_bound_lines(rec["guard_bound"]))

    het = rec.get("heterogeneous")
    if het:
        lines.append("\n## Heterogeneous slice — per-worker-state profiles "
                     "(DESIGN.md §13)\n")
        lines.append(
            f"profiles: {', '.join(het.get('profiles', []))}; "
            f"max_delay={het.get('max_delay', 0)}; scenario labels carry "
            f"the profile suffix; {het['n_runs_per_aggregator']} runs per "
            f"aggregator, one jit.\n"
        )
        lines.append("| scenario | aggregator | gap med | detect p50 "
                     "| ever filtered good |")
        lines.append("|---" * 5 + "|")
        het_rows, het_footer = _cap(het["leaderboard"], "heterogeneous")
        for r in het_rows:
            lines.append(
                f"| {r['scenario']} | {r['aggregator']} "
                f"| {r['gap_med']:.5f} | {r['detect_p50']} "
                f"| {'yes' if r['ever_filtered_good'] else 'no'} |"
            )
        if het_footer:
            lines.append(het_footer)
        if het.get("guard_bound"):
            lines.extend(_guard_bound_lines(het["guard_bound"]))

    mega = rec.get("mega")
    if mega and mega.get("grid"):
        g = mega["grid"]
        lines.append("\n## Mega campaign — chunked 10× grid (DESIGN.md §14)\n")
        ratio = g.get("peak_temp_ratio_vs_reference")
        bounded = g.get("peak_memory_bounded")
        lines.append(
            f"{g['total_runs']} runs ({g['n_runs']} grid rows × "
            f"{g['n_variants']} variants, T={g['T']}) under one traced "
            f"campaign: `lax.map` over {g['n_chunks']} chunks of "
            f"{g['chunk_size']}; backends: {', '.join(g['backends'])}.\n"
        )
        if ratio is not None:
            lines.append(
                f"peak temp memory vs the {g['reference_runs']}-run "
                f"unchunked reference: {ratio:.2f}× "
                f"({'✓ bounded' if bounded else '✗ NOT bounded'}, "
                f"assertion ≤ 2×); wall {g['wall_s']:.1f}s "
                f"+ {g['compile_s']:.1f}s compile.\n"
            )
        if mega.get("aggregator_ranking"):
            lines.append("| aggregator | mean rank | median gap | worst gap "
                         "| breaks | cells |")
            lines.append("|---" * 6 + "|")
            for r in mega["aggregator_ranking"]:
                lines.append(
                    f"| {r['aggregator']} | {r['mean_rank']:.2f} "
                    f"| {r['gap_med_median']:.5f} "
                    f"| {r['gap_med_worst']:.5f} "
                    f"| {r['n_breaks']} | {r['n_cells']} |"
                )
        if mega.get("guard_bound"):
            lines.extend(_guard_bound_lines(mega["guard_bound"]))

    lines.append("\n## Detection latency (ByzantineSGD), steps to full filter\n")
    lines.append("| guard | scenario | α | p50 | p90 | detect rate |")
    lines.append("|---" * 6 + "|")
    lat_rows, lat_footer = _cap(
        [r for r in rec["leaderboard"]
         if r["aggregator"].startswith("byzantine_sgd")], "detection-latency")
    for r in lat_rows:
        lines.append(f"| {r['aggregator']} | {r['scenario']} | {r['alpha']} "
                     f"| {r['detect_p50']} | {r['detect_p90']} "
                     f"| {r['detect_rate']:.2f} |")
    if lat_footer:
        lines.append(lat_footer)

    ba = rec.get("backend_axis")
    if ba:
        shape = ba["model_shape"]
        lines.append("\n## Guard-backend axis (DESIGN.md §9)\n")
        lines.append(
            f"measured on `{ba['measured_backend']}` "
            f"(fused via Pallas interpreter: {ba['fused_runs_interpret']}); "
            f"model = bytes/HBM-bandwidth on {shape['hw']} at "
            f"m={shape['m']}, d={shape['d']}.\n"
        )
        lines.append("| backend | campaign wall s | runs | model step bytes "
                     "| model steady-state µs |")
        lines.append("|---" * 5 + "|")
        for be, p in ba["per_backend"].items():
            lines.append(
                f"| {be} | {p['campaign_wall_s']:.2f} | {p['campaign_runs']} "
                f"| {p['model_step_bytes']:,} "
                f"| {p['model_steady_state_us']:.0f} |"
            )
        if "fused_le_dense_model" in ba:
            lines.append(
                f"\nfused ≤ dense at the headline shape (model): "
                f"{'✓' if ba['fused_le_dense_model'] else '✗'}"
            )

    wc = rec["wall_clock"]
    lines.append(
        f"\ncampaign wall-clock: {wc['runs_total']} runs in "
        f"{wc['batched_s']:.2f}s (one jit; +{wc['compile_s']:.1f}s compile)"
    )
    mx = rec.get("matrix6x6_wallclock")
    if mx and "looped_s" in mx:
        lines.append(
            f"\n6×6 matrix (T={mx['T']}): batched {mx['batched_s']:.2f}s vs "
            f"looped {mx['looped_s']:.2f}s → "
            f"{mx['speedup_steady']:.1f}x steady-state "
            f"({mx['speedup_incl_compile']:.2f}x incl. compile)"
        )
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_scenarios.json"
    with open(path) as f:
        rec = json.load(f)
    print(render(rec))


if __name__ == "__main__":
    main()
