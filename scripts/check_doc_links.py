#!/usr/bin/env python3
"""Docs-link check (CI): every ``DESIGN.md §N`` reference in the tree must
resolve to a ``## §N`` heading in DESIGN.md, and every file that mentions
DESIGN.md / README.md must find it present.  Exits non-zero with a listing
of dangling references.

Usage: python scripts/check_doc_links.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

SECTION_REF = re.compile(r"DESIGN\.md\s*§\s*([0-9]+(?:\.[0-9]+)?)")
HEADING = re.compile(r"^#{1,6}\s*§\s*([0-9]+(?:\.[0-9]+)?)\b", re.M)
SCAN_SUFFIXES = {".py", ".md"}
SKIP_DIRS = {".git", "__pycache__", ".github", "experiments"}
SKIP_FILES = {"DESIGN.md"}  # self-references are headings, not links


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    design = root / "DESIGN.md"
    if not design.exists():
        print(f"FAIL: {design} does not exist but is referenced across the tree")
        return 1
    sections = set(HEADING.findall(design.read_text()))
    print(f"DESIGN.md sections: {sorted(sections, key=float)}")

    errors = []
    n_refs = 0
    for path in sorted(root.rglob("*")):
        if path.suffix not in SCAN_SUFFIXES or path.name in SKIP_FILES:
            continue
        if any(part in SKIP_DIRS for part in path.parts):
            continue
        text = path.read_text(errors="replace")
        for lineno, line in enumerate(text.splitlines(), 1):
            for sec in SECTION_REF.findall(line):
                n_refs += 1
                if sec not in sections:
                    errors.append(
                        f"{path.relative_to(root)}:{lineno}: DESIGN.md §{sec} "
                        f"does not resolve (have {sorted(sections, key=float)})"
                    )

    if errors:
        print(f"FAIL: {len(errors)} dangling DESIGN.md section reference(s):")
        for e in errors:
            print(" ", e)
        return 1
    print(f"ok: {n_refs} DESIGN.md § references all resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
