"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from the dry-run JSON
records. §Perf and §Paper-validation sections are maintained by hand in
EXPERIMENTS.md between the AUTOGEN markers.

    PYTHONPATH=src python scripts/render_experiments.py
"""
from __future__ import annotations

import glob
import json
import os

BEGIN = "<!-- AUTOGEN-DRYRUN-BEGIN -->"
END = "<!-- AUTOGEN-DRYRUN-END -->"


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def load_records():
    recs = []
    for p in sorted(glob.glob("experiments/dryrun/*.json")):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def render() -> str:
    recs = load_records()
    base = [r for r in recs if not r.get("opts")]
    sp = [r for r in base if not r["multi_pod"]]
    mp = [r for r in base if r["multi_pod"]]

    lines = [BEGIN, ""]
    lines.append("### §Dry-run — lowering + compile status\n")
    lines.append(f"Single-pod (16×16 = 256 chips): **{len(sp)}/40** combinations "
                 f"compiled; multi-pod (2×16×16 = 512 chips): **{len(mp)}/40**. "
                 "Per-combination JSON records live in `experiments/dryrun/`.\n")
    lines.append("| arch | shape | variant | compile s | arg GB/dev | temp GB/dev | fits 16G |")
    lines.append("|---|---|---|---|---|---|---|")
    for r in sp:
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} | {r['compile_s']:.0f} "
            f"| {fmt_bytes(m['argument_bytes'])} | {fmt_bytes(m['temp_bytes'])} "
            f"| {'✓' if m['fits_hbm_16g'] else '✗'} |"
        )
    lines.append("")
    lines.append("Multi-pod pass (proves the `pod` axis shards; same code path, "
                 "W=32 workers, worker axis `('pod','data')`):\n")
    lines.append("| arch | shape | compile s | collective GB/dev | bottleneck |")
    lines.append("|---|---|---|---|---|")
    for r in mp:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} "
            f"| {fmt_bytes(r['collectives']['total_bytes_per_device'])} "
            f"| {r['roofline']['bottleneck']} |"
        )

    lines.append("\n### §Roofline — per (arch × shape), single-pod 16×16\n")
    lines.append("Terms in ms/step/device (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, "
                 "50 GB/s ICI). `useful` = MODEL_FLOPS / (chips · HLO_FLOPs); "
                 "FLOPs/bytes are loop-aware (see `repro.roofline.hlo_cost`).\n")
    lines.append("| arch | shape | compute | memory | collective | bottleneck | useful | MODEL_TFLOPs | note |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in sp:
        rl = r["roofline"]
        note = ""
        if not r["memory"]["fits_hbm_16g"]:
            note = "over-HBM"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['t_compute_s']*1e3:.2f} "
            f"| {rl['t_memory_s']*1e3:.2f} | {rl['t_collective_s']*1e3:.2f} "
            f"| {rl['bottleneck']} | {rl['useful_ratio']:.1%} "
            f"| {rl['model_flops']/1e12:.1f} | {note} |"
        )
    lines.append("")
    lines.append(END)
    return "\n".join(lines)


def main():
    block = render()
    path = "EXPERIMENTS.md"
    if os.path.exists(path):
        text = open(path).read()
        if BEGIN in text and END in text:
            pre = text.split(BEGIN)[0]
            post = text.split(END)[1]
            text = pre + block + post
        else:
            text = text + "\n" + block + "\n"
    else:
        text = block + "\n"
    with open(path, "w") as f:
        f.write(text)
    print(f"rendered {path} with {len(load_records())} records")


if __name__ == "__main__":
    main()
