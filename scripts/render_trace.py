"""Render a guard flight-recorder trace (DESIGN.md §12) as console text.

Input is the structured JSONL event log written by ``--trace`` /
``--trace-out`` (``repro.launch.train``, ``repro.launch.serve``,
``benchmarks.bench_scenarios``): a provenance meta line followed by
``guard_step`` / ``timeline`` / ``span`` / ``roofline`` / ``counter``
events.  Output:

* the meta block (commit, device, measured telemetry overhead);
* a span table (count / total / mean per ``<layer>/<phase>``);
* the roofline comparator rows (measured vs modeled per-step µs);
* per-run filter timelines — per-worker first-filter step split
  byzantine/good, an ASCII Byzantine-survival sparkline, and the last
  recorded frames' martingale deviations vs their thresholds 𝔗;
* serve counters, when present.

    PYTHONPATH=src python scripts/render_trace.py TRACE.jsonl
    PYTHONPATH=src python scripts/render_trace.py TRACE.jsonl --perfetto out.json
"""
from __future__ import annotations

import argparse
from collections import defaultdict

from repro.obs import EventLog, spans_by_name, write_chrome_trace

_META_KEYS = ("tool", "commit", "timestamp", "backend", "device_kind",
              "jax_version", "telemetry_overhead_frac")
_SPARK = " ▁▂▃▄▅▆▇█"


def _sparkline(values: list[float], width: int = 48) -> str:
    if not values:
        return ""
    if len(values) > width:
        stride = len(values) / width
        values = [values[int(i * stride)] for i in range(width)]
    hi = max(max(values), 1e-12)
    return "".join(_SPARK[min(int(v / hi * (len(_SPARK) - 1)), len(_SPARK) - 1)]
                   for v in values)


def _survival_values(timeline_ev: dict, steps: list[dict]) -> list[float]:
    """Per-step byz-survivor series for this run.  Prefers the timeline
    event's full-horizon change-point curve (the ring only holds the last
    ``ring_size`` frames); falls back to reconstructing from the recorded
    guard_step frames (alive ∧ byz)."""
    curve = timeline_ev.get("byz_survival")
    if curve:
        out, last = [], 0.0
        end = int(curve[-1][0])
        pairs = {int(s): float(v) for s, v in curve}
        for step in range(1, end + 1):
            last = pairs.get(step, last)
            out.append(last)
        return out
    byz = timeline_ev.get("byz_mask") or []
    out = []
    for ev in steps:
        alive = ev.get("alive") or []
        out.append(sum(1.0 for a, b in zip(alive, byz) if b and a and a > 0))
    return out


def render(meta: dict, events: list[dict]) -> str:
    lines = ["# Guard flight-recorder trace\n"]
    for k in _META_KEYS:
        if k in meta:
            lines.append(f"- **{k}**: {meta[k]}")
    extra = {k: v for k, v in meta.items()
             if k not in _META_KEYS and k != "type"}
    if extra:
        lines.append(f"- run config: {extra}")

    spans = spans_by_name(events)
    if spans:
        lines.append("\n## Spans\n")
        lines.append("| span | count | total s | mean s |")
        lines.append("|---|---|---|---|")
        for name, rec in sorted(spans.items()):
            lines.append(f"| {name} | {rec['count']} | {rec['total_s']:.3f} "
                         f"| {rec['mean_s']:.4f} |")

    roofline = [e for e in events if e.get("type") == "roofline"]
    if roofline:
        lines.append("\n## Measured vs roofline (per guard step)\n")
        lines.append("| backend | m | d | measured µs | modeled µs | ratio |")
        lines.append("|---|---|---|---|---|---|")
        for r in roofline:
            lines.append(
                f"| {r['backend']} | {r['m']} | {r['d']} "
                f"| {r['measured_step_us']:.1f} | {r['modeled_step_us']:.2f} "
                f"| {r['measured_over_model']:.1f}x |")

    counters = [e for e in events if e.get("type") == "counter"]
    for c in counters:
        lines.append(f"\n## Counter: {c.get('name', '?')}\n")
        lines.append(", ".join(f"{k}={v}" for k, v in sorted(c.items())
                               if k not in ("type", "name")))

    steps_by_run: dict[str, list[dict]] = defaultdict(list)
    for ev in events:
        if ev.get("type") == "guard_step":
            steps_by_run[ev.get("run", "run")].append(ev)
    timelines = {e.get("run", "run"): e for e in events
                 if e.get("type") == "timeline"}

    for run, steps in sorted(steps_by_run.items()):
        lines.append(f"\n## Run: {run}\n")
        tl = timelines.get(run)
        if tl and tl.get("first_filter_step") is not None:
            ffs = tl["first_filter_step"]
            byz = tl.get("byz_mask") or [False] * len(ffs)
            rows = [(w, int(s), bool(b))
                    for w, (s, b) in enumerate(zip(ffs, byz))]
            caught = sorted(s for _, s, b in rows if b and s > 0)
            missed = sum(1 for _, s, b in rows if b and s <= 0)
            spent = [(w, s) for w, s, b in rows if not b and s > 0]
            lines.append(
                f"- first-filter (byz): {caught if caught else 'none'}"
                + (f", {missed} never caught" if missed else ""))
            lines.append(
                "- good workers filtered: "
                + (str(spent) if spent else "none"))
            surv = _survival_values(tl, steps)
            if surv:
                span = (f"steps 1–{len(surv)}" if tl.get("byz_survival")
                        else f"recorded steps "
                             f"{int(steps[0].get('step', 0))}–"
                             f"{int(steps[-1].get('step', 0))}")
                lines.append(f"- byz survival  `{_sparkline(surv)}` ({span})")
        # martingale-vs-threshold table for the last few recorded frames
        lines.append("\n| step | n_alive | max dev_a / 𝔗_A "
                     "| max dist_b / 𝔗_B | ‖ξ‖ | v_est |")
        lines.append("|---|---|---|---|---|---|")
        def _num(v):
            return "-" if v is None else f"{v:.3g}"

        for ev in steps[-5:]:
            dev_a = ev.get("dev_a") or []
            dist_b = ev.get("dist_b") or []
            da = max((v for v in dev_a if v is not None), default=None)
            db = max((v for v in dist_b if v is not None), default=None)
            thr_a, thr_b = ev.get("thr_a"), ev.get("thr_b")
            lines.append(
                f"| {int(ev.get('step', -1))} "
                f"| {ev.get('n_alive', '-')} "
                f"| {_num(da)} / {_num(thr_a)} "
                f"| {_num(db)} / {_num(thr_b)} "
                f"| {_num(ev.get('xi_norm'))} "
                f"| {_num(ev.get('v_est'))} |")
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="JSONL event log (from --trace/--trace-out)")
    ap.add_argument("--perfetto", default=None, metavar="OUT.json",
                    help="also convert to chrome trace-event JSON "
                         "(load in Perfetto / chrome://tracing)")
    args = ap.parse_args()
    meta, events = EventLog.read_jsonl(args.trace)
    print(render(meta, events))
    if args.perfetto:
        write_chrome_trace(meta, events, args.perfetto)
        print(f"wrote {args.perfetto}")


if __name__ == "__main__":
    main()
