"""Render ``BENCH_train.json`` (produced by ``python -m benchmarks.bench_train``)
as markdown tables: the scan-vs-loop driver wall-clock and the LM train
campaign leaderboard (DESIGN.md §10).

    PYTHONPATH=src python scripts/render_train.py [BENCH_train.json]
"""
from __future__ import annotations

import json
import sys


def render(rec: dict) -> str:
    lines = []
    dw = rec.get("driver_wallclock")
    if dw:
        lines.append("## Train driver — chunked scan vs per-step loop\n")
        lines.append(
            f"{dw['arch']} reduced (d_model={dw['d_model']}), "
            f"{dw['workers']} workers, guard `{dw['guard_backend']}`, "
            f"measured on `{dw['backend']}` (steady state, first call "
            "excluded).\n"
        )
        lines.append("| driver | steady-state µs/step (median ± IQR) "
                     "| first call s |")
        lines.append("|---|---|---|")
        lines.append(f"| loop (per-step dispatch + per-metric transfer) "
                     f"| {dw['loop_steady_state_us_per_step']:.0f} "
                     f"± {dw.get('loop_iqr_us', 0):.0f} "
                     f"| {dw['loop_first_call_s']:.1f} |")
        lines.append(f"| scan (chunk={dw['chunk']}, on-device data) "
                     f"| {dw['scan_steady_state_us_per_step']:.0f} "
                     f"± {dw.get('scan_iqr_us', 0):.0f} "
                     f"| {dw['scan_first_call_s']:.1f} |")
        slack = dw.get("scan_le_loop_slack", 1.0)
        lines.append(
            f"\nscan speedup: {dw['scan_speedup']:.2f}x "
            f"(scan ≤ {slack:g}×loop: {'✓' if dw['scan_le_loop'] else '✗'}; "
            "the slack absorbs shared-CPU noise, see "
            "benchmarks.bench_train.SCAN_LE_LOOP_SLACK)"
        )

    camp = rec.get("campaign")
    if camp:
        cfg = camp["config"]
        lines.append("\n## LM train campaign — one jit over the "
                     "(scenario × α × seed) grid\n")
        lines.append(
            f"{camp['arch']} reduced, m={cfg['m']}, {cfg['steps']} steps, "
            f"{camp['n_runs_per_variant']} runs per variant; "
            f"wall {camp['wall_clock']['batched_s']:.2f}s "
            f"(+{camp['wall_clock']['compile_s']:.1f}s compile) for "
            f"{camp['wall_clock']['runs_total']} runs.\n"
        )
        lines.append("| scenario | α | variant | loss first→final (med) "
                     "| alive_T | byz alive | good filtered |")
        lines.append("|---" * 7 + "|")
        for r in camp["leaderboard"]:
            lines.append(
                f"| {r['scenario']} | {r['alpha']} | {r['variant']} "
                f"| {r['loss_first_med']:.3f}→{r['loss_final_med']:.3f} "
                f"| {r['n_alive_final_min']} "
                f"| {r['byz_alive_final_max']} "
                f"| {'**yes**' if r['ever_filtered_good'] else 'no'} |"
            )
    if rec.get("note"):
        lines.append(f"\n_{rec['note']}_")
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_train.json"
    with open(path) as f:
        rec = json.load(f)
    print(render(rec))


if __name__ == "__main__":
    main()
