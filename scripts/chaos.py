"""Chaos-injection harness (DESIGN.md §15) → ``CHAOS_report.json``.

Runs the fault matrix the checkpoint layer and the sanitize stage promise
to survive, end-to-end through the real entry points (``repro.launch.train``
subprocesses for the crash cases, ``run_campaign`` in-process for the
value-corruption cases):

==================  =======================================================
``kill_resume``     SIGKILL the trainer right after its first periodic
                    checkpoint lands, resume with ``--resume`` — the final
                    checkpoint must be **bit-identical** to an
                    uninterrupted run's
``truncate``        truncate the newest checkpoint file (torn write);
                    ``latest_step`` must skip it and resume from the
                    previous complete one, still bit-identical at the end
``corrupt``         flip a stored leaf under an intact container + stale
                    checksum (silent bit rot); restore must quarantine the
                    file (``*.corrupt``) with a warning and degrade to the
                    previous valid checkpoint, still bit-identical
``sigterm``         SIGTERM mid-run (preemption notice); the trainer must
                    exit cleanly, flushing a resumable final checkpoint
                    within the grace budget, and resume to bit-parity
``nonfinite``       mini campaign with NaN/Inf/bitflip fault plans over
                    every guard backend under ``sanitize="quarantine"`` —
                    every leaderboard gap finite, victims filtered
==================  =======================================================

Bit-parity is the strong form of the resume-equals-uninterrupted contract:
the comparison is over the raw stored arrays of the final checkpoint, not a
float tolerance.

Usage::

    PYTHONPATH=src python scripts/chaos.py --mini          # CI tier-2 shape
    PYTHONPATH=src python scripts/chaos.py --steps 48      # bigger sweep
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _train_cmd(ckpt_dir: str, steps: int, d_model: int, *extra: str) -> list[str]:
    return [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "mamba2-130m", "--reduced",
        "--workers", "4", "--per-worker-batch", "1",
        "--seq-len", "32", "--d-model", str(d_model),
        "--steps", str(steps), "--log-every", "4",
        "--alpha", "0.25", "--attack", "sign_flip",
        "--guard-backend", "dp_exact", "--seed", "0",
        "--ckpt-dir", ckpt_dir, *extra,
    ]


def _env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _run(cmd: list[str], timeout: int = 900) -> subprocess.CompletedProcess:
    return subprocess.run(cmd, env=_env(), cwd=REPO, capture_output=True,
                          text=True, timeout=timeout)


def _wait_for_ckpt(ckpt_dir: str, proc: subprocess.Popen,
                   timeout: float = 600.0) -> str | None:
    """Poll until the first committed ``ckpt_*.npz`` appears (or the
    process exits / times out).  Returns the path or None."""
    t0 = time.time()
    while time.time() - t0 < timeout:
        if os.path.isdir(ckpt_dir):
            names = sorted(f for f in os.listdir(ckpt_dir)
                           if f.startswith("ckpt_") and f.endswith(".npz"))
            if names:
                return os.path.join(ckpt_dir, names[0])
        if proc.poll() is not None:
            return None
        time.sleep(0.25)
    return None


def _final_ckpt_arrays(ckpt_dir: str, step: int) -> dict:
    import numpy as np
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    with np.load(path, allow_pickle=False) as data:
        return {k: np.array(data[k]) for k in data.files}


def _bit_identical(a: dict, b: dict) -> bool:
    import numpy as np

    def eq(x, y):
        if x.dtype != y.dtype or x.shape != y.shape:
            return False
        # equal_nan only exists for float dtypes; exact compare elsewhere
        if np.issubdtype(x.dtype, np.floating):
            return bool(np.array_equal(x, y, equal_nan=True))
        return bool(np.array_equal(x, y))

    return set(a) == set(b) and all(eq(a[k], b[k]) for k in a)


def case_baseline(work: str, steps: int, d_model: int) -> tuple[dict, dict]:
    """Uninterrupted reference run; its final checkpoint is the parity
    target for every crash case."""
    ckpt = os.path.join(work, "baseline")
    p = _run(_train_cmd(ckpt, steps, d_model, "--ckpt-every", "8"))
    ok = p.returncode == 0
    arrays = _final_ckpt_arrays(ckpt, steps) if ok else {}
    return {"ok": ok, "detail": p.stderr[-2000:] if not ok else ""}, arrays


def _resume_and_compare(ckpt: str, steps: int, d_model: int,
                        baseline: dict, expect_warn: bool = False) -> dict:
    p = _run(_train_cmd(ckpt, steps, d_model, "--ckpt-every", "8", "--resume"))
    if p.returncode != 0:
        return {"ok": False, "detail": f"resume failed: {p.stderr[-2000:]}"}
    out = {"ok": True, "resumed_line": next(
        (ln for ln in p.stdout.splitlines() if ln.startswith("resumed")), "")}
    if expect_warn and "quarantined" not in p.stderr:
        return {"ok": False, "detail": "expected a quarantine warning"}
    final = _final_ckpt_arrays(ckpt, steps)
    if not _bit_identical(final, baseline):
        return {"ok": False, "detail": "final checkpoint differs from "
                                       "uninterrupted run (bit-parity broken)"}
    out["bit_identical"] = True
    return out


def case_kill_resume(work: str, steps: int, d_model: int, baseline: dict) -> dict:
    """SIGKILL right after the first periodic checkpoint commits."""
    ckpt = os.path.join(work, "kill")
    proc = subprocess.Popen(_train_cmd(ckpt, steps, d_model, "--ckpt-every", "8"),
                            env=_env(), cwd=REPO,
                            stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    first = _wait_for_ckpt(ckpt, proc)
    if first is None:
        proc.kill()
        return {"ok": False, "detail": "no checkpoint appeared before exit"}
    proc.send_signal(signal.SIGKILL)
    proc.wait()
    return _resume_and_compare(ckpt, steps, d_model, baseline)


def _seed_two_checkpoints(work: str, name: str, steps: int, d_model: int) -> str | None:
    """A prefix run that leaves ≥ 2 committed checkpoints to damage."""
    ckpt = os.path.join(work, name)
    p = _run(_train_cmd(ckpt, steps, d_model, "--ckpt-every", "8",
                        "--stop-after", "16"))
    if p.returncode != 0:
        return None
    names = sorted(f for f in os.listdir(ckpt)
                   if f.startswith("ckpt_") and f.endswith(".npz"))
    return ckpt if len(names) >= 2 else None


def case_truncate(work: str, steps: int, d_model: int, baseline: dict) -> dict:
    """Torn write: the newest checkpoint is half a file."""
    ckpt = _seed_two_checkpoints(work, "truncate", steps, d_model)
    if ckpt is None:
        return {"ok": False, "detail": "could not seed two checkpoints"}
    latest = sorted(f for f in os.listdir(ckpt)
                    if f.startswith("ckpt_") and f.endswith(".npz"))[-1]
    path = os.path.join(ckpt, latest)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)
    # truncated zip = incomplete unit: latest_step must not advertise it,
    # so the resume silently starts from the previous complete checkpoint
    return _resume_and_compare(ckpt, steps, d_model, baseline)


def case_corrupt(work: str, steps: int, d_model: int, baseline: dict) -> dict:
    """Silent bit rot: intact container, one leaf no longer matches its
    manifest checksum — must quarantine + degrade, not crash."""
    import numpy as np
    ckpt = _seed_two_checkpoints(work, "corrupt", steps, d_model)
    if ckpt is None:
        return {"ok": False, "detail": "could not seed two checkpoints"}
    latest = sorted(f for f in os.listdir(ckpt)
                    if f.startswith("ckpt_") and f.endswith(".npz"))[-1]
    path = os.path.join(ckpt, latest)
    with np.load(path, allow_pickle=False) as data:
        arrays = {k: np.array(data[k]) for k in data.files}
    flat = arrays["leaf_0"].reshape(-1)
    flat[: max(1, flat.size // 8)] = flat[: max(1, flat.size // 8)] + 1
    with open(path, "wb") as f:
        np.savez(f, **arrays)  # container valid, checksum now stale
    res = _resume_and_compare(ckpt, steps, d_model, baseline, expect_warn=True)
    if res.get("ok") and not any(f.endswith(".corrupt")
                                 for f in os.listdir(ckpt)):
        return {"ok": False, "detail": "corrupt file was not quarantined"}
    return res


def case_sigterm(work: str, steps: int, d_model: int, baseline: dict) -> dict:
    """Preemption notice: SIGTERM after the first periodic checkpoint; the
    trainer must exit 0 with a flushed, resumable checkpoint."""
    ckpt = os.path.join(work, "sigterm")
    proc = subprocess.Popen(_train_cmd(ckpt, steps, d_model, "--ckpt-every", "8"),
                            env=_env(), cwd=REPO,
                            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
                            text=True)
    first = _wait_for_ckpt(ckpt, proc)
    if first is None:
        proc.kill()
        return {"ok": False, "detail": "no checkpoint appeared before exit"}
    proc.send_signal(signal.SIGTERM)
    try:
        proc.wait(timeout=300)
    except subprocess.TimeoutExpired:
        proc.kill()
        return {"ok": False, "detail": "trainer ignored SIGTERM (grace "
                                       "budget exceeded)"}
    if proc.returncode != 0:
        return {"ok": False, "detail": f"exit code {proc.returncode} after "
                                       "SIGTERM (expected graceful flush)"}
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.checkpoint import latest_step
    flushed = latest_step(ckpt)
    if flushed is None:
        return {"ok": False, "detail": "no complete checkpoint after SIGTERM"}
    res = ({"ok": True, "note": "run completed before the signal landed"}
           if flushed >= steps else
           _resume_and_compare(ckpt, steps, d_model, baseline))
    res["flushed_step"] = int(flushed)
    return res


def case_nonfinite(steps: int) -> dict:
    """NaN/Inf/bitflip fault sweep through one jitted campaign: every guard
    backend returns finite leaderboard rows and filters the victims."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    import numpy as np
    from repro.core.solver import SolverConfig
    from repro.data.problems import make_quadratic_problem
    from repro.scenarios import (
        expand_grid,
        fault_bitflip,
        fault_inf_rows,
        fault_nan_rows,
        fault_none,
        run_campaign,
        scenario_static,
    )

    quad = make_quadratic_problem(d=24, sigma=1.0, L=8.0, V=1.0, seed=1)
    cfg = SolverConfig(m=8, T=steps, eta=0.05, alpha=0.25,
                       aggregator="byzantine_sgd", attack="sign_flip",
                       sanitize="quarantine")
    grid = expand_grid(
        [("static", scenario_static("sign_flip"))], [0.125], [0, 1],
        faults=[("none", fault_none()),
                ("nan", fault_nan_rows(0.25)),
                ("inf", fault_inf_rows(0.25, period=2)),
                ("bitflip", fault_bitflip(0.25, start_step=4))],
    )
    result = run_campaign(
        quad, cfg, grid, ["byzantine_sgd", "mean", "coordinate_median"],
        backends=["dense", "fused", "dp_exact", "dp_sketch"],
    )
    cells, bad = 0, []
    for name, stats in result.stats.items():
        for field in ("gap_avg", "gap_final"):
            vals = np.asarray(getattr(stats, field))
            cells += vals.size
            if not np.all(np.isfinite(vals)):
                bad.append(f"{name}.{field}")
    # the guard must count fault victims toward the realized Byzantine set
    guard = result.stats["byzantine_sgd@dense"]
    n_ever = np.asarray(guard.n_byz_ever).reshape(2, 4)  # (seed, fault)
    filtered = bool(np.all(n_ever[:, 1:] > n_ever[:, :1]))
    return {"ok": not bad and filtered, "cells_checked": cells,
            "non_finite_cells": bad,
            "victims_filtered": filtered,
            "variants": sorted(result.stats)}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=24,
                    help="trainer steps per crash case (≥ 17 so two "
                         "periodic checkpoints land before completion)")
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--mini", action="store_true",
                    help="CI tier-2 shape (same as the defaults today; "
                         "pinned so local sweeps can grow without moving CI)")
    ap.add_argument("--out", default=os.path.join(REPO, "CHAOS_report.json"))
    ap.add_argument("--keep-work", action="store_true",
                    help="keep the scratch checkpoint directories")
    args = ap.parse_args()
    steps, d_model = args.steps, args.d_model

    report: dict = {"steps": steps, "d_model": d_model, "cases": {}}
    work = tempfile.mkdtemp(prefix="chaos_")
    try:
        t0 = time.time()
        base_res, base_arrays = case_baseline(work, steps, d_model)
        report["cases"]["baseline"] = base_res
        if base_res["ok"]:
            for name, fn in [("kill_resume", case_kill_resume),
                             ("truncate", case_truncate),
                             ("corrupt", case_corrupt),
                             ("sigterm", case_sigterm)]:
                t = time.time()
                res = fn(work, steps, d_model, base_arrays)
                res["wall_s"] = round(time.time() - t, 2)
                report["cases"][name] = res
                print(f"{name}: {'PASS' if res['ok'] else 'FAIL'} "
                      f"({res['wall_s']}s)  {res.get('detail', '')}")
        t = time.time()
        res = case_nonfinite(steps=20)
        res["wall_s"] = round(time.time() - t, 2)
        report["cases"]["nonfinite"] = res
        print(f"nonfinite: {'PASS' if res['ok'] else 'FAIL'} "
              f"({res['wall_s']}s)")
        report["wall_s"] = round(time.time() - t0, 2)
    finally:
        if args.keep_work:
            print(f"work dir kept: {work}")
        else:
            shutil.rmtree(work, ignore_errors=True)

    report["ok"] = all(c.get("ok") for c in report["cases"].values())
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}  (matrix {'GREEN' if report['ok'] else 'RED'})")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
