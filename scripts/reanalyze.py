"""Re-derive roofline terms from saved .hlo.gz files (no recompilation).

Lets parser improvements (repro.roofline.hlo_cost) propagate to the whole
table instantly, and prints op-level attribution for chosen records.

    PYTHONPATH=src python scripts/reanalyze.py                    # refresh all JSONs
    PYTHONPATH=src python scripts/reanalyze.py --attribute TAG    # top contributors
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json

from repro.configs import INPUT_SHAPES, get_config
from repro.roofline.analysis import model_flops
from repro.roofline.hlo_cost import cost_from_hlo_text
from repro.roofline.hw import TPU_V5E


def reanalyze_one(json_path: str, verbose: bool = False):
    hlo_path = json_path.replace(".json", ".hlo.gz")
    try:
        with gzip.open(hlo_path, "rt") as f:
            text = f.read()
    except FileNotFoundError:
        return None
    rec = json.load(open(json_path))
    cost = cost_from_hlo_text(text)
    hw = TPU_V5E
    t_c = cost.flops / hw.peak_flops_bf16
    t_m = cost.bytes_accessed / hw.hbm_bw
    t_n = cost.collective_bytes / (hw.ici_bw_per_link * hw.ici_links)
    bott = max([("compute", t_c), ("memory", t_m), ("collective", t_n)],
               key=lambda kv: kv[1])[0]
    shape = INPUT_SHAPES[rec["shape"]]
    mf = model_flops(get_config(rec["arch"]), shape)
    rec["cost"] = {
        "hlo_flops_per_device": cost.flops,
        "hlo_bytes_per_device": cost.bytes_accessed,
    }
    rec["collectives"] = {
        "total_bytes_per_device": cost.collective_bytes,
        "by_kind": cost.collective_by_kind,
        "by_op": cost.collective_by_op,
    }
    rec["roofline"] = {
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_n,
        "bottleneck": bott, "model_flops": mf,
        "useful_ratio": mf / max(rec["n_chips"] * cost.flops, 1.0),
    }
    rec["bytes_by_op"] = cost.bytes_by_op
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=2)
    if verbose:
        print(f"{rec['arch']} {rec['shape']} {'mp' if rec['multi_pod'] else 'sp'} "
              f"opts={rec.get('opts')} → c={t_c*1e3:.2f}ms m={t_m*1e3:.2f}ms "
              f"n={t_n*1e3:.2f}ms [{bott}]")
    return rec


def attribute(tag: str):
    for path in sorted(glob.glob(f"experiments/dryrun/*{tag}*.json")):
        rec = reanalyze_one(path, verbose=True)
        if rec is None:
            continue
        print("  -- collectives by op (GB/dev/step) --")
        for k, v in list(rec["collectives"].get("by_op", {}).items())[:10]:
            print(f"    {v/1e9:9.2f}  {k[:100]}")
        print("  -- HBM bytes by op (GB/dev/step) --")
        for k, v in list(rec.get("bytes_by_op", {}).items())[:10]:
            print(f"    {v/1e9:9.2f}  {k[:100]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--attribute", default=None, help="substring of record tag")
    args = ap.parse_args()
    if args.attribute:
        attribute(args.attribute)
        return
    n = 0
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        if reanalyze_one(path, verbose=True) is not None:
            n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main()
