"""Benchmark harness — one module per paper table/figure.

  table1       — sample complexity (iterations-to-ε) per aggregator/α/m
  aggregators  — per-iteration per-machine work (wall time) incl. kernels
  filtering    — Claim 3.5 detection latency / false-positive behaviour
  lower_bound  — Theorems 5.4/5.5 distinguishing-success curves
  scenarios    — dynamic-adversary campaigns (one-jit grid) → BENCH_scenarios.json
  train        — scan-vs-loop driver wall-clock + LM train campaigns → BENCH_train.json
  roofline     — deliverable (g) table from the dry-run records

Prints ``name,us_per_call,derived`` CSV.  Select suites with
``python -m benchmarks.run [suite ...]``; default runs all.
"""
import sys


SUITES = ["table1", "aggregators", "filtering", "lower_bound", "ablation",
          "scenarios", "train", "roofline"]


def main() -> None:
    selected = sys.argv[1:] or SUITES
    print("name,us_per_call,derived")
    for suite in selected:
        if suite not in SUITES:
            raise SystemExit(f"unknown suite {suite!r}; have {SUITES}")
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["main"])
        mod.main()


if __name__ == "__main__":
    main()
