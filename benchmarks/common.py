"""Benchmark utilities: timing, CSV emission, provenance-stamped JSON."""
from __future__ import annotations

import json
import time

import jax

from repro.obs.provenance import provenance_meta


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time (µs) of fn(*args) with jax block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def write_json(path: str, record: dict) -> None:
    """Write a ``BENCH_*.json`` record with a provenance ``meta`` block
    (commit SHA, jax/jaxlib versions, device kind, timestamp — DESIGN.md
    §12), so every benchmark artifact says which code on which machine
    produced it.  An existing ``meta`` key is kept (caller stamped richer
    fields)."""
    record.setdefault("meta", provenance_meta())
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {path}")
