"""Benchmark utilities: timing, CSV emission, provenance-stamped JSON."""
from __future__ import annotations

import json
import time

import jax

from repro.obs.provenance import provenance_meta


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time (µs) of fn(*args) with jax block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def device_memory_stats() -> dict | None:
    """Peak / in-use device memory of the default device, in bytes —
    ``None`` when the platform does not report allocator statistics (CPU
    JAX usually does not; TPU/GPU do).  Best-effort by design: memory
    accounting must never be the reason a benchmark fails."""
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:  # noqa: BLE001 — platform-dependent, optional
        return None
    if not stats:
        return None
    keep = ("peak_bytes_in_use", "bytes_in_use", "largest_alloc_size",
            "bytes_limit", "pool_bytes")
    out = {k: int(v) for k, v in stats.items() if k in keep}
    return out or None


def write_json(path: str, record: dict) -> None:
    """Write a ``BENCH_*.json`` record with a provenance ``meta`` block
    (commit SHA, jax/jaxlib versions, device kind, timestamp — DESIGN.md
    §12) plus the device allocator's peak-memory counters where the
    platform reports them, so every benchmark artifact says which code on
    which machine produced it and how much device memory the run actually
    held.  An existing ``meta`` key is kept (caller stamped richer fields)
    but still gains the memory counters if it lacks them."""
    record.setdefault("meta", provenance_meta())
    mem = device_memory_stats()
    if mem is not None and isinstance(record.get("meta"), dict):
        record["meta"].setdefault("device_memory", mem)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {path}")
