"""Claim 3.5 + §1.3 — filter behaviour: detection latency per attack class,
good-worker false-positive rate, and the hidden-shift damage bound.

Also benchmarks the guard *pipeline* itself: the dense three-pass reference
vs the fused one-pass Pallas path (DESIGN.md §5), at **both statistics
precisions** of the ``stats_dtype`` axis (§5 Numerics) — recording the
analytic bytes-moved model from :mod:`repro.roofline.guard_cost`, measured
wall-clock, dense/fused agreement per dtype, and the bf16-vs-f32 filter-
decision agreement into ``BENCH_filtering.json``.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, write_json
from repro.core.byzantine_sgd import ByzantineGuard, GuardConfig
from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_generated_problem, make_quadratic_problem
from repro.kernels import gradgen, ops, ref
from repro.roofline.guard_cost import backend_cost, stats_elem_bytes
from repro.roofline.guard_cost import steady_state_us


def bench_detection_latency() -> None:
    prob = make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)
    for attack in ["sign_flip", "random_gaussian", "alie", "constant_drift",
                   "inner_product", "hidden_shift"]:
        cfg = SolverConfig(m=16, T=2000, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack=attack)
        res = run_sgd(prob, cfg, jax.random.PRNGKey(0))
        n_alive = np.asarray(res.n_alive)
        n_byz = int(np.asarray(res.byz_mask).sum())
        target = 16 - n_byz
        detected = np.where(n_alive <= target)[0]
        latency = int(detected[0]) + 1 if detected.size else -1
        gap = float(prob.f(res.x_avg) - prob.f(prob.x_star))
        emit(f"filter/{attack}", float(latency),
             f"detect_iter={latency},final_alive={int(n_alive[-1])},"
             f"good_filtered={bool(res.ever_filtered_good)},gap={gap:.5f}")


def bench_guard_pipeline(m: int = 32, d: int = 1 << 20, iters: int = 5,
                         d_block: int | None = None,
                         out_path: str = "BENCH_filtering.json") -> dict:
    """Dense vs fused guard step at the ISSUE's headline shape, at both
    statistics precisions (f32 and bf16 — ``SolverConfig.stats_dtype``).

    Bytes-moved comes from the roofline model (the quantity that predicts
    TPU wall-clock — the guard is memory-bound); wall-clock is measured on
    the current backend (on CPU the fused path runs the Pallas interpreter,
    so only the TPU-relevant bytes model is comparable across paths).

    ``d_block=None`` picks the kernel's VMEM-sized default (2048) on TPU;
    under the interpreter there is no VMEM budget, so a wide 2¹⁶ block
    keeps the grid short (interpreter time scales with grid steps).
    """
    if d_block is None:
        d_block = (1 << 16) if ops.interpret_mode() else 2048
    # V matched to the i.i.d.-normal worker data (‖g_i − g_j‖ ≈ √(2d)): the
    # filter keeps honest workers, so the recorded good_k / ξ agreement
    # compares *live* decisions rather than the everyone-filtered
    # degenerate state a V=1 guard collapses to at this d
    cfg = GuardConfig(m=m, T=1000, V=float(np.sqrt(2.0 * d)), D=10.0)

    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    grads = jax.random.normal(k1, (m, d), jnp.float32)
    x1 = jnp.zeros((d,), jnp.float32)
    xk = 0.01 * jax.random.normal(k2, (d,), jnp.float32)
    grads2 = jax.random.normal(k3, (m, d), jnp.float32)

    # in-kernel generation point (DESIGN.md §14): the same guard shape, but
    # rows regenerated from the counter-based PRNG inside the sweep instead
    # of read from HBM.  An ALIE coalition on the first quarter of the fleet
    # exercises the per-strip attack statistics (honest mean/std) in-kernel.
    from repro.core.attacks import alie_z_max

    gprob = make_generated_problem(d=d, sigma=1.0, L=8.0,
                                   V=float(np.sqrt(2.0 * d)), seed=0)
    wk1 = gradgen.key_bits(jax.random.split(jax.random.PRNGKey(5), m))
    wk2 = gradgen.key_bits(jax.random.split(jax.random.PRNGKey(6), m))
    gen_mask = jnp.arange(m) < m // 4
    gen_slot = jnp.where(gen_mask, 1, 0).astype(jnp.int32)
    tg = gradgen.mean_grad(gprob.gen.h, xk, gprob.gen.x_star)
    gen_params = (
        jnp.zeros((gradgen.GEN_NPARAMS,), jnp.float32)
        .at[gradgen.P_ID_A].set(4.0)  # ATTACK_TABLE id: alie
        .at[gradgen.P_Z_A].set(alie_z_max(m, jnp.sum(gen_mask)))
        .at[gradgen.P_TGNRM].set(jnp.maximum(jnp.linalg.norm(tg), 1e-12))
        .at[gradgen.P_NSCALE].set(gprob.gen.noise_scale)
    )
    zeros_m = jnp.zeros((m,), jnp.float32)

    def genctx(keys):
        return gradgen.GenStepCtx(worker_keys=keys, skewsign=zeros_m,
                                  slot=gen_slot, params=gen_params,
                                  w_byz=gen_mask.astype(jnp.float32))

    def gen_rows(keys):
        return jax.jit(ref.gen_rows_ref)(
            xk, gprob.gen.h, gprob.gen.x_star, gprob.gen.het_dir,
            keys, zeros_m, gen_slot, gen_params)

    per_dtype: dict[str, dict] = {}
    fused_alive: dict[str, jax.Array] = {}
    fused_xi: dict[str, jax.Array] = {}
    for sdt in ("f32", "bf16"):
        dense = ByzantineGuard(cfg, stats_dtype=sdt)
        fused = ByzantineGuard(cfg, use_fused=True, d_block=d_block,
                               stats_dtype=sdt)
        # one burn-in step so B ≠ 0 and the incremental Gram is exercised
        state_d = dense.step(dense.init(d), grads, xk, x1)[0]
        state_f = fused.step(fused.init(d), grads, xk, x1)[0]

        dense_step = jax.jit(dense.step)
        fused_step = jax.jit(fused.step)
        t_dense = time_fn(dense_step, state_d, grads2, xk, x1,
                          warmup=1, iters=iters)
        t_fused = time_fn(fused_step, state_f, grads2, xk, x1,
                          warmup=1, iters=iters)

        # agreement of the two paths on identical inputs (the oracle
        # contract, per stats dtype)
        sd, xi_d, _ = jax.block_until_ready(dense_step(state_d, grads2, xk, x1))
        sf, xi_f, _ = jax.block_until_ready(fused_step(state_f, grads2, xk, x1))
        fused_alive[sdt], fused_xi[sdt] = sf.alive, xi_f
        gb_err = float(jnp.linalg.norm(sf.gram_B - sd.gram_B)
                       / jnp.maximum(jnp.linalg.norm(sd.gram_B), 1e-12))
        xi_err = float(jnp.max(jnp.abs(xi_f - xi_d)))
        good_eq = bool(jnp.all(sf.alive == sd.alive))

        # gen point: identical row history delivered two ways — materialized
        # strips through the fused guard vs in-kernel regeneration through
        # gen_step (the differential oracle at the headline shape)
        geng = ByzantineGuard(cfg, use_fused=True, d_block=d_block,
                              stats_dtype=sdt, gen_spec=gprob.gen)
        gen_step = jax.jit(geng.gen_step)
        state_g = gen_step(geng.init(d), genctx(wk1), xk, x1)[0]
        t_gen = time_fn(gen_step, state_g, genctx(wk2), xk, x1,
                        warmup=1, iters=iters)
        sg, xi_g, _, _ = jax.block_until_ready(
            gen_step(state_g, genctx(wk2), xk, x1))
        state_fm = fused_step(fused.init(d), gen_rows(wk1), xk, x1)[0]
        sm, xi_m, _ = jax.block_until_ready(
            fused_step(state_fm, gen_rows(wk2), xk, x1))
        gen_agree = {
            "good_k_equal": bool(jnp.all(sg.alive == sm.alive)),
            "xi_max_abs_err": float(jnp.max(jnp.abs(xi_g - xi_m))),
            "n_alive": int(jnp.sum(sg.alive)),
        }

        cd = backend_cost("dense", m, d, sdt)
        cf = backend_cost("fused", m, d, sdt)
        cg = backend_cost("gen", m, d, sdt)
        per_dtype[sdt] = {
            "elem_bytes": stats_elem_bytes(sdt),
            # analytic HBM-traffic model (repro.roofline.guard_cost), NOT
            # a measurement — the ratios follow from counting the passes
            # each path makes over (m, d) data; wallclock_us below is what
            # was actually measured on this backend
            "bytes_moved_model": {
                "source": "repro.roofline.guard_cost",
                "dense": {"stats": cd.stats_bytes, "xi": cd.xi_bytes,
                          "step": cd.step_bytes},
                "fused": {"stats": cf.stats_bytes, "xi": cf.xi_bytes,
                          "step": cf.step_bytes},
                "gen": {"stats": cg.stats_bytes, "xi": cg.xi_bytes,
                        "step": cg.step_bytes},
                "stats_ratio": cd.stats_bytes / cf.stats_bytes,
                "step_ratio": cd.step_bytes / cf.step_bytes,
                "gen_step_ratio": cf.step_bytes / cg.step_bytes,
            },
            "wallclock_us": {"dense": t_dense, "fused": t_fused,
                             "gen": t_gen},
            # measured / bandwidth-modeled ratio of the gen step — the
            # measured-vs-modeled band; only a roofline statement on TPU
            # (on CPU the fused paths run the Pallas interpreter, see
            # fused_runs_interpret)
            "gen_measured_over_model": t_gen / max(
                steady_state_us(cg), 1e-12),
            "agreement": {"gram_B_rel_err": gb_err,
                          "xi_max_abs_err": xi_err,
                          "good_k_equal": good_eq,
                          # visible guard against the all-filtered
                          # degenerate state (where agreement is vacuous)
                          "n_alive": int(jnp.sum(sf.alive))},
            "gen_vs_fused": gen_agree,
        }
        emit(f"filter/guard_step_dense_{sdt}", t_dense,
             f"model_stats_bytes={cd.stats_bytes}")
        emit(f"filter/guard_step_fused_{sdt}", t_fused,
             f"model_stats_bytes={cf.stats_bytes},"
             f"model_stats_ratio={cd.stats_bytes / cf.stats_bytes:.2f},"
             f"model_step_ratio={cd.step_bytes / cf.step_bytes:.2f},"
             f"interpret={ops.interpret_mode()}")
        emit(f"filter/guard_step_gen_{sdt}", t_gen,
             f"model_step_bytes={cg.step_bytes},"
             f"model_gen_step_ratio={cf.step_bytes / cg.step_bytes:.2f},"
             f"good_k_equal={gen_agree['good_k_equal']},"
             f"xi_err={gen_agree['xi_max_abs_err']:.2e},"
             f"interpret={ops.interpret_mode()}")

    # the dtype axis headline (ISSUE 5): fused@bf16 must model ≤ 0.55× the
    # fused@f32 statistics bytes, and the saved bytes must not change the
    # filter's decisions on this step
    f32_stats = per_dtype["f32"]["bytes_moved_model"]["fused"]["stats"]
    bf16_stats = per_dtype["bf16"]["bytes_moved_model"]["fused"]["stats"]
    xi_rel = float(
        jnp.linalg.norm(fused_xi["bf16"].astype(jnp.float32) - fused_xi["f32"])
        / jnp.maximum(jnp.linalg.norm(fused_xi["f32"]), 1e-12)
    )
    bf16_vs_f32 = {
        "fused_stats_bytes_ratio_model": bf16_stats / f32_stats,
        "good_k_equal": bool(jnp.all(fused_alive["bf16"] == fused_alive["f32"])),
        "xi_rel_err": xi_rel,
    }
    record = {
        "m": m,
        "d": d,
        "d_block": d_block,
        "backend": jax.default_backend(),
        "fused_runs_interpret": ops.interpret_mode(),
        "stats_dtypes": per_dtype,
        "bf16_vs_f32": bf16_vs_f32,
    }
    write_json(out_path, record)
    emit("filter/stats_dtype_bf16_ratio",
         bf16_vs_f32["fused_stats_bytes_ratio_model"],
         f"good_k_equal={bf16_vs_f32['good_k_equal']},"
         f"xi_rel_err={xi_rel:.2e},out={out_path}")
    return record


def main(m: int = 32, d: int = 1 << 20, iters: int = 5,
         d_block: int | None = None,
         out_path: str = "BENCH_filtering.json",
         pipeline_only: bool = False) -> None:
    if not pipeline_only:
        bench_detection_latency()
    bench_guard_pipeline(m=m, d=d, iters=iters, d_block=d_block,
                         out_path=out_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--m", type=int, default=32)
    ap.add_argument("--d", type=int, default=1 << 20)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--d-block", type=int, default=None,
                    help="fused-kernel strip width (default: 2048 on TPU, "
                         "2^16 under the interpreter)")
    ap.add_argument("--out", default="BENCH_filtering.json")
    ap.add_argument("--pipeline-only", action="store_true",
                    help="skip the detection-latency sweep")
    args = ap.parse_args()
    if args.d_block is not None and args.d_block <= 0:
        ap.error("--d-block must be a positive strip width")
    main(m=args.m, d=args.d, iters=args.iters, d_block=args.d_block,
         out_path=args.out, pipeline_only=args.pipeline_only)
