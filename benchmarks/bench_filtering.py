"""Claim 3.5 + §1.3 — filter behaviour: detection latency per attack class,
good-worker false-positive rate, and the hidden-shift damage bound."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_quadratic_problem


def main() -> None:
    prob = make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)
    for attack in ["sign_flip", "random_gaussian", "alie", "constant_drift",
                   "inner_product", "hidden_shift"]:
        cfg = SolverConfig(m=16, T=2000, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack=attack)
        res = run_sgd(prob, cfg, jax.random.PRNGKey(0))
        n_alive = np.asarray(res.n_alive)
        n_byz = int(np.asarray(res.byz_mask).sum())
        target = 16 - n_byz
        detected = np.where(n_alive <= target)[0]
        latency = int(detected[0]) + 1 if detected.size else -1
        gap = float(prob.f(res.x_avg) - prob.f(prob.x_star))
        emit(f"filter/{attack}", float(latency),
             f"detect_iter={latency},final_alive={int(n_alive[-1])},"
             f"good_filtered={bool(res.ever_filtered_good)},gap={gap:.5f}")


if __name__ == "__main__":
    main()
