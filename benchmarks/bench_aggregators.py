"""Table 1 — per-iteration per-machine work: wall time per aggregation call
vs (m, d).  Confirms the complexity separation the paper argues in §1.4:
Krum's O(m²(d + log m)) vs the guard's O(md) + O(m²) scalar work, and the
Pallas kernel variants of the reductions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.aggregators import get_aggregator
from repro.core.byzantine_sgd import ByzantineGuard, GuardConfig
from repro.kernels import ops


def main() -> None:
    key = jax.random.PRNGKey(0)
    for m, d in [(16, 1 << 14), (16, 1 << 17), (64, 1 << 14)]:
        x = jax.random.normal(key, (m, d), jnp.float32)

        for name in ["mean", "coordinate_median", "trimmed_mean", "krum",
                      "geometric_median"]:
            kwargs = {"n_byzantine": m // 4} if name == "krum" else (
                {"trim_fraction": 0.25} if name == "trimmed_mean" else {})
            fn = jax.jit(get_aggregator(name, **kwargs))
            us = time_fn(fn, x, warmup=1, iters=5)
            emit(f"agg/{name}/m{m}/d{d}", us, f"throughput_GBps={m*d*4/us/1e3:.2f}")

        # the guard's full step (martingales + filter + masked mean)
        guard = ByzantineGuard(GuardConfig(m=m, T=100, V=4.0, D=10.0))
        state = guard.init(d)
        xk = jnp.zeros((d,))
        step = jax.jit(lambda s, g: guard.step(s, g, xk, xk))
        us = time_fn(step, state, x, warmup=1, iters=5)
        emit(f"agg/byzantine_sgd_step/m{m}/d{d}", us,
             f"throughput_GBps={m*d*4/us/1e3:.2f}")

    # Pallas kernels: interpret mode on CPU executes the kernel body in
    # Python — time one small shape per kernel (wall time on CPU is NOT the
    # TPU projection; the roofline suite covers that)
    m, d = 16, 1 << 12
    x = jax.random.normal(key, (m, d), jnp.float32)
    us = time_fn(lambda y: ops.gram(y, d_block=1024), x, warmup=1, iters=3)
    emit(f"kernel/gram/m{m}/d{d}", us, "interpret-mode")
    us = time_fn(lambda y: ops.coordinate_median(y, d_block=1024), x, warmup=1, iters=3)
    emit(f"kernel/coordinate_median/m{m}/d{d}", us, "interpret-mode")
    mask = jnp.ones((m,), bool)
    us = time_fn(lambda y: ops.filtered_mean(y, mask, float(m), d_block=1024), x,
                 warmup=1, iters=3)
    emit(f"kernel/filtered_mean/m{m}/d{d}", us, "interpret-mode")
    us = time_fn(lambda y: ops.countsketch(y, 256, d_block=1024), x, warmup=1, iters=3)
    emit(f"kernel/countsketch/m{m}/d{d}", us, "interpret-mode")


if __name__ == "__main__":
    main()
