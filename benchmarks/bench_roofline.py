"""Deliverable (g) — render the roofline table from the dry-run records
in experiments/dryrun/*.json (written by repro.launch.dryrun)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def main() -> None:
    records = []
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        with open(path) as f:
            records.append(json.load(f))
    if not records:
        emit("roofline/none", 0.0, "run `python -m repro.launch.dryrun` first")
        return
    for r in records:
        rl = r["roofline"]
        tag = f"{r['arch']}/{r['shape']}/{'mp' if r['multi_pod'] else 'sp'}"
        if r.get("opts"):
            tag += "/opt-" + "-".join(sorted(r["opts"]))
        dominant = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        emit(
            f"roofline/{tag}", dominant * 1e6,
            f"bottleneck={rl['bottleneck']},c={rl['t_compute_s']*1e3:.1f}ms,"
            f"m={rl['t_memory_s']*1e3:.1f}ms,n={rl['t_collective_s']*1e3:.1f}ms,"
            f"useful={rl['useful_ratio']:.2%},fits={r['memory']['fits_hbm_16g']},"
            f"peakGB={r['memory']['peak_bytes']/1e9:.1f}",
        )


if __name__ == "__main__":
    main()
