"""Theorems 5.4/5.5 — the lower bound, made observable: success probability
of the distinguishing reduction vs T, sweeping through the α²V²D²/ε²
threshold. Below ⇒ coin-flip; above ⇒ certainty."""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core.lower_bound import (
    distinguishing_experiment_linear,
    distinguishing_experiment_strongly_convex,
)


def main() -> None:
    key = jax.random.PRNGKey(0)
    alpha, eps = 0.3, 0.05
    for T in [2, 8, 32, 128, 512, 2048]:
        r = distinguishing_experiment_linear(
            key, m=16, T=T, n_trials=64, alpha=alpha, eps=eps)
        emit(f"lower_bound/linear/T{T}", float(T),
             f"success={float(r.success_rate):.3f},threshold_T={r.threshold_T:.0f}")
    for T in [2, 8, 32, 128, 512, 2048]:
        r = distinguishing_experiment_strongly_convex(
            key, m=16, T=T, n_trials=64, alpha=alpha, eps_hat=eps)
        emit(f"lower_bound/strongly_convex/T{T}", float(T),
             f"success={float(r.success_rate):.3f},threshold_T={r.threshold_T:.0f}")


if __name__ == "__main__":
    main()
