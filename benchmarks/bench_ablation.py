"""Ablations on the framework's beyond-paper knobs.

1. sketch_dim — detection latency + final loss of the sketch-mode guard vs
   the exact mode, on a reduced LM under sign-flip. Quantifies the
   accuracy cost of the O(W·k) communication mode.
2. threshold slack — how much threshold inflation the filter tolerates
   before Byzantine leakage appears (robustness of the V auto-calibration).
3. threshold_mode — anytime (Lemma-3.6) vs fixed (Algorithm-1 header)
   thresholds: detection latency on the convex problem.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_quadratic_problem
from repro.data.synthetic import SyntheticTokens, make_worker_batch
from repro.distributed.trainer import build_train_step, init_train_state, rank_from_mask
from repro.models import build_model
from repro.optim import adamw
from repro.configs import get_config


def sketch_dim_ablation() -> None:
    cfg = get_config("internlm2-1.8b").reduced(max_d_model=128)
    model = build_model(cfg)
    W, steps = 8, 25
    stream = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32)
    opt = adamw(3e-3, grad_clip=1.0)
    rank = rank_from_mask(jnp.arange(W) < 2)
    for backend, k in [("dp_exact", 0), ("dp_sketch", 256),
                       ("dp_sketch", 1024), ("dp_sketch", 4096)]:
        scfg = SolverConfig(m=W, T=steps, eta=3e-3, alpha=0.25,
                            aggregator="byzantine_sgd", attack="sign_flip",
                            mean_over_alive=True, guard_backend=backend,
                            guard_opts=(("sketch_dim", max(k, 1)),))
        ts = jax.jit(build_train_step(model, opt, scfg))
        state = init_train_state(model, opt, scfg, jax.random.PRNGKey(0))
        detect = -1
        for i in range(steps):
            batch = make_worker_batch(stream, W, 2, jnp.asarray(i))
            state, m = ts(state, batch, rank, jax.random.PRNGKey(i))
            if detect < 0 and int(m["byz_alive"]) == 0:
                detect = i + 1
        emit(f"ablation/sketch_dim/{backend}{k}", float(detect),
             f"detect_step={detect},loss={float(m['loss_good_workers']):.4f},"
             f"good_filtered={int(m['good_filtered'])}")


def threshold_mode_ablation() -> None:
    prob = make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)
    for mode in ["anytime", "fixed"]:
        cfg = SolverConfig(m=16, T=2000, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="alie",
                           threshold_mode=mode)
        res = run_sgd(prob, cfg, jax.random.PRNGKey(0))
        n_alive = np.asarray(res.n_alive)
        target = 16 - int(np.asarray(res.byz_mask).sum())
        det = np.where(n_alive <= target)[0]
        latency = int(det[0]) + 1 if det.size else -1
        gap = float(prob.f(res.x_avg) - prob.f(prob.x_star))
        emit(f"ablation/threshold_mode/{mode}", float(latency),
             f"detect_iter={latency},gap={gap:.5f},"
             f"good_filtered={bool(res.ever_filtered_good)}")


def main() -> None:
    sketch_dim_ablation()
    threshold_mode_ablation()


if __name__ == "__main__":
    main()
