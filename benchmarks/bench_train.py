"""Training-path benchmarks (DESIGN.md §10) → ``BENCH_train.json``.

Two deliverables:

1. **scan-vs-loop wall-clock** — the chunked ``lax.scan`` train driver of
   ``repro.launch.train`` against the historical per-step Python loop (one
   jitted call + one host transfer per metric per step).  Both drive the
   *same* jitted ``train_step`` on the same reduced LM, so the comparison
   isolates the driver (dispatch + host-transfer) overhead the scan
   removes.  Steady state excludes the first (compiling) call.

2. **train campaign leaderboard** — ``run_train_campaign`` vmaps a
   (scenario × α × seed) grid of reduced-LM training runs for several
   (aggregator × guard-backend) variants under one jit: does the guard
   still isolate the Byzantine set when the gradients come from a real
   model instead of a convex toy?

Timing hygiene (repo norm, see BENCH_scenarios.json): both deliverables
compare like with like **on the same backend** (scan vs loop run the same
guard; the campaign reports per-variant robustness, not per-backend
speed).  Cross-guard-backend *speed* claims stay with the roofline model
in ``repro.roofline.guard_cost`` — the dp_* backends measured here on CPU
say nothing about TPU wall-clock.

``--mini`` is the CI tier-2 shape: mamba2-130m reduced, 2 guard backends ×
1 scenario (+ mean), ~30 steps.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, write_json
from repro.configs import get_config
from repro.core.solver import SolverConfig, byz_rank
from repro.data.synthetic import SyntheticTokens, make_worker_batch
from repro.distributed.trainer import build_train_step, init_train_state
from repro.models import build_model
from repro.optim import adamw
from repro.scenarios import (
    expand_grid,
    run_train_campaign,
    scenario_adaptive,
    scenario_churn,
    scenario_static,
    summarize_train_campaign,
)

ARCH = "mamba2-130m"

# CI slack on the scan ≤ loop check: the scan driver removes a *fixed*
# per-step cost, so at the light bench shape its true margin is ~1.2x —
# but back-to-back measurements on a shared CPU box carry enough noise
# to flip a raw ≤ comparison (observed: alternating-round medians still
# land within ±5% on contended runners).  The check therefore asserts
# scan ≤ 1.05 × loop: tight enough to catch a real driver regression
# (which re-adds ≥15% at this shape), loose enough not to flake on noise.
SCAN_LE_LOOP_SLACK = 1.05


def _median_iqr(sorted_times: list[float]) -> tuple[float, float]:
    """(median, IQR) of an already-sorted small sample — the recorded
    round statistics of the alternating-round driver bench."""
    n = len(sorted_times)
    med = sorted_times[n // 2]
    iqr = sorted_times[(3 * n) // 4] - sorted_times[n // 4]
    return med, iqr


def _setup(workers: int, steps: int, seq_len: int, d_model: int,
           guard_backend: str = "dp_exact"):
    cfg = get_config(ARCH).reduced(max_d_model=d_model)
    model = build_model(cfg)
    stream = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=seq_len)
    opt = adamw(3e-3, grad_clip=1.0)
    scfg = SolverConfig(m=workers, T=steps, eta=3e-3, alpha=0.25,
                        aggregator="byzantine_sgd", attack="sign_flip",
                        mean_over_alive=True, guard_backend=guard_backend,
                        guard_opts=(("sketch_dim", 256),))
    return cfg, model, stream, opt, scfg


def scan_vs_loop(workers: int = 8, steps: int = 48, chunk: int = 8,
                 seq_len: int = 16, d_model: int = 32,
                 rounds: int = 3) -> dict:
    """Steady-state per-step wall-clock of the two drivers on the same
    jitted train_step (scan additionally fuses on-device data generation
    into the chunk).

    Timing hygiene: after both paths have compiled, the drivers are timed
    in ``rounds`` *alternating* segments of ``steps`` steps each and the
    per-round median **and IQR** are recorded — back-to-back single
    measurements on a shared CPU box are order-sensitive enough to invert
    a 1.x× margin, and the IQR makes that noise floor visible in the JSON
    instead of silently flipping the ``scan_le_loop`` flag (which itself
    carries the documented ``SCAN_LE_LOOP_SLACK``).
    The default shape is deliberately light (seq 16, d_model 32): the scan
    removes a *fixed* per-step cost (Python dispatch + one host transfer
    per metric), so a compute-heavy step buries the difference in noise —
    at ~30 ms/step the two drivers measure equal on CPU, at ~15 ms/step
    the driver overhead is resolvable.
    """
    cfg, model, stream, opt, scfg = _setup(workers, steps, seq_len, d_model)
    train_step = build_train_step(model, opt, scfg)
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    rank = byz_rank(keys[1], workers)
    steps -= steps % chunk

    def make_batch(i):
        return make_worker_batch(stream, workers, 1, i)

    def one_step(st, i):
        return train_step(st, make_batch(i), rank,
                          jax.random.fold_in(keys[3], i))

    step_fn = jax.jit(one_step)

    @jax.jit
    def run_chunk(st, idx):
        return jax.lax.scan(lambda s, i: one_step(s, i), st, idx)

    def time_loop(state, lo):
        # jitted per-step call + per-metric host transfer (the historical
        # driver this bench exists to retire)
        t0 = time.perf_counter()
        for i in range(lo, lo + steps):
            state, m = step_fn(state, jnp.asarray(i))
            _ = {k: float(v) for k, v in m.items()}
        return state, (time.perf_counter() - t0) / steps * 1e6

    def time_scan(state, lo):
        t0 = time.perf_counter()
        for c in range(lo, lo + steps, chunk):
            state, ms = run_chunk(state, jnp.arange(c, c + chunk))
            _ = jax.device_get(ms)
        return state, (time.perf_counter() - t0) / steps * 1e6

    # compile both paths (first calls measured separately)
    state = init_train_state(model, opt, scfg, keys[0])
    t0 = time.perf_counter()
    state, m = step_fn(state, jnp.asarray(0))
    _ = {k: float(v) for k, v in m.items()}
    t_compile_loop = time.perf_counter() - t0
    t0 = time.perf_counter()
    state, ms = run_chunk(state, jnp.arange(1, 1 + chunk))
    _ = jax.device_get(ms)
    t_compile_scan = time.perf_counter() - t0

    loop_times, scan_times = [], []
    lo = 1 + chunk
    for _ in range(rounds):
        state, t = time_loop(state, lo)
        loop_times.append(t)
        lo += steps
        state, t = time_scan(state, lo)
        scan_times.append(t)
        lo += steps
    loop_times.sort(), scan_times.sort()
    loop_us, loop_iqr = _median_iqr(loop_times)
    scan_us, scan_iqr = _median_iqr(scan_times)

    rec = {
        "arch": ARCH, "workers": workers, "steps_per_round": steps,
        "rounds": rounds, "chunk": chunk,
        "seq_len": seq_len, "d_model": d_model,
        "guard_backend": scfg.guard_backend,
        "backend": jax.default_backend(),
        "loop_steady_state_us_per_step": loop_us,
        "scan_steady_state_us_per_step": scan_us,
        "loop_iqr_us": loop_iqr,
        "scan_iqr_us": scan_iqr,
        "loop_us_per_round": loop_times,
        "scan_us_per_round": scan_times,
        "loop_first_call_s": t_compile_loop,
        "scan_first_call_s": t_compile_scan,
        "scan_speedup": loop_us / max(scan_us, 1e-9),
        # the CI check: alternating-round median with the documented noise
        # slack (see SCAN_LE_LOOP_SLACK) — a raw ≤ flips on CPU contention
        "scan_le_loop_slack": SCAN_LE_LOOP_SLACK,
        "scan_le_loop": bool(scan_us <= SCAN_LE_LOOP_SLACK * loop_us),
    }
    emit("train/driver_loop", loop_us, f"steps={steps},rounds={rounds}")
    emit("train/driver_scan", scan_us,
         f"steps={steps},chunk={chunk},speedup={rec['scan_speedup']:.2f}x")
    return rec


def train_campaign(mini: bool, workers: int = 8, steps: int = 30,
                   seq_len: int = 32, d_model: int = 64,
                   backends: list[str] | None = None) -> dict:
    """The (scenario × α × seed) training grid, one jit per the §10 runner."""
    cfg, model, stream, opt, scfg = _setup(workers, steps, seq_len, d_model)
    # attack_scale=2 plays sign_flip at −6g: at the synthetic-LM gradient
    # geometry the default −3g deviation sits only ~14% above the exact
    # 4V radius, a margin the sketch guard's 1.5x threshold slack absorbs
    # by design — the scaled attack separates the backends instead of
    # measuring that known slack (the probe is recorded in DESIGN.md §10's
    # timing-hygiene note and the JSON `note`)
    scenarios = [("static_sign_flip",
                  scenario_static("sign_flip", attack_scale=2.0))]
    if not mini:
        scenarios += [
            ("churn_sign_flip",
             scenario_churn("sign_flip", period=steps // 2,
                            stride=max(workers // 8, 1), attack_scale=2.0)),
            ("adaptive_inner_product",
             scenario_adaptive("inner_product", adapt_rate=0.5)),
        ]
    seeds = range(2) if mini else range(3)
    if backends is None:
        backends = ["dp_exact", "dp_sketch"]
    grid = expand_grid(scenarios, [0.25], seeds)
    result = run_train_campaign(
        model, opt, scfg, grid, steps=steps, stream=stream,
        per_worker_batch=1, aggregators=["mean", "byzantine_sgd"],
        backends=backends,
    )
    record = summarize_train_campaign(result, scfg)
    record["arch"] = ARCH
    record["backends"] = backends
    n_variants = len(result.stats)
    emit("train/campaign", result.wall_s * 1e6,
         f"runs={result.n_runs * n_variants},steps={steps},"
         f"compile_s={result.compile_s:.1f}")
    for row in record["leaderboard"]:
        emit(
            f"train/{row['scenario']}/a{row['alpha']}/{row['variant']}",
            row["loss_final_med"] * 1e6,
            f"loss_final={row['loss_final_med']:.4f},"
            f"byz_alive={row['byz_alive_final_max']},"
            f"good_filtered={row['ever_filtered_good']}",
        )
    return record


def main(mini: bool = False, out_path: str = "BENCH_train.json",
         backends: list[str] | None = None) -> dict:
    steps = 30 if mini else 40
    record = {
        "mini": mini,
        "note": ("scan-vs-loop compares drivers on one backend; "
                 "cross-guard-backend speed uses the roofline model "
                 "(repro.roofline.guard_cost), not CPU wall-clock. "
                 "Campaign sign_flip runs at attack_scale=2 (-6g): the "
                 "default -3g deviation clears the exact 4V radius by only "
                 "~14% at this gradient geometry, inside the dp_sketch "
                 "1.5x threshold slack — the sketch guard absorbing "
                 "marginal attacks is the documented cost of its O(W*k) "
                 "communication, not a leaderboard bug"),
        "driver_wallclock": scan_vs_loop(steps=32 if mini else 48,
                                         rounds=3 if mini else 5),
        "campaign": train_campaign(mini, steps=steps, backends=backends),
    }
    write_json(out_path, record)
    emit("train/report", 0.0, f"out={out_path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mini", action="store_true",
                    help="CI tier-2 shape: 1 scenario x 2 seeds x 2 backends")
    ap.add_argument("--backends", default=None,
                    help="comma-separated guard backends (default "
                         "dp_exact,dp_sketch)")
    ap.add_argument("--out", default="BENCH_train.json")
    args = ap.parse_args()
    main(mini=args.mini, out_path=args.out,
         backends=args.backends.split(",") if args.backends else None)
