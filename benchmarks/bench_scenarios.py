"""Scenario campaigns — dynamic adversaries × aggregators, one jit.

Two deliverables (DESIGN.md §8):

1. the **scenario leaderboard**: every aggregator against the full dynamic
   zoo (lie-low-then-strike, churn, coalition splits, filter-feedback
   adaptation) across ≥ 100 (scenario, α, seed) grid rows, seed-aggregated
   into ``BENCH_scenarios.json`` — including the degradation table (which
   baselines break under a dynamic adversary whose static counterpart they
   survive) and the Theorem-3.8 bound check for the guard;
2. the **batched-vs-looped wall-clock** on the 6×6 robustness matrix: the
   one-jit campaign against the historical one-eager-``run_sgd``-per-cell
   Python loop.

Third deliverable (DESIGN.md §9): the **guard-backend axis** — the same
campaign sweeps the guard's realizations (dense / fused Pallas pipeline /
distributed CountSketch) as variants next to the aggregator axis, and the
report gains a ``backend_axis`` section with per-backend campaign
wall-clock (measured on this backend) plus the roofline-model steady-state
per-step wall-clock at the m = 32, d = 2²⁰ headline shape, where the fused
pipeline's 3-vs-6-pass traffic reduction makes it strictly cheaper than
dense.

Fourth deliverable (DESIGN.md §12): ``--trace-out`` arms the guard
**flight recorder** on a guard-only rerun of the campaign — per-step
filter forensics for the adaptive cells (martingale deviations vs
thresholds, alive deltas, first-filter steps) exported as structured
JSONL + a Perfetto-loadable chrome trace, with the measured
telemetry-enabled overhead fraction recorded in the trace's own meta
block, and measured-vs-roofline comparator rows for the swept backends.

Fifth deliverable (DESIGN.md §13): the **heterogeneous slice** — non-iid
data skew, periodic stragglers, and partial participation swept as a named
:class:`~repro.scenarios.spec.WorkerProfile` axis of one campaign (the
``heterogeneous`` record section), with the Theorem-3.8 check at each
row's realized skew-inflated V and effective reporter count.

Sixth deliverable (DESIGN.md §14): the **mega campaign** — the full
(scenario × α × seed) grid 10×'d to tens of thousands of runs under ONE
traced campaign, peak device memory bounded by run-axis chunking
(``lax.map`` over chunks of the vmapped grid) and the ``gen``
pseudo-backend regenerating worker gradients inside the guard sweep so
the (N, m, d) batch never materializes.  The record carries the compiled
program's memory analysis next to a chunk-sized reference compile and
*asserts* the chunked temp allocation stays within 2× of it — the
sublinear-in-runs peak-memory claim lives in the artifact it gates.

``--mini`` is the CI tier-2 shape: 5 scenarios (3 dynamic) × 2 seeds at
small T, the guard backends (gen included), one non-iid skew level in the
heterogeneous slice, looped comparison on the matrix kept, and a
guard-only ~2k-run mini-mega grid with the peak-memory assertion.
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import emit
from repro.core.guard_backends import parse_backend_spec
from repro.core.solver import SolverConfig
from repro.data.problems import (
    heterogenize_problem,
    make_generated_problem,
    make_quadratic_problem,
)
from repro.kernels import ops
from repro.obs import EventLog, TelemetryConfig, roofline_rows
from repro.roofline.guard_cost import backend_cost, steady_state_us
from repro.roofline.hw import TPU_V5E
from repro.scenarios import (
    degraded_pairs,
    expand_grid,
    profile_iid,
    profile_partial,
    profile_stragglers,
    run_campaign,
    run_campaign_looped,
    scenario_adaptive,
    scenario_churn,
    scenario_coalition,
    scenario_lie_low_then_strike,
    scenario_static,
    summarize_campaign,
    worker_profile,
    write_report,
)
from repro.scenarios.campaign import CampaignResult, build_campaign_fn
from repro.scenarios.report import campaign_trace_events, filter_timelines

# the blades-comparable aggregator cross: the classical zoo, the stateful
# rules (AutoGM's auto-weighted geometric median, Karimireddy's
# momentum-carried centered clipping), two bucketing compositions
# (s=2 pre-averaging in front of Krum / trimmed mean), and the guard
AGGREGATORS = ["mean", "krum", "coordinate_median", "trimmed_mean",
               "geometric_median", "autogm", "centered_clip",
               "bucket2:krum", "bucket2:trimmed_mean", "byzantine_sgd"]
MATRIX_ATTACKS = ["none", "sign_flip", "random_gaussian", "alie",
                  "inner_product", "hidden_shift"]
# the guard-backend sweep: dense oracle, fused Pallas pipeline at both
# statistics precisions (DESIGN.md §5 Numerics — the bf16 row records the
# accuracy cost of the halved guard traffic), the in-kernel-generation
# pseudo-backend (DESIGN.md §14 — fused + generate='kernel', worker
# strips regenerated inside the sweep), distributed CountSketch guard
# (dp_exact is covered by the tier-1 parity tests; it models collective
# savings, not local-traffic savings, so the leaderboard sweeps the local
# realizations)
BACKENDS = ["dense", "fused", "fused@bf16", "gen", "dp_sketch"]
MINI_BACKENDS = ["dense", "fused", "fused@bf16", "gen"]
# headline shape of the DESIGN.md §5 roofline claim
MODEL_SHAPE = {"m": 32, "d": 1 << 20}
# run-axis chunk width of the mega campaign (DESIGN.md §14): peak device
# memory scales with this, not with the grid's tens of thousands of runs
MEGA_CHUNK = 120


def scenario_zoo(T: int, m: int) -> tuple[list, dict]:
    """The standard campaign scenarios + the dynamic→static pairing used by
    the degradation table.  Churn is one rotation by an m/8-sized group at
    T/2, so the ever-Byzantine fraction is α + 1/8 — at most 0.375 on the
    α ≤ 0.25 grid, strictly inside the α < 1/2 Theorem-3.8 regime (the
    report checks the bound at that realized fraction)."""
    scenarios = [
        ("static_sign_flip", scenario_static("sign_flip")),
        ("static_alie", scenario_static("alie")),
        ("static_alie_update", scenario_static("alie_update")),
        ("static_inner_product", scenario_static("inner_product")),
        ("static_hidden_shift", scenario_static("hidden_shift")),
        ("lie_low_then_strike", scenario_lie_low_then_strike("inner_product", T // 2)),
        ("churn_sign_flip", scenario_churn("sign_flip", period=T // 2, stride=m // 8)),
        ("adaptive_inner_product", scenario_adaptive("inner_product", adapt_rate=0.5)),
        ("coalition_alie_ip", scenario_coalition("alie", "inner_product", 0.5)),
        ("retreat_on_filter", scenario_static("retreat_on_filter")),
    ]
    static_of = {
        "lie_low_then_strike": "static_inner_product",
        "churn_sign_flip": "static_sign_flip",
        "adaptive_inner_product": "static_inner_product",
        "coalition_alie_ip": "static_alie",
        "retreat_on_filter": "static_inner_product",
    }
    return scenarios, static_of


def campaign_leaderboard(mini: bool, backends: list[str] | None = None) -> dict:
    m = 16
    T = 300 if mini else 1500
    # generated problem (counter-based PRNG sampler, DESIGN.md §14): the
    # same worker-gradient distribution whether rows are materialized on
    # the host (dense/fused/dp_sketch variants) or regenerated inside the
    # guard sweep (the "gen" variant) — one leaderboard, all realizations
    prob = make_generated_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)
    # sketch_dim < d so the dp_sketch variant actually exercises sketch
    # compression (k=8 at d=16 is a 2x fold; the default k=4096 > d would
    # make the CountSketch lossless and silently measure the exact guard);
    # the opts filter drops the knob for the dense/fused variants
    cfg = SolverConfig(m=m, T=T, eta=0.05, alpha=0.25,
                       aggregator="byzantine_sgd", attack="sign_flip",
                       guard_opts=(("sketch_dim", 8),))
    scenarios, static_of = scenario_zoo(T, m)
    aggs = AGGREGATORS
    if mini:
        keep = {"static_sign_flip", "static_inner_product",
                "lie_low_then_strike", "churn_sign_flip",
                "adaptive_inner_product"}
        scenarios = [s for s in scenarios if s[0] in keep]
        static_of = {k: v for k, v in static_of.items() if k in keep}
        alphas, seeds = [0.25], range(2)
        aggs = ["mean", "krum", "autogm", "centered_clip", "byzantine_sgd"]
    else:
        alphas, seeds = [0.125, 0.25], range(8)
    if backends is None:
        backends = MINI_BACKENDS if mini else BACKENDS

    grid = expand_grid(scenarios, alphas, seeds)
    result = run_campaign(prob, cfg, grid, aggs, backends=backends)
    record = summarize_campaign(result, prob, cfg, static_of=static_of)
    record["backend_axis"] = backend_axis_record(prob, cfg, grid, backends)
    n_variants = len(result.stats)
    emit("scenarios/campaign", result.wall_s * 1e6,
         f"runs={result.n_runs * n_variants},backends={len(backends)},"
         f"compile_s={result.compile_s:.1f}")
    for row in record["leaderboard"]:
        emit(
            f"scenarios/{row['scenario']}/a{row['alpha']}/{row['aggregator']}",
            row["gap_med"] * 1e6,  # gap in µ-units for the CSV column
            f"gap_med={row['gap_med']:.5f},detect_p50={row['detect_p50']},"
            f"breaks={row['breaks']}",
        )
    for row in record["guard_bound"]:
        # one row per guard backend variant — the variant is part of the key
        emit(f"scenarios/bound/{row['aggregator']}/{row['scenario']}"
             f"/a{row['alpha']}",
             row["gap_med"] * 1e6,
             f"thm38_bound={row['bound']:.4f},within={row['within']},"
             f"alpha_ever={row['alpha_ever']:.3f}")
    for row in degraded_pairs(record):
        emit(f"scenarios/degraded/{row['aggregator']}/{row['dynamic']}",
             row["gap_dynamic"] * 1e6,
             f"static_gap={row['gap_static']:.5f},ratio={row['ratio']:.1f}")
    return record


def _slice_grid(grid, n: int):
    """First-``n``-rows view of a stacked grid — the chunk-sized reference
    compile of the mega campaign's peak-memory assertion."""
    from repro.scenarios.spec import CampaignGrid
    return CampaignGrid(
        jax.tree.map(lambda x: x[:n], grid.scenarios),
        grid.alpha[:n], grid.seeds[:n], grid.entries[:n],
        None if grid.profiles is None
        else jax.tree.map(lambda x: x[:n], grid.profiles),
    )


def mega_campaign(mini: bool, backends: list[str] | None = None,
                  chunk_size: int = MEGA_CHUNK) -> dict:
    """The 10×-grid deliverable (DESIGN.md §14): the full scenario zoo ×
    a dense α grid × a deep seed axis, under ONE traced chunked campaign.

    Full shape: 10 scenarios × 6 α × 16 seeds = 960 grid rows × 14
    variants (every aggregator, the guard expanded across all five
    backend realizations, in-kernel generation included) = 13 440 runs.
    Mini (CI tier-2): guard-only, 10 × 4 α × 12 seeds × 4 backends =
    1 920 runs at small T.

    Peak memory is the point: the chunked campaign's XLA temp allocation
    is compared against a *chunk-sized reference grid* compiled unchunked
    — the assertion that the mega grid's temp bytes stay ≤ 2× the
    reference is what "peak memory sublinear in runs" means, and it fails
    the benchmark loudly rather than decorating it.
    """
    m = 16
    T = 100 if mini else 1500
    prob = make_generated_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)
    cfg = SolverConfig(m=m, T=T, eta=0.05, alpha=0.25,
                       aggregator="byzantine_sgd", attack="sign_flip",
                       guard_opts=(("sketch_dim", 8),))
    scenarios, static_of = scenario_zoo(T, m)
    if mini:
        alphas, seeds = [0.0625, 0.125, 0.1875, 0.25], range(12)
        aggs: list[str] = ["byzantine_sgd"]
        static_of = None
    else:
        alphas = [0.0625, 0.125, 0.1875, 0.25, 0.3125, 0.375]
        seeds = range(16)
        aggs = AGGREGATORS
    if backends is None:
        backends = MINI_BACKENDS if mini else BACKENDS

    grid = expand_grid(scenarios, alphas, list(seeds))
    result = run_campaign(prob, cfg, grid, aggs, backends=backends,
                          chunk_size=chunk_size)
    ref_n = min(chunk_size, grid.n_runs)
    ref = run_campaign(prob, cfg, _slice_grid(grid, ref_n), aggs,
                       backends=backends)

    record = summarize_campaign(result, prob, cfg, static_of=static_of)
    n_variants = len(result.stats)
    total_runs = grid.n_runs * n_variants
    peak_ratio = peak_bounded = None
    if result.memory and ref.memory:
        peak_ratio = (result.memory["temp_size_in_bytes"]
                      / max(ref.memory["temp_size_in_bytes"], 1))
        peak_bounded = bool(peak_ratio <= 2.0)
    record["grid"] = {
        "n_runs": grid.n_runs,
        "n_variants": n_variants,
        "total_runs": total_runs,
        "chunk_size": chunk_size,
        "n_chunks": -(-grid.n_runs // chunk_size),
        "T": T,
        "backends": list(backends),
        "wall_s": result.wall_s,
        "compile_s": result.compile_s,
        "memory": result.memory,
        "reference_runs": ref_n,
        "reference_memory": ref.memory,
        "peak_temp_ratio_vs_reference": peak_ratio,
        "peak_memory_bounded": peak_bounded,
    }
    emit("scenarios/mega_campaign", result.wall_s * 1e6,
         f"runs={total_runs},chunks={record['grid']['n_chunks']},"
         f"chunk_size={chunk_size},compile_s={result.compile_s:.1f},"
         f"peak_temp_ratio={peak_ratio if peak_ratio is None else round(peak_ratio, 3)},"
         f"bounded={peak_bounded}")
    if peak_bounded is False:
        raise SystemExit(
            f"mega campaign peak-memory assertion failed: chunked temp "
            f"bytes {result.memory['temp_size_in_bytes']} exceed 2x the "
            f"{ref_n}-run reference's {ref.memory['temp_size_in_bytes']}")
    return record


def heterogeneous_campaign(mini: bool,
                           backends: list[str] | None = None) -> dict:
    """The per-worker-state slice (DESIGN.md §13): non-iid data skew,
    periodic stragglers, and partial participation as a *named profile
    axis* of one campaign — every row, the armed-degenerate ``uniform``
    profile included, stacks into the same single ``jit(vmap)`` trace.

    Runs on a heterogenized problem (known optimum, zero-sum per-worker
    bias directions), so the report's Theorem-3.8 check evaluates each
    row's bound at its *realized* skew-inflated V and effective reporter
    count rather than the worst case the problem's V was built for.
    """
    m = 16
    T = 300 if mini else 1500
    max_delay = 3
    # one skew level for CI; the full sweep adds a second
    skews = [0.5] if mini else [0.25, 0.5]
    prob = heterogenize_problem(
        make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0),
        m, skew_max=max(skews), seed=0,
    )
    cfg = SolverConfig(m=m, T=T, eta=0.05, alpha=0.25,
                       aggregator="byzantine_sgd", attack="sign_flip",
                       max_delay=max_delay, partial_participation=True)
    keep = {"static_sign_flip", "churn_sign_flip"}
    scenarios = [s for s in scenario_zoo(T, m)[0] if s[0] in keep]
    # fleet-uniform skew keeps the per-worker biases cancelling exactly,
    # so the known optimum (and hence the bound's gap) stays valid
    profiles = [("uniform", profile_iid(m))]
    profiles += [(f"skew{s:g}", worker_profile(m, skew=s)) for s in skews]
    profiles += [("stragglers", profile_stragglers(m, 0.25, max_delay)),
                 ("partial", profile_partial(m, 0.8))]
    seeds = range(2) if mini else range(4)
    grid = expand_grid(scenarios, [0.25], seeds, profiles=profiles)
    aggs = ["mean", "byzantine_sgd"]
    if backends is None:
        backends = ["dense"] if mini else ["dense", "fused"]
    result = run_campaign(prob, cfg, grid, aggs, backends=backends)
    record = summarize_campaign(result, prob, cfg)
    record["profiles"] = [name for name, _ in profiles]
    record["max_delay"] = max_delay
    n_variants = len(result.stats)
    emit("scenarios/het_campaign", result.wall_s * 1e6,
         f"runs={result.n_runs * n_variants},profiles={len(profiles)},"
         f"compile_s={result.compile_s:.1f}")
    for row in record["guard_bound"]:
        emit(f"scenarios/het_bound/{row['aggregator']}/{row['scenario']}"
             f"/a{row['alpha']}",
             row["gap_med"] * 1e6,
             f"thm38_bound={row['bound']:.4f},within={row['within']},"
             f"V_realized={row['V_realized']:.3f},"
             f"alpha_ever={row['alpha_ever']:.3f},"
             f"in_regime={row['in_regime']}")
    return record


def backend_axis_record(prob, cfg, grid, backends: list[str]) -> dict:
    """Per-backend record: measured steady-state campaign wall-clock (each
    backend's guard-only campaign, compiled separately so the execution time
    is attributable) + the roofline-model per-step steady-state wall-clock
    at the m = 32, d = 2²⁰ headline shape on the target TPU.

    On CPU the fused backend runs the Pallas *interpreter*, so its measured
    numbers are not comparable across backends (``interpret`` is recorded);
    the modeled numbers are the cross-backend comparison — bytes moved is
    wall-clock for this memory-bound step, and the fused pipeline's 3-pass
    sweep is strictly cheaper than the dense 6-pass reference.
    """
    ms, ds = MODEL_SHAPE["m"], MODEL_SHAPE["d"]
    per_backend = {}
    for be in backends:
        timed = run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                             backends=[be])
        name, sdt = parse_backend_spec(be)
        cost = backend_cost(name, ms, ds, sdt or "f32")
        per_backend[be] = {
            "campaign_wall_s": timed.wall_s,
            "campaign_compile_s": timed.compile_s,
            "campaign_runs": timed.n_runs,
            "stats_dtype": sdt or "f32",
            "model_stats_bytes": cost.stats_bytes,
            "model_step_bytes": cost.step_bytes,
            "model_steady_state_us": steady_state_us(cost),
        }
        emit(f"scenarios/backend/{be}", timed.wall_s * 1e6,
             f"runs={timed.n_runs},"
             f"model_step_us_m{ms}_d2e20={per_backend[be]['model_steady_state_us']:.0f}")
    rec = {
        "backends": backends,
        "guard_opts": dict(cfg.guard_opts),
        "model_shape": dict(MODEL_SHAPE, hw=TPU_V5E.name,
                            hbm_bw=TPU_V5E.hbm_bw,
                            source="repro.roofline.guard_cost"),
        "measured_backend": jax.default_backend(),
        "fused_runs_interpret": ops.interpret_mode(),
        "per_backend": per_backend,
    }
    if "dense" in per_backend and "fused" in per_backend:
        rec["fused_le_dense_model"] = bool(
            per_backend["fused"]["model_steady_state_us"]
            <= per_backend["dense"]["model_steady_state_us"]
        )
    if "fused" in per_backend and "fused@bf16" in per_backend:
        # the ISSUE-5 headline: bf16 statistics move ≤ 0.55x the f32 bytes
        rec["bf16_stats_ratio_model"] = (
            per_backend["fused@bf16"]["model_stats_bytes"]
            / per_backend["fused"]["model_stats_bytes"]
        )
    return rec


def _timed_campaign(prob, cfg, grid, backends, telemetry, reps: int = 3):
    """Lower once, execute ``reps`` times, keep the min wall — the
    overhead comparison needs execution-only times robust to scheduler
    noise at the mini shape, which single-shot ``run_campaign`` is not."""
    fn = jax.jit(build_campaign_fn(prob, cfg, ["byzantine_sgd"],
                                   backends=backends, telemetry=telemetry))
    t0 = time.perf_counter()
    compiled = fn.lower(grid).compile()
    compile_s = time.perf_counter() - t0
    walls, out = [], None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = jax.block_until_ready(compiled(grid))
        walls.append(time.perf_counter() - t0)
    return CampaignResult(stats=out, entries=grid.entries,
                          wall_s=min(walls), compile_s=compile_s,
                          n_runs=grid.n_runs)


def trace_campaign(mini: bool, trace_out: str,
                   backends: list[str] | None = None,
                   ring_size: int = 64) -> dict:
    """The flight-recorder deliverable (DESIGN.md §12): a guard-only rerun
    of the leaderboard campaign, telemetry off vs on.

    Off/on wall-clocks give the measured enabled-mode overhead (recorded
    in the trace meta — the ≤10 % acceptance bound lives *in* the
    artifact it gates); the armed run's rings are drained into guard_step
    events for the dynamic cells, roofline comparator rows join each
    backend's measured per-step time against the ``guard_cost`` model at
    the campaign shape, and both JSONL and a Perfetto-loadable chrome
    trace are written next to ``BENCH_scenarios.json``.
    """
    m, d = 16, 16
    T = 300 if mini else 1500
    # heterogenized problem + armed per-worker-state gates: the traced
    # cells sweep a uniform profile next to a mixed skew/straggler/partial
    # one, so the exported frames exercise the n_reporting / staleness
    # lanes of the schema (DESIGN.md §13)
    prob = heterogenize_problem(
        make_quadratic_problem(d=d, sigma=1.0, L=8.0, V=1.0, seed=0),
        m, skew_max=0.3, seed=0,
    )
    cfg = SolverConfig(m=m, T=T, eta=0.05, alpha=0.25,
                       aggregator="byzantine_sgd", attack="sign_flip",
                       guard_opts=(("sketch_dim", 8),),
                       max_delay=2, partial_participation=True)
    scenarios, _ = scenario_zoo(T, m)
    keep = {"static_sign_flip", "adaptive_inner_product",
            "lie_low_then_strike"}
    scenarios = [s for s in scenarios if s[0] in keep]
    profiles = [
        ("uniform", profile_iid(m)),
        ("hetmix", worker_profile(m, skew=0.3, p_report=0.9)._replace(
            delay=profile_stragglers(m, 0.25, 2).delay)),
    ]
    grid = expand_grid(scenarios, [0.25], range(2), profiles=profiles)
    if backends is None:
        backends = ["dense", "fused"]
    tel = TelemetryConfig(enabled=True, ring_size=ring_size)

    log = EventLog(tool="benchmarks.bench_scenarios", mini=mini,
                   m=m, d=d, T=T, ring_size=ring_size,
                   grid_runs=grid.n_runs, backends=list(backends))
    measured_step_us: dict[str, float] = {}
    off_wall = on_wall = 0.0
    n_cells = 0
    dynamic = ("adaptive_inner_product", "lie_low_then_strike")
    results_on = {}
    for be in backends:
        off = _timed_campaign(prob, cfg, grid, [be], None)
        on = _timed_campaign(prob, cfg, grid, [be], tel)
        off_wall += off.wall_s
        on_wall += on.wall_s
        measured_step_us[be] = off.wall_s / (off.n_runs * T) * 1e6
        n_cells += campaign_trace_events(
            on, log, select=lambda e: e["scenario"] in dynamic)
        results_on[be] = on
    overhead = on_wall / max(off_wall, 1e-9) - 1.0
    for row in roofline_rows(measured_step_us, m, d):
        log.event("roofline", **row)
    timelines = [r for be in backends
                 for r in filter_timelines(results_on[be])]
    log.add_meta(telemetry_overhead_frac=overhead,
                 telemetry_off_wall_s=off_wall,
                 telemetry_on_wall_s=on_wall)
    log.write_jsonl(trace_out)
    perfetto = trace_out.rsplit(".", 1)[0] + ".perfetto.json"
    log.write_chrome_trace(perfetto)
    emit("scenarios/telemetry_overhead", overhead * 1e6,
         f"off_s={off_wall:.3f},on_s={on_wall:.3f},cells={n_cells},"
         f"out={trace_out}")
    return {
        "trace_path": trace_out,
        "perfetto_path": perfetto,
        "overhead_frac": overhead,
        "off_wall_s": off_wall,
        "on_wall_s": on_wall,
        "cells_exported": n_cells,
        "events": len(log.events),
        "filter_timelines": timelines,
    }


def matrix_wallclock(mini: bool, skip_looped: bool = False) -> dict:
    """The 6×6 robustness matrix (every static attack × every aggregator),
    batched through one jit vs the historical per-cell Python loop."""
    m = 16
    T = 200 if mini else 2000
    prob = make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)
    cfg = SolverConfig(m=m, T=T, eta=0.05, alpha=0.25,
                       aggregator="byzantine_sgd", attack="sign_flip")
    scenarios = [(a, scenario_static(a)) for a in MATRIX_ATTACKS]
    grid = expand_grid(scenarios, [0.25], [0])
    result = run_campaign(prob, cfg, grid, AGGREGATORS)
    cells = result.n_runs * len(AGGREGATORS)
    rec = {
        "T": T,
        "cells": cells,
        "batched_s": result.wall_s,
        "batched_compile_s": result.compile_s,
    }
    if not skip_looped:
        _, looped_s = run_campaign_looped(prob, cfg, grid, AGGREGATORS)
        rec["looped_s"] = looped_s
        rec["speedup_steady"] = looped_s / max(result.wall_s, 1e-9)
        rec["speedup_incl_compile"] = looped_s / max(
            result.wall_s + result.compile_s, 1e-9
        )
    emit("scenarios/matrix6x6_batched", result.wall_s * 1e6,
         f"cells={cells},compile_s={result.compile_s:.1f}")
    if not skip_looped:
        emit("scenarios/matrix6x6_looped", looped_s * 1e6,
             f"cells={cells},speedup_steady={rec['speedup_steady']:.1f}x,"
             f"incl_compile={rec['speedup_incl_compile']:.2f}x")
    return rec


def main(mini: bool = False, skip_looped: bool = False,
         out_path: str = "BENCH_scenarios.json",
         backends: list[str] | None = None,
         trace_out: str | None = None) -> dict:
    record = campaign_leaderboard(mini, backends=backends)
    record["mega"] = mega_campaign(mini, backends=backends)
    record["heterogeneous"] = heterogeneous_campaign(mini)
    record["matrix6x6_wallclock"] = matrix_wallclock(mini, skip_looped)
    record["mini"] = mini
    if trace_out:
        record["telemetry"] = trace_campaign(mini, trace_out)
    write_report(record, out_path)
    emit("scenarios/report", 0.0,
         f"out={out_path},degraded_pairs={len(degraded_pairs(record))}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mini", action="store_true",
                    help="CI tier-2 shape: 5 scenarios x 2 seeds, small T")
    ap.add_argument("--skip-looped", action="store_true",
                    help="skip the slow per-cell Python-loop baseline")
    ap.add_argument("--backends", default=None,
                    help="comma-separated guard backends to sweep "
                         f"(default: {','.join(MINI_BACKENDS)} for --mini, "
                         f"{','.join(BACKENDS)} otherwise)")
    ap.add_argument("--out", default="BENCH_scenarios.json")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="arm the guard flight recorder on a guard-only "
                         "campaign rerun and write the JSONL event log + "
                         "Perfetto trace here (DESIGN.md §12)")
    args = ap.parse_args()
    main(mini=args.mini, skip_looped=args.skip_looped, out_path=args.out,
         backends=args.backends.split(",") if args.backends else None,
         trace_out=args.trace_out)
