"""Table 1 — sample complexity: iterations-to-ε per aggregator / α / m.

The paper's headline claims, measured:
  * mini-batch SGD (mean) matches ByzantineSGD at α = 0 (criterion 3);
  * under attack, mean diverges while ByzantineSGD's T-to-ε degrades only
    by the additive α² term;
  * parallel speedup: T-to-ε improves with m (Remark 1.2).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_quadratic_problem


def iters_to_eps(problem, cfg: SolverConfig, eps: float, seed: int = 0) -> int:
    res = run_sgd(problem, cfg, jax.random.PRNGKey(seed))
    gaps = np.asarray(res.gaps)
    # smooth out stochastic wiggle with a running min
    below = np.minimum.accumulate(gaps) <= eps
    return int(np.argmax(below)) + 1 if below.any() else -1


def main() -> None:
    prob = make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)
    eps = 2e-2
    T = 4000

    # --- α = 0: guard matches mean ---
    for agg in ["mean", "byzantine_sgd"]:
        cfg = SolverConfig(m=16, T=T, eta=0.05, alpha=0.0, aggregator=agg, attack="none")
        t = iters_to_eps(prob, cfg, eps)
        emit(f"table1/alpha0/{agg}", float(t), f"iters_to_eps={t}")

    # --- α sweep under sign-flip ---
    for alpha in [0.125, 0.25, 0.375]:
        for agg in ["mean", "byzantine_sgd", "coordinate_median", "krum", "trimmed_mean"]:
            cfg = SolverConfig(m=16, T=T, eta=0.05, alpha=alpha,
                               aggregator=agg, attack="sign_flip")
            t = iters_to_eps(prob, cfg, eps)
            emit(f"table1/alpha{alpha}/{agg}", float(t), f"iters_to_eps={t}")

    # --- parallel speedup in m (Remark 1.2) ---
    for m in [4, 8, 16, 32]:
        cfg = SolverConfig(m=m, T=T, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip")
        t = iters_to_eps(prob, cfg, eps)
        emit(f"table1/speedup/m{m}", float(t), f"iters_to_eps={t}")


if __name__ == "__main__":
    main()
