"""Table 1 — sample complexity: iterations-to-ε per aggregator / α / m.

The paper's headline claims, measured:
  * mini-batch SGD (mean) matches ByzantineSGD at α = 0 (criterion 3);
  * under attack, mean diverges while ByzantineSGD's T-to-ε degrades only
    by the additive α² term;
  * parallel speedup: T-to-ε improves with m (Remark 1.2).

Every point is now a ≥ 5-seed distribution (median + IQR), not a single
run: the seeds ride a campaign grid (repro.scenarios.campaign), so each
sweep is one jit(vmap) instead of a Python loop of re-traced solves.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.solver import SolverConfig
from repro.data.problems import make_quadratic_problem
from repro.scenarios import expand_grid, run_campaign, scenario_static

SEEDS = range(5)


def iters_to_eps_batch(gaps: np.ndarray, eps: float) -> np.ndarray:
    """First iteration (1-based) whose running-min gap is ≤ eps, per run;
    -1 where the run never reaches eps.  ``gaps`` is (N, T)."""
    below = np.minimum.accumulate(np.asarray(gaps), axis=1) <= eps
    hit = below.any(axis=1)
    return np.where(hit, below.argmax(axis=1) + 1, -1)


def _emit_quantiles(name: str, t: np.ndarray) -> None:
    ok = t[t > 0]
    if ok.size == 0:
        emit(name, -1.0, f"iters_to_eps_med=-1,n_seeds={t.size},reached=0")
        return
    p25, med, p75 = np.percentile(ok, [25, 50, 75])
    emit(name, float(med),
         f"iters_to_eps_med={int(med)},iqr=[{int(p25)},{int(p75)}],"
         f"reached={ok.size}/{t.size}")


def main() -> None:
    prob = make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)
    eps = 2e-2
    T = 4000

    # --- α = 0: guard matches mean (one campaign, both aggregators) ---
    cfg = SolverConfig(m=16, T=T, eta=0.05, alpha=0.0,
                       aggregator="mean", attack="none")
    grid = expand_grid([("none", scenario_static("none"))], [0.0], SEEDS)
    res = run_campaign(prob, cfg, grid, ["mean", "byzantine_sgd"],
                       return_gaps=True)
    for agg in ["mean", "byzantine_sgd"]:
        t = iters_to_eps_batch(res.stats[agg].gaps, eps)
        _emit_quantiles(f"table1/alpha0/{agg}", t)

    # --- α sweep under sign-flip: one campaign per α, so Krum's f and the
    # trim fraction are sized for that α (the nominal cfg.alpha configures
    # the baselines; only the seeds ride the grid axis here) ---
    for alpha in [0.125, 0.25, 0.375]:
        cfg_a = cfg._replace(alpha=alpha, attack="sign_flip")
        grid = expand_grid([("sign_flip", scenario_static("sign_flip"))],
                           [alpha], SEEDS)
        res = run_campaign(
            prob, cfg_a, grid,
            ["mean", "byzantine_sgd", "coordinate_median", "krum",
             "trimmed_mean"],
            return_gaps=True,
        )
        for agg in res.stats:
            t = iters_to_eps_batch(res.stats[agg].gaps, eps)
            _emit_quantiles(f"table1/alpha{alpha}/{agg}", t)

    # --- guard backends are Table-1-invariant (DESIGN.md §9): the dense,
    # fused-Pallas, and distributed-sketch realizations of the same filter
    # must land the same T-to-ε distribution (one campaign, backend axis;
    # sketch_dim=8 < d so the sketch rows carry real compression noise) ---
    cfg_b = cfg._replace(alpha=0.25, attack="sign_flip",
                         guard_opts=(("sketch_dim", 8),))
    grid = expand_grid([("sign_flip", scenario_static("sign_flip"))],
                       [0.25], SEEDS)
    res = run_campaign(prob, cfg_b, grid, ["byzantine_sgd"],
                       return_gaps=True,
                       backends=["dense", "fused", "dp_sketch"])
    for name in sorted(res.stats):
        t = iters_to_eps_batch(res.stats[name].gaps, eps)
        _emit_quantiles(f"table1/backend/{name.partition('@')[2]}", t)

    # --- parallel speedup in m (Remark 1.2); m is static → one jit per m ---
    for m in [4, 8, 16, 32]:
        cfg_m = SolverConfig(m=m, T=T, eta=0.05, alpha=0.25,
                             aggregator="byzantine_sgd", attack="sign_flip")
        grid = expand_grid([("sign_flip", scenario_static("sign_flip"))],
                           [0.25], SEEDS)
        res = run_campaign(prob, cfg_m, grid, ["byzantine_sgd"],
                           return_gaps=True)
        t = iters_to_eps_batch(res.stats["byzantine_sgd"].gaps, eps)
        _emit_quantiles(f"table1/speedup/m{m}", t)


if __name__ == "__main__":
    main()
