"""Scenario engine: spec/grid plumbing, mask schedules, adaptive feedback,
and the one-jit campaign runner (DESIGN.md §8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_quadratic_problem
from repro.scenarios import (
    ATTACK_TABLE,
    NEVER,
    ScenarioAdversary,
    attack_id,
    expand_grid,
    run_campaign,
    scenario_adaptive,
    scenario_churn,
    scenario_coalition,
    scenario_late_join,
    scenario_lie_low_then_strike,
    scenario_static,
    summarize_campaign,
    theorem38_bound,
)
from repro.scenarios.adversary import ADAPT_MAX, ADAPT_MIN, AdvState


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=1)


def _cfg(**kw):
    base = dict(m=16, T=200, eta=0.05, alpha=0.25,
                aggregator="byzantine_sgd", attack="sign_flip")
    base.update(kw)
    return SolverConfig(**base)


def _adv(scn, alpha=0.25):
    return ScenarioAdversary(scenario=scn, alpha=jnp.float32(alpha))


class TestSpec:
    def test_attack_ids_roundtrip(self):
        for i, name in enumerate(ATTACK_TABLE):
            assert attack_id(name) == i
        with pytest.raises(KeyError):
            attack_id("mirror")  # needs ctx the scenario engine doesn't carry

    def test_expand_grid_cartesian(self):
        scns = [("a", scenario_static("sign_flip")),
                ("b", scenario_static("alie"))]
        grid = expand_grid(scns, alphas=[0.125, 0.25], seeds=[0, 1, 2])
        assert grid.n_runs == 12
        assert grid.alpha.shape == (12,) and grid.seeds.shape == (12,)
        assert grid.scenarios.attack_a.shape == (12,)
        names = [e["scenario"] for e in grid.entries]
        assert names[:6] == ["a"] * 6 and names[6:] == ["b"] * 6

    def test_empty_grid_rejected(self):
        with pytest.raises(ValueError):
            expand_grid([], [0.25], [0])


class TestMaskSchedule:
    rank = jnp.arange(16)  # identity ranks: workers 0..3 byz at α=0.25

    def test_static_mask_fixed_count(self):
        adv = _adv(scenario_static("sign_flip"))
        for k in [0, 57, 199]:
            mask = adv.mask_at(self.rank, jnp.asarray(k))
            assert int(mask.sum()) == 4
            np.testing.assert_array_equal(np.asarray(mask), np.arange(16) < 4)

    def test_late_join_activates_at_step(self):
        adv = _adv(scenario_late_join("sign_flip", join_step=100))
        assert int(adv.mask_at(self.rank, jnp.asarray(99)).sum()) == 0
        assert int(adv.mask_at(self.rank, jnp.asarray(100)).sum()) == 4

    def test_churn_rotates_identity(self):
        adv = _adv(scenario_churn("sign_flip", period=50, stride=4))
        m0 = np.asarray(adv.mask_at(self.rank, jnp.asarray(0)))
        m1 = np.asarray(adv.mask_at(self.rank, jnp.asarray(50)))
        m2 = np.asarray(adv.mask_at(self.rank, jnp.asarray(100)))
        assert m0.sum() == m1.sum() == m2.sum() == 4
        # stride = n_byz → disjoint rotation groups
        assert not (m0 & m1).any() and not (m1 & m2).any()
        np.testing.assert_array_equal(m1, np.roll(m0, 4))

    def test_alpha_zero_never_byzantine(self):
        adv = _adv(scenario_static("sign_flip"), alpha=0.0)
        assert int(adv.mask_at(self.rank, jnp.asarray(0)).sum()) == 0


class TestAdversaryRuntime:
    def test_static_scenario_matches_cfg_attack(self, quad):
        """scale=1 scenarios reproduce the static zoo — the scenario path
        must be a strict generalization of cfg.attack.  Same RNG streams,
        same masks; values agree up to compiler reassociation (the dual
        coalition-phase evaluation fuses reductions differently)."""
        for attack in ["sign_flip", "alie", "inner_product", "hidden_shift"]:
            cfg = _cfg(attack=attack)
            key = jax.random.PRNGKey(3)
            res_static = run_sgd(quad, cfg, key)
            res_scn = run_sgd(quad, cfg, key,
                              adversary=_adv(scenario_static(attack)))
            np.testing.assert_allclose(np.asarray(res_static.gaps),
                                       np.asarray(res_scn.gaps),
                                       rtol=2e-4, atol=1e-6)
            np.testing.assert_array_equal(np.asarray(res_static.byz_mask),
                                          np.asarray(res_scn.byz_mask))

    def test_lie_low_is_honest_before_switch(self, quad):
        """Before switch_step the adversary plays `none`, so the run is
        identical to an unattacked one up to the strike."""
        cfg = _cfg(aggregator="mean", T=100)
        key = jax.random.PRNGKey(0)
        adv = _adv(scenario_lie_low_then_strike("inner_product", switch_step=50))
        res = run_sgd(quad, cfg, key, adversary=adv)
        res_none = run_sgd(quad, cfg, key, adversary=_adv(scenario_static("none")))
        np.testing.assert_allclose(np.asarray(res.gaps[:50]),
                                   np.asarray(res_none.gaps[:50]), rtol=1e-6)
        assert not np.allclose(np.asarray(res.gaps[60:]),
                               np.asarray(res_none.gaps[60:]))

    def test_coalition_split_rows(self, quad):
        """frac=0.5 → half the coalition plays attack_a, half attack_b."""
        adv = _adv(scenario_coalition("sign_flip", "constant_drift", 0.5))
        m, d = 16, quad.d
        grads = jax.random.normal(jax.random.PRNGKey(1), (m, d))
        mask = jnp.arange(m) < 4
        ctx = {"true_grad": quad.grad(quad.x1), "V": quad.V,
               "step": jnp.asarray(0), "alive": jnp.ones((m,), bool),
               "n_alive": jnp.asarray(m), "prev_xi": jnp.zeros((d,))}
        state = adv.init_state(m, d)
        out = np.asarray(adv.attack(jax.random.PRNGKey(2), grads, mask, ctx, state))
        np.testing.assert_allclose(out[:2], -3.0 * np.asarray(grads[:2]), rtol=1e-5)
        drift_row = 10.0 * quad.V * np.ones(d) / np.sqrt(d)
        np.testing.assert_allclose(out[2:4], np.broadcast_to(drift_row, (2, d)),
                                   rtol=1e-5)
        np.testing.assert_array_equal(out[4:], np.asarray(grads[4:]))

    def test_feedback_escalates_and_backs_off(self):
        """update_state judges ξ against the *current* coalition row:
        aligned residual + intact coalition → scale × (1+r); opposed
        residual → scale ÷ (1+r); always clipped."""
        m, d = 8, 4
        adv = _adv(scenario_adaptive("inner_product", adapt_rate=0.5))
        mask = jnp.arange(m) < 2
        dirn = jnp.ones((d,)) / 2.0
        ctx = {"true_grad": jnp.zeros((d,))}
        grads_out = jnp.where(mask[:, None], dirn[None, :], 0.0)
        state = AdvState(adapt_scale=jnp.float32(1.0))
        win = adv.update_state(state, mask, grads_out, xi=dirn,
                               alive=jnp.ones((m,), bool),
                               n_alive=jnp.asarray(m), ctx=ctx)
        assert float(win.adapt_scale) == pytest.approx(1.5)
        lose = adv.update_state(state, mask, grads_out, xi=-dirn,
                                alive=jnp.ones((m,), bool),
                                n_alive=jnp.asarray(m), ctx=ctx)
        assert float(lose.adapt_scale) == pytest.approx(1.0 / 1.5)
        # filtered coalition loses even with aligned residual
        dead = adv.update_state(state, mask, grads_out, xi=dirn,
                                alive=~mask, n_alive=jnp.asarray(m - 2), ctx=ctx)
        assert float(dead.adapt_scale) == pytest.approx(1.0 / 1.5)
        # no currently-Byzantine worker (pre-join) → feedback is a no-op
        idle = adv.update_state(state, jnp.zeros((m,), bool), grads_out,
                                xi=dirn, alive=jnp.ones((m,), bool),
                                n_alive=jnp.asarray(m), ctx=ctx)
        assert float(idle.adapt_scale) == 1.0
        # clipping
        hi = AdvState(adapt_scale=jnp.float32(ADAPT_MAX))
        assert float(adv.update_state(hi, mask, grads_out, xi=dirn,
                                      alive=jnp.ones((m,), bool),
                                      n_alive=jnp.asarray(m),
                                      ctx=ctx).adapt_scale) <= ADAPT_MAX
        assert ADAPT_MIN <= float(lose.adapt_scale)

    def test_engine_rule_equals_combinator_composition(self, quad):
        """ScenarioAdversary.attack collapses the combinator composition
        coalition(phase_switch(a, b, switch), b, frac) to two dispatches —
        pin the equivalence so the two implementations cannot drift."""
        from repro.core.attacks import (
            attack_constant_drift,
            attack_sign_flip,
            coalition,
            phase_switch,
        )
        from repro.scenarios import make_scenario

        m, d = 16, quad.d
        scn = make_scenario(attack_a="sign_flip", attack_b="constant_drift",
                            switch_step=50, coalition_frac=0.5)
        adv = _adv(scn)
        fa = lambda key, grads, mask, ctx: attack_sign_flip(
            key, grads, mask, ctx, scale=3.0)
        fb = lambda key, grads, mask, ctx: attack_constant_drift(
            key, grads, mask, ctx, scale=10.0)
        composed = coalition(phase_switch(fa, fb, 50), fb, 0.5)
        grads = jax.random.normal(jax.random.PRNGKey(4), (m, d))
        mask = jnp.arange(m) < 4
        state = adv.init_state(m, d)
        for k in [0, 49, 50, 120]:
            ctx = {"true_grad": quad.grad(quad.x1), "V": quad.V,
                   "step": jnp.asarray(k), "alive": jnp.ones((m,), bool),
                   "n_alive": jnp.asarray(m), "prev_xi": jnp.zeros((d,))}
            out_engine = adv.attack(jax.random.PRNGKey(5), grads, mask, ctx, state)
            out_comb = composed(jax.random.PRNGKey(5), grads, mask, ctx)
            np.testing.assert_allclose(np.asarray(out_engine),
                                       np.asarray(out_comb), rtol=1e-6)

    def test_adapt_rate_zero_is_static(self):
        m, d = 8, 4
        adv = _adv(scenario_static("inner_product"))
        state = adv.init_state(m, d)
        mask = jnp.arange(m) < 2
        out = adv.update_state(state, mask,
                               jnp.ones((m, d)), xi=jnp.ones((d,)),
                               alive=jnp.ones((m,), bool),
                               n_alive=jnp.asarray(m),
                               ctx={"true_grad": jnp.zeros((d,))})
        assert float(out.adapt_scale) == 1.0


class TestCampaign:
    def test_grid_runs_match_individual_runs(self, quad):
        """The vmapped campaign must reproduce per-run eager results."""
        cfg = _cfg(T=150)
        scns = [("sf", scenario_static("sign_flip")),
                ("churn", scenario_churn("sign_flip", period=75, stride=4))]
        grid = expand_grid(scns, alphas=[0.25], seeds=[0, 1])
        result = run_campaign(quad, cfg, grid, ["mean", "byzantine_sgd"])
        assert result.n_runs == 4
        for agg in ["mean", "byzantine_sgd"]:
            for i, e in enumerate(result.entries):
                scn = dict(scns)[e["scenario"]]
                res = run_sgd(quad, cfg._replace(aggregator=agg),
                              jax.random.PRNGKey(e["seed"]),
                              adversary=_adv(scn, e["alpha"]))
                gap = float(quad.f(res.x_avg) - quad.f(quad.x_star))
                assert float(result.stats[agg].gap_avg[i]) == pytest.approx(
                    gap, rel=1e-5
                ), (agg, e)

    def test_churn_inflates_ever_byzantine(self, quad):
        cfg = _cfg(T=100)
        grid = expand_grid(
            [("churn", scenario_churn("sign_flip", period=50, stride=4)),
             ("static", scenario_static("sign_flip"))],
            alphas=[0.25], seeds=[0],
        )
        result = run_campaign(quad, cfg, grid, ["byzantine_sgd"])
        ever = np.asarray(result.stats["byzantine_sgd"].n_byz_ever)
        by_name = {e["scenario"]: ever[i] for i, e in enumerate(result.entries)}
        assert by_name["churn"] == 8 and by_name["static"] == 4

    def test_return_gaps_shape(self, quad):
        cfg = _cfg(T=60)
        grid = expand_grid([("sf", scenario_static("sign_flip"))],
                           alphas=[0.25], seeds=[0, 1, 2])
        result = run_campaign(quad, cfg, grid, ["mean"], return_gaps=True)
        assert result.stats["mean"].gaps.shape == (3, 60)

    def test_summarize_and_bound(self, quad):
        cfg = _cfg(T=150)
        grid = expand_grid(
            [("static_sf", scenario_static("sign_flip")),
             ("adaptive_ip", scenario_adaptive("inner_product", 0.5))],
            alphas=[0.25], seeds=[0, 1],
        )
        result = run_campaign(quad, cfg, grid, ["mean", "byzantine_sgd"])
        rec = summarize_campaign(result, quad, cfg,
                                 static_of={"adaptive_ip": "static_sf"})
        assert len(rec["leaderboard"]) == 2 * 2  # scenarios × aggregators
        guard_rows = {r["scenario"]: r for r in rec["guard_bound"]}
        assert set(guard_rows) == {"static_sf", "adaptive_ip"}
        for r in guard_rows.values():
            assert r["within"], r  # Theorem-3.8 gap bound holds
        assert all(d["static"] == "static_sf" for d in rec["degradation"])
        assert theorem38_bound(quad, cfg, 0.5) > theorem38_bound(quad, cfg, 0.25)
