"""Fused one-pass guard pipeline vs the dense reference (the oracle).

Covers three layers: the raw fused kernel vs :func:`ref.fused_guard_ref`,
the incremental-Gram identity across steps, and the full
``ByzantineGuard.step`` fused path vs the dense path — clean gradients and
under the alie / sign-flip attacks.  All Pallas calls run interpret mode
on CPU (the kernel dispatch in ``ops`` does this automatically).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import attack_alie, attack_sign_flip
from repro.core.byzantine_sgd import ByzantineGuard, GuardConfig
from repro.kernels import ref
from repro.kernels.fused_guard import fused_guard_pallas

SHAPES = [(4, 64), (8, 1000), (16, 4096), (17, 555), (32, 2048)]


def _rel_close(got, want, tol=1e-5):
    """‖got − want‖ ≤ tol·‖want‖ (+tol absolute for near-zero targets)."""
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    err = np.linalg.norm(got - want)
    assert err <= tol * np.linalg.norm(want) + tol, (err, np.linalg.norm(want))


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_kernel_matches_oracle(m, d, dtype):
    key = jax.random.PRNGKey(m * 1000 + d)
    k1, k2, k3 = jax.random.split(key, 3)
    g = jax.random.normal(k1, (m, d), jnp.float32).astype(dtype)
    B = (3.0 * jax.random.normal(k2, (m, d), jnp.float32)).astype(dtype)
    dlt = jax.random.normal(k3, (d,), jnp.float32).astype(dtype)
    got = fused_guard_pallas(g, B, dlt, d_block=512, interpret=True)
    want = ref.fused_guard_ref(g, B, dlt)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-5
    for a, b in zip(got, want):
        _rel_close(a, b, tol)


def test_incremental_gram_identity():
    """G_B^k = G_B^{k-1} + cross + crossᵀ + gram_g reproduces (B+g)(B+g)ᵀ."""
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    m, d = 12, 777
    B = jax.random.normal(k1, (m, d))
    g = jax.random.normal(k2, (m, d))
    gram_g, cross, _, B_new = fused_guard_pallas(
        g, B, jnp.zeros((d,)), d_block=256, interpret=True
    )
    got = B @ B.T + cross + cross.T + gram_g
    _rel_close(got, B_new @ B_new.T, 1e-5)
    _rel_close(B_new, B + g, 1e-6)


def _run_both(m, d, steps, grads_fn, cfg=None, gram_resync_every=64):
    cfg = cfg or GuardConfig(m=m, T=100, V=1.0, D=5.0)
    dense = ByzantineGuard(cfg)
    fused = ByzantineGuard(cfg, use_fused=True, d_block=256,
                           gram_resync_every=gram_resync_every)
    sd, sf = dense.init(d), fused.init(d)
    x1 = jnp.zeros((d,))
    xd = xf = x1
    for k in range(steps):
        grads = grads_fn(k)
        sd, xi_d, _ = dense.step(sd, grads, xd, x1)
        sf, xi_f, _ = fused.step(sf, grads, xf, x1)
        xd = xd - 0.05 * xi_d
        xf = xf - 0.05 * xi_f
    return sd, sf, xi_d, xi_f


def _assert_paths_agree(sd, sf, xi_d, xi_f):
    assert bool(jnp.all(sd.alive == sf.alive)), "good_k diverged"
    _rel_close(sf.gram_B, sd.gram_B, 1e-5)
    _rel_close(sf.A, sd.A, 1e-5)
    _rel_close(xi_f, xi_d, 1e-5)
    _rel_close(sf.B, sd.B, 1e-5)


@pytest.mark.parametrize("m,d", [(8, 300), (16, 1024), (5, 2000)])
def test_guard_step_fused_equals_dense_clean(m, d):
    key = jax.random.PRNGKey(7)

    def grads_fn(k):
        noise = jax.random.normal(jax.random.fold_in(key, k), (m, d))
        noise = noise / jnp.linalg.norm(noise, axis=1, keepdims=True)
        return 0.1 * jnp.ones((m, d)) + 0.5 * noise

    sd, sf, xi_d, xi_f = _run_both(m, d, 6, grads_fn)
    _assert_paths_agree(sd, sf, xi_d, xi_f)
    assert int(jnp.sum(sf.alive)) == m   # clean workers all survive


@pytest.mark.parametrize("attack", [attack_alie, attack_sign_flip])
def test_guard_step_fused_equals_dense_under_attack(attack):
    m, d = 16, 512
    key = jax.random.PRNGKey(3)
    byz = jnp.isin(jnp.arange(m), jnp.asarray([1, 5, 9, 13]))

    def grads_fn(k):
        kk = jax.random.fold_in(key, k)
        noise = jax.random.normal(kk, (m, d))
        noise = noise / jnp.linalg.norm(noise, axis=1, keepdims=True)
        honest = 0.1 * jnp.ones((m, d)) + 0.5 * noise
        ctx = {"true_grad": 0.1 * jnp.ones((d,)), "V": 1.0, "step": k}
        return attack(kk, honest, byz, ctx)

    sd, sf, xi_d, xi_f = _run_both(m, d, 6, grads_fn)
    _assert_paths_agree(sd, sf, xi_d, xi_f)


def test_fused_gram_resync_matches_dense():
    """With resync firing mid-run (every 2nd step) the fused path re-derives
    gram_B from B — it must still track the dense oracle exactly as the
    pure-incremental path does."""
    m, d = 8, 300
    key = jax.random.PRNGKey(11)

    def grads_fn(k):
        noise = jax.random.normal(jax.random.fold_in(key, k), (m, d))
        return 0.1 * jnp.ones((m, d)) + 0.5 * noise / jnp.linalg.norm(
            noise, axis=1, keepdims=True)

    sd, sf, xi_d, xi_f = _run_both(m, d, 5, grads_fn, gram_resync_every=2)
    _assert_paths_agree(sd, sf, xi_d, xi_f)


def test_fused_filters_gross_outlier_like_dense():
    m, d = 8, 400
    cfg = GuardConfig(m=m, T=100, V=1.0, D=5.0)
    fused = ByzantineGuard(cfg, use_fused=True, d_block=128)
    x1 = jnp.zeros((d,))
    grads = jnp.ones((m, d)) * 0.1
    grads = grads.at[3].set(100.0)
    state, _, _ = fused.step(fused.init(d), grads, x1, x1)
    assert not bool(state.alive[3])
    assert int(jnp.sum(state.alive)) == m - 1
