"""Section-5 lower-bound distinguishing experiments (coarse but real)."""
import jax
import pytest

from repro.core.lower_bound import (
    distinguishing_experiment_linear,
    distinguishing_experiment_strongly_convex,
)


@pytest.mark.slow
def test_linear_threshold_behaviour():
    key = jax.random.PRNGKey(0)
    lo = distinguishing_experiment_linear(key, m=16, T=2, n_trials=48, alpha=0.3, eps=0.05)
    hi = distinguishing_experiment_linear(key, m=16, T=1024, n_trials=48, alpha=0.3, eps=0.05)
    # far below the α²V²D²/ε² threshold: near coin-flip; far above: near 1
    assert float(lo.success_rate) < 0.75
    assert float(hi.success_rate) > 0.9
    assert hi.threshold_T == pytest.approx((0.3 ** 2) / (0.05 ** 2))


@pytest.mark.slow
def test_strongly_convex_threshold_behaviour():
    key = jax.random.PRNGKey(1)
    lo = distinguishing_experiment_strongly_convex(key, m=16, T=2, n_trials=48,
                                                   alpha=0.3, eps_hat=0.05)
    hi = distinguishing_experiment_strongly_convex(key, m=16, T=1024, n_trials=48,
                                                   alpha=0.3, eps_hat=0.05)
    assert float(lo.success_rate) < 0.75
    assert float(hi.success_rate) > 0.9
