"""Differential calibration tests — pin the in-trace math against
independent references.

* :func:`repro.core.attacks.alie_z_max` (computed via
  ``jax.scipy.special.ndtri`` inside the campaign trace) against a
  committed ``scipy.stats.norm.ppf`` table over an (n, ⌈αn⌉) grid — the
  table is generated offline so the suite has **no scipy runtime
  dependency**;
* :func:`~repro.core.aggregators.aggregate_geometric_median` and
  :func:`~repro.core.aggregators.aggregate_autogm` (fixed-iteration,
  f32, jitted) against float64 NumPy brute-force solves at small m;
* :func:`~repro.core.aggregators.simplex_project` against a literal
  NumPy implementation of the Duchi et al. algorithm.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import (
    aggregate_autogm,
    aggregate_geometric_median,
    simplex_project,
)
from repro.core.attacks import alie_z_max

# (n_workers, n_byz, z_max) — scipy.stats.norm.ppf((n-m-s)/(n-m)) with
# s = floor(n/2+1) - m, the blades ALIE supporter-count calibration.
# Regenerate with:
#   python - <<'PY'
#   import math, numpy as np
#   from scipy.stats import norm
#   for n in (8, 12, 16, 20, 24, 32, 48, 64):
#       for alpha in (0.0625, 0.125, 0.1875, 0.25, 0.3125, 0.375):
#           f = math.ceil(alpha * n - 1e-9)
#           if f < 1: continue
#           s = np.floor(n / 2 + 1) - f
#           cdf = np.clip((n - f - s) / (n - f), 1e-6, 1 - 1e-6)
#           print(n, f, norm.ppf(cdf))
#   PY
_Z_TABLE = [
    (8, 1, -0.18001237),
    (8, 2, 0.00000000),
    (8, 3, 0.25334710),
    (12, 1, -0.11418529),
    (12, 2, 0.00000000),
    (12, 3, 0.13971030),
    (12, 4, 0.31863936),
    (12, 5, 0.56594882),
    (16, 1, -0.08365173),
    (16, 2, 0.00000000),
    (16, 3, 0.09655862),
    (16, 4, 0.21042839),
    (16, 5, 0.34875570),
    (16, 6, 0.52440051),
    (20, 2, 0.00000000),
    (20, 3, 0.07379127),
    (20, 4, 0.15731068),
    (20, 5, 0.25334710),
    (20, 7, 0.50240222),
    (20, 8, 0.67448975),
    (24, 2, 0.00000000),
    (24, 3, 0.05971710),
    (24, 5, 0.19920132),
    (24, 6, 0.28221615),
    (24, 8, 0.48877641),
    (24, 9, 0.62292572),
    (32, 2, 0.00000000),
    (32, 4, 0.08964235),
    (32, 6, 0.19402814),
    (32, 8, 0.31863936),
    (32, 10, 0.47278912),
    (32, 12, 0.67448975),
    (48, 3, 0.02785503),
    (48, 6, 0.11964811),
    (48, 9, 0.22688544),
    (48, 12, 0.35549042),
    (48, 15, 0.51570479),
    (48, 18, 0.72791329),
    (64, 4, 0.04178930),
    (64, 8, 0.13468979),
    (64, 12, 0.24340418),
    (64, 16, 0.37409541),
    (64, 20, 0.53751911),
    (64, 24, 0.75541503),
]


@pytest.mark.parametrize("n,f,z_ref", _Z_TABLE,
                         ids=[f"n{n}_f{f}" for n, f, _ in _Z_TABLE])
def test_alie_z_max_matches_scipy_table(n, f, z_ref):
    z = jax.jit(alie_z_max)(n, f)
    assert abs(float(z) - z_ref) < 2e-5


def test_alie_z_max_traced_counts():
    """The campaign path: z_max vmapped over traced per-step Byzantine
    counts (churn schedules change m mid-run) stays finite and matches the
    per-pair evaluation."""
    ns = jnp.asarray([t[0] for t in _Z_TABLE])
    fs = jnp.asarray([t[1] for t in _Z_TABLE])
    zs = jax.jit(jax.vmap(alie_z_max))(ns, fs)
    refs = np.asarray([t[2] for t in _Z_TABLE])
    assert np.all(np.isfinite(np.asarray(zs)))
    np.testing.assert_allclose(np.asarray(zs), refs, atol=2e-5)


def test_alie_z_max_saturates_past_majority():
    """A coalition past n/2 is outside the calibration's regime — the cdf
    clip saturates instead of returning ±inf."""
    z = float(alie_z_max(16, 9))
    assert np.isfinite(z)


# ---------------------------------------------------------------------------
# geometric median / AutoGM vs float64 NumPy brute force
# ---------------------------------------------------------------------------

def _np_weiszfeld(x: np.ndarray, w: np.ndarray | None = None,
                  iters: int = 5000, tol: float = 1e-12,
                  floor: float = 1e-6) -> np.ndarray:
    """Float64 smoothed Weiszfeld to convergence — the brute-force
    reference, with the same distance floor as the jitted implementation."""
    w = np.ones(x.shape[0]) if w is None else w
    y = np.mean(x, axis=0)
    for _ in range(iters):
        dist = np.linalg.norm(x - y[None], axis=1)
        ww = w / np.maximum(dist, floor)
        if ww.sum() <= 0:
            return y
        y_new = (ww @ x) / ww.sum()
        if np.linalg.norm(y_new - y) < tol:
            return y_new
        y = y_new
    return y


def _np_simplex_project(y: np.ndarray) -> np.ndarray:
    u = np.sort(y)[::-1]
    css = np.cumsum(u)
    j = np.arange(1, y.size + 1)
    rho = int(np.max(np.where(u + (1.0 - css) / j > 0, j, 1)))
    tau = (css[rho - 1] - 1.0) / rho
    return np.maximum(y - tau, 0.0)


def _np_autogm(x: np.ndarray, lamb: float, outer: int = 50) -> np.ndarray:
    """Float64 alternating minimization of the AutoGM objective (mean warm
    start, matching the jitted schedule)."""
    m = x.shape[0]
    a = np.full(m, 1.0 / m)
    for _ in range(outer):
        v = _np_weiszfeld(x, a)
        dist = np.linalg.norm(x - v[None], axis=1)
        a = _np_simplex_project(-dist / (2.0 * lamb))
    return _np_weiszfeld(x, a)


def _autogm_obj(x: np.ndarray, v: np.ndarray, lamb: float) -> float:
    dist = np.linalg.norm(x - v[None], axis=1)
    # evaluate at the optimal alphas for this v (the alternating scheme's
    # exact alpha-step), so the comparison is over v alone
    a = _np_simplex_project(-dist / (2.0 * lamb))
    return float(a @ dist + lamb * (a @ a))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_geometric_median_matches_numpy_brute_force(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(7, 5))
    ref = _np_weiszfeld(x)
    got = np.asarray(aggregate_geometric_median(
        jnp.asarray(x, jnp.float32), n_iters=64))
    obj = lambda y: np.linalg.norm(x - y[None], axis=1).sum()
    assert obj(got) <= obj(ref) + 1e-4
    np.testing.assert_allclose(got, ref, atol=1e-3)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_autogm_matches_numpy_brute_force(seed):
    rng = np.random.default_rng(seed)
    x = np.concatenate([rng.normal(size=(6, 4)), 50.0 + rng.normal(size=(2, 4))])
    lamb = 2.0
    ref = _np_autogm(x, lamb)
    # long fixed-iteration schedule: the comparison targets the alternation
    # fixed point, not the campaign default (n_outer=4) snapshot
    got = np.asarray(aggregate_autogm(
        jnp.asarray(x, jnp.float32), lamb=lamb, n_outer=64, n_inner=64))
    assert _autogm_obj(x, got, lamb) <= _autogm_obj(x, ref, lamb) + 1e-3
    np.testing.assert_allclose(got, ref, atol=5e-3)


@pytest.mark.parametrize("seed", range(5))
def test_simplex_project_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    y = rng.normal(size=11) * 3.0
    got = np.asarray(simplex_project(jnp.asarray(y, jnp.float32)))
    ref = _np_simplex_project(y)
    np.testing.assert_allclose(got, ref, atol=1e-5)
    assert abs(got.sum() - 1.0) < 1e-5
    assert (got >= 0).all()
