"""Attack zoo semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import ATTACKS, apply_attack


@pytest.fixture
def setup(rng):
    m, d = 8, 16
    grads = jax.random.normal(rng, (m, d))
    byz = jnp.arange(m) < 3
    ctx = {"true_grad": jnp.ones((d,)) * 0.5, "V": 1.0, "step": 0}
    return grads, byz, ctx


def test_none_is_identity(setup, rng):
    grads, byz, ctx = setup
    np.testing.assert_array_equal(apply_attack("none", rng, grads, byz, ctx), grads)


@pytest.mark.parametrize("name", sorted(set(ATTACKS) - {"none", "mirror"}))
def test_good_rows_untouched(setup, rng, name):
    grads, byz, ctx = setup
    out = apply_attack(name, rng, grads, byz, ctx)
    np.testing.assert_array_equal(out[~byz], grads[~byz])
    assert out.shape == grads.shape


def test_sign_flip(setup, rng):
    grads, byz, ctx = setup
    out = apply_attack("sign_flip", rng, grads, byz, ctx, scale=3.0)
    np.testing.assert_allclose(out[byz], -3.0 * grads[byz], rtol=1e-6)


def test_hidden_shift_within_deviation_bound(setup, rng):
    grads, byz, ctx = setup
    out = apply_attack("hidden_shift", rng, grads, byz, ctx, c=0.9)
    dev = jnp.linalg.norm(out[byz] - ctx["true_grad"][None], axis=1)
    assert float(jnp.max(dev)) <= 0.9 * ctx["V"] + 1e-5  # passes the ∇-check


def test_alie_rows_close_to_good_stats(setup, rng):
    grads, byz, ctx = setup
    out = apply_attack("alie", rng, grads, byz, ctx, z=1.0)
    mu = jnp.mean(grads[~byz], axis=0)
    sd = jnp.std(grads[~byz], axis=0)
    assert float(jnp.max(jnp.abs(out[byz][0] - (mu - sd)))) < 1e-4


def test_mirror_uses_ctx(setup, rng):
    grads, byz, ctx = setup
    ctx = dict(ctx, mirror_grads=-grads)
    out = apply_attack("mirror", rng, grads, byz, ctx)
    np.testing.assert_array_equal(out[byz], -grads[byz])
