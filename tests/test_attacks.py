"""Attack zoo semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import ATTACKS, alie_z_max, apply_attack


@pytest.fixture
def setup(rng):
    m, d = 8, 16
    grads = jax.random.normal(rng, (m, d))
    byz = jnp.arange(m) < 3
    # full solver-provided ctx, incl. the previous-step feedback channel
    ctx = {"true_grad": jnp.ones((d,)) * 0.5, "V": 1.0, "step": 0,
           "alive": jnp.ones((m,), bool), "n_alive": jnp.asarray(m),
           "prev_xi": jnp.zeros((d,))}
    return grads, byz, ctx


def test_none_is_identity(setup, rng):
    grads, byz, ctx = setup
    np.testing.assert_array_equal(apply_attack("none", rng, grads, byz, ctx), grads)


@pytest.mark.parametrize("name", sorted(set(ATTACKS) - {"none", "mirror"}))
def test_good_rows_untouched(setup, rng, name):
    grads, byz, ctx = setup
    out = apply_attack(name, rng, grads, byz, ctx)
    np.testing.assert_array_equal(out[~byz], grads[~byz])
    assert out.shape == grads.shape


def test_sign_flip(setup, rng):
    grads, byz, ctx = setup
    out = apply_attack("sign_flip", rng, grads, byz, ctx, scale=3.0)
    np.testing.assert_allclose(out[byz], -3.0 * grads[byz], rtol=1e-6)


def test_hidden_shift_within_deviation_bound(setup, rng):
    grads, byz, ctx = setup
    out = apply_attack("hidden_shift", rng, grads, byz, ctx, c=0.9)
    dev = jnp.linalg.norm(out[byz] - ctx["true_grad"][None], axis=1)
    assert float(jnp.max(dev)) <= 0.9 * ctx["V"] + 1e-5  # passes the ∇-check


def test_alie_rows_close_to_good_stats(setup, rng):
    grads, byz, ctx = setup
    out = apply_attack("alie", rng, grads, byz, ctx, z=1.0)
    mu = jnp.mean(grads[~byz], axis=0)
    sd = jnp.std(grads[~byz], axis=0)
    assert float(jnp.max(jnp.abs(out[byz][0] - (mu - sd)))) < 1e-4


def test_alie_default_is_calibrated(setup, rng):
    """z=None (the default) computes the blades supporter-count z_max
    in-trace; passing the same value explicitly must match bit-for-bit."""
    grads, byz, ctx = setup
    z = float(alie_z_max(grads.shape[0], int(jnp.sum(byz))))
    out_default = apply_attack("alie", rng, grads, byz, ctx)
    out_pinned = apply_attack("alie", rng, grads, byz, ctx, z=z)
    np.testing.assert_allclose(out_default, out_pinned, rtol=1e-6)


def test_alie_update_mirrors_alie(setup, rng):
    """The fedavg/update variant probes the opposite coordinate-wise tail:
    the two Byzantine rows average to exactly the honest mean."""
    grads, byz, ctx = setup
    a = apply_attack("alie", rng, grads, byz, ctx)
    b = apply_attack("alie_update", rng, grads, byz, ctx)
    mu = jnp.mean(grads[~byz], axis=0)
    np.testing.assert_allclose(
        np.asarray((a[byz][0] + b[byz][0]) / 2.0), np.asarray(mu), atol=1e-5)


def test_alie_z_scale_scales_deviation(setup, rng):
    grads, byz, ctx = setup
    mu = jnp.mean(grads[~byz], axis=0)
    one = apply_attack("alie", rng, grads, byz, ctx, z_scale=1.0)
    two = apply_attack("alie", rng, grads, byz, ctx, z_scale=2.0)
    np.testing.assert_allclose(
        np.asarray(two[byz][0] - mu), 2.0 * np.asarray(one[byz][0] - mu),
        rtol=1e-4, atol=1e-6)


def test_mirror_uses_ctx(setup, rng):
    grads, byz, ctx = setup
    ctx = dict(ctx, mirror_grads=-grads)
    out = apply_attack("mirror", rng, grads, byz, ctx)
    np.testing.assert_array_equal(out[byz], -grads[byz])


def test_retreat_on_filter_feedback(setup, rng):
    """Strikes while the coalition is intact, reverts to honesty once the
    guard's previous filter decision caught any colluder."""
    grads, byz, ctx = setup
    struck = apply_attack("retreat_on_filter", rng, grads, byz, ctx)
    expect = apply_attack("inner_product", rng, grads, byz, ctx)
    np.testing.assert_array_equal(struck, expect)
    caught = dict(ctx, alive=ctx["alive"].at[0].set(False))  # worker 0 is byz
    out = apply_attack("retreat_on_filter", rng, grads, byz, caught)
    np.testing.assert_array_equal(out, grads)


def test_phase_switch_combinator(setup, rng):
    from repro.core.attacks import attack_none, attack_sign_flip, phase_switch

    fn = phase_switch(attack_none, attack_sign_flip, switch_step=10)
    early = fn(rng, *setup[:2], dict(setup[2], step=jnp.asarray(5)))
    late = fn(rng, *setup[:2], dict(setup[2], step=jnp.asarray(10)))
    np.testing.assert_array_equal(early, setup[0])
    np.testing.assert_allclose(late[setup[1]], -3.0 * setup[0][setup[1]], rtol=1e-6)


def test_coalition_combinator(setup, rng):
    from repro.core.attacks import attack_constant_drift, attack_sign_flip, coalition

    grads, byz, ctx = setup  # byz = workers 0,1,2
    fn = coalition(attack_sign_flip, attack_constant_drift, frac=0.5)
    out = fn(rng, grads, byz, ctx)
    # ceil(0.5·3) = 2 → workers 0,1 sign-flip; worker 2 drifts
    np.testing.assert_allclose(out[:2], -3.0 * grads[:2], rtol=1e-6)
    drift = apply_attack("constant_drift", rng, grads, byz, ctx)
    np.testing.assert_array_equal(out[2], drift[2])
    np.testing.assert_array_equal(out[~byz], grads[~byz])
