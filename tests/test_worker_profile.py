"""Per-worker state axis (DESIGN.md §13): degenerate-profile bit-identity,
reporting-mask filter semantics, the churn+late-join α_ever oracle under
partial participation, the Theorem-3.8 regime flag, and the eval_shape
sharding-spec regression for the (m,)-leaf WorkerProfile / stale buffer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.byzantine_sgd import masked_median
from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import heterogenize_problem, make_quadratic_problem
from repro.scenarios import (
    ScenarioAdversary,
    WorkerProfile,
    profile_iid,
    profile_knobs,
    profile_linear_skew,
    profile_partial,
    profile_stragglers,
    scenario_churn,
    scenario_late_join,
    scenario_static,
    summarize_campaign,
    worker_profile,
)
from repro.scenarios.campaign import CampaignResult, RunStats


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=1)


@pytest.fixture(scope="module")
def het_quad(quad):
    return heterogenize_problem(quad, m=16, skew_max=0.5, seed=3)


def _cfg(**kw):
    base = dict(m=16, T=120, eta=0.05, alpha=0.25,
                aggregator="byzantine_sgd", attack="sign_flip")
    base.update(kw)
    return SolverConfig(**base)


def _adv(scn, alpha=0.25, profile=None):
    return ScenarioAdversary(scenario=scn, alpha=jnp.float32(alpha),
                             profile=profile)


def _bytes(x):
    return np.asarray(x).tobytes()


class TestProfileConstructors:
    def test_broadcast_and_dtypes(self):
        p = worker_profile(8, skew=0.5, delay=2, p_report=0.9)
        assert p.skew.shape == (8,) and p.skew.dtype == jnp.float32
        assert p.delay.shape == (8,) and p.delay.dtype == jnp.int32
        assert p.p_report.shape == (8,) and p.p_report.dtype == jnp.float32

    def test_stragglers_count(self):
        p = profile_stragglers(16, frac=0.25, delay=3)
        assert int((p.delay > 0).sum()) == 4
        assert int(p.delay.max()) == 3

    def test_knobs_summary(self):
        assert profile_knobs(None) == {
            "skew": 0.0, "max_delay": 0, "participation": 1.0}
        k = profile_knobs(worker_profile(8, skew=0.5, delay=2, p_report=0.8))
        assert k["skew"] == 0.5 and k["max_delay"] == 2
        assert k["participation"] == pytest.approx(0.8)

    def test_profile_is_stackable_pytree(self):
        a, b = profile_iid(8), profile_linear_skew(8, 0.5)
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), a, b)
        assert stacked.skew.shape == (2, 8)


class TestDegenerateBitIdentity:
    def test_solver_armed_machinery_is_bit_identical(self, het_quad):
        """The acceptance pin: heterogenized problem + degenerate profile +
        staleness/participation gates armed reproduces the profile=None
        trajectory bytes-for-bytes (skew 0 → identical gradients, delay 0 →
        buffer refreshed every step, p_report 1 → everyone reports)."""
        key = jax.random.PRNGKey(7)
        scn = scenario_static("sign_flip")
        base = run_sgd(het_quad, _cfg(), key, adversary=_adv(scn))
        armed = run_sgd(
            het_quad,
            _cfg(max_delay=3, partial_participation=True),
            key,
            adversary=_adv(scn, profile=profile_iid(16)),
        )
        assert _bytes(armed.x_final) == _bytes(base.x_final)
        assert _bytes(armed.x_avg) == _bytes(base.x_avg)
        assert _bytes(armed.gaps) == _bytes(base.gaps)
        np.testing.assert_array_equal(np.asarray(armed.n_alive),
                                      np.asarray(base.n_alive))
        np.testing.assert_array_equal(np.asarray(armed.final_alive),
                                      np.asarray(base.final_alive))
        # p_report = 1 → the reporter count is pinned at m every step
        assert base.n_reporting is None
        np.testing.assert_array_equal(np.asarray(armed.n_reporting),
                                      np.full(120, 16, dtype=np.int32))

    def test_gates_stay_cold_without_profile(self, quad):
        """cfg.max_delay / cfg.partial_participation alone (profile=None)
        must not change the trace at all."""
        key = jax.random.PRNGKey(11)
        scn = scenario_static("sign_flip")
        base = run_sgd(quad, _cfg(), key, adversary=_adv(scn))
        cold = run_sgd(quad, _cfg(max_delay=5, partial_participation=True),
                       key, adversary=_adv(scn))
        assert _bytes(cold.gaps) == _bytes(base.gaps)
        assert cold.n_reporting is None


class TestReportingMask:
    def test_honest_nonreporters_never_filtered(self, quad):
        """The filter only scores reporters: an honest worker that never
        reports can never be filtered, no matter what the Byzantine
        reporters do (DESIGN.md §13 reporting-mask vs alive-mask)."""
        res = run_sgd(
            quad,
            _cfg(max_delay=0, partial_participation=True),
            jax.random.PRNGKey(5),
            adversary=_adv(scenario_static("sign_flip"),
                           profile=profile_partial(16, 0.0)),
        )
        honest = ~np.asarray(res.byz_mask)
        assert np.asarray(res.final_alive)[honest].all()
        assert not bool(res.ever_filtered_good)
        # Byzantine workers always report, so every step sees exactly n_byz
        np.testing.assert_array_equal(np.asarray(res.n_reporting),
                                      np.full(120, 4, dtype=np.int32))

    def test_alpha_ever_matches_schedule_oracle_under_partial(self, quad):
        """ever-Byzantine is the pure mask-schedule union — partial
        participation must not leak into it (scenario_churn's docstring
        promise).  Checked against a step-by-step oracle for churn and
        late-join."""
        for scn in [scenario_churn("sign_flip", period=30, stride=4),
                    scenario_late_join("sign_flip", join_step=60)]:
            adv = _adv(scn, profile=profile_partial(16, 0.5))
            key = jax.random.PRNGKey(9)
            res = run_sgd(quad, _cfg(partial_participation=True), key,
                          adversary=adv)
            _, mask_key = jax.random.split(key)
            from repro.core.solver import byz_rank
            rank = byz_rank(mask_key, 16)
            oracle = np.zeros(16, dtype=bool)
            for k in range(120):
                oracle |= np.asarray(adv.mask_at(rank, jnp.asarray(k)))
            np.testing.assert_array_equal(np.asarray(res.byz_mask), oracle)


class TestRegimeFlag:
    def _synthetic_result(self, n_byz_ever, report_frac=None):
        n = len(n_byz_ever)
        stats = RunStats(
            gap_avg=jnp.full((n,), 0.05),
            gap_final=jnp.full((n,), 0.05),
            n_alive_final=jnp.full((n,), 16, dtype=jnp.int32),
            n_byz_ever=jnp.asarray(n_byz_ever, dtype=jnp.int32),
            detect_latency=jnp.full((n,), -1, dtype=jnp.int32),
            ever_filtered_good=jnp.zeros((n,), dtype=bool),
            report_frac=(None if report_frac is None
                         else jnp.asarray(report_frac, dtype=jnp.float32)),
        )
        entries = [
            {"scenario": "churn", "alpha": 0.25, "seed": 0},
            {"scenario": "static", "alpha": 0.25, "seed": 0},
        ]
        return CampaignResult(stats={"byzantine_sgd": stats}, entries=entries,
                              wall_s=0.0, compile_s=0.0, n_runs=n)

    def test_out_of_regime_rows_are_flagged(self, quad):
        """α_ever ≥ 1/2 leaves the Theorem-3.8 regime: the guard row must
        say so (in_regime False, within None) instead of asserting a bound
        the theorem never claimed."""
        rec = summarize_campaign(self._synthetic_result([10, 4]),
                                 quad, _cfg())
        rows = {r["scenario"]: r for r in rec["guard_bound"]}
        assert rows["churn"]["alpha_ever"] == pytest.approx(10 / 16)
        assert rows["churn"]["in_regime"] is False
        assert rows["churn"]["within"] is None
        assert rows["static"]["in_regime"] is True
        assert isinstance(rows["static"]["within"], bool)

    def test_m_eff_and_realized_v(self, het_quad):
        """Bound rows evaluate at the realized reporter count and the
        heterogeneity-inflated V, and record both."""
        rec = summarize_campaign(
            self._synthetic_result([4, 4], report_frac=[0.75, 1.0]),
            het_quad, _cfg())
        rows = {r["scenario"]: r for r in rec["guard_bound"]}
        assert rows["churn"]["m_eff"] == pytest.approx(12.0)
        assert rows["static"]["m_eff"] == pytest.approx(16.0)
        v_real = het_quad.het["V0"] + 0.0 * het_quad.het["cmax"]
        assert rows["churn"]["V_realized"] == pytest.approx(v_real)

    def test_entry_label_suffixes_profiles(self):
        from repro.scenarios.report import _entry_label
        assert _entry_label({"scenario": "alie", "profile": "iid"}) == "alie"
        assert _entry_label({"scenario": "alie"}) == "alie"
        assert (_entry_label({"scenario": "alie", "profile": "stragglers"})
                == "alie+stragglers")


class TestHeterogenizedProblem:
    def test_zero_row_sum_and_provenance(self, quad, het_quad):
        assert het_quad.het is not None
        assert het_quad.het["V0"] == pytest.approx(quad.V)
        assert het_quad.V == pytest.approx(
            quad.V + 0.5 * het_quad.het["cmax"])

    def test_zero_skew_gradient_is_bitwise_unchanged(self, het_quad):
        key = jax.random.PRNGKey(0)
        x = jnp.ones(16)
        g0 = het_quad.stoch_grad(key, x)
        g = het_quad.het_grad(key, x, jnp.float32(0.0),
                              jnp.asarray(0, jnp.int32))
        assert _bytes(g) == _bytes(g0)


class TestShardingSpecsRegression:
    """eval_shape-based regression (DESIGN.md §13): make_train_specs must
    mirror init_train_state exactly — including the stale-gradient buffer —
    and route (m,)-profile / (W,d)-buffer leaves to the worker axes."""

    @pytest.fixture(scope="class")
    def mesh(self):
        return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))

    def test_specs_match_init_state_with_stale_buffer(self, mesh):
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.distributed.sharding import LOGICAL_RULES_SINGLE_POD
        from repro.distributed.specs import make_train_specs
        from repro.distributed.trainer import init_train_state
        from repro.models import build_model
        from repro.optim import adamw

        mcfg = get_config("internlm2-1.8b").reduced(max_d_model=128)
        model = build_model(mcfg)
        W = 8
        cfg = SolverConfig(m=W, T=16, eta=1e-3, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip",
                           guard_backend="dp_exact",
                           max_delay=2, partial_participation=True)
        adv = _adv(scenario_static("sign_flip"),
                   profile=worker_profile(W, delay=2, p_report=0.9))
        opt = adamw(1e-3)
        shape = InputShape(name="t", seq_len=32, global_batch=W, kind="train")
        rules = LOGICAL_RULES_SINGLE_POD

        state_sds, _, rank_sds, _ = make_train_specs(
            model, cfg, "adamw", shape, rules, mesh, adversary=adv)
        state_abs = jax.eval_shape(
            lambda k: init_train_state(model, opt, cfg, k, adversary=adv),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

        assert (jax.tree_util.tree_structure(state_sds)
                == jax.tree_util.tree_structure(state_abs))
        jax.tree_util.tree_map(
            lambda s, a: (s.shape, jnp.dtype(s.dtype)),
            state_sds, state_abs)  # structural zip must not raise
        mism = [
            (s.shape, a.shape, s.dtype, a.dtype)
            for s, a in zip(jax.tree_util.tree_leaves(state_sds),
                            jax.tree_util.tree_leaves(state_abs))
            if s.shape != a.shape or jnp.dtype(s.dtype) != jnp.dtype(a.dtype)
        ]
        assert not mism, mism

        # the stale buffer is worker × flat_grad, not replicated
        d = state_sds.anchor.shape[0]
        assert state_sds.grad_buf.shape == (W, d)
        assert state_sds.grad_buf.sharding.spec == P(("data",), "model")
        assert rank_sds.sharding.spec == P(("data",))

    def test_specs_omit_buffer_when_gate_cold(self, mesh):
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.distributed.sharding import LOGICAL_RULES_SINGLE_POD
        from repro.distributed.specs import make_train_specs
        from repro.models import build_model

        mcfg = get_config("internlm2-1.8b").reduced(max_d_model=128)
        model = build_model(mcfg)
        W = 8
        cfg = SolverConfig(m=W, T=16, eta=1e-3, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip",
                           guard_backend="dp_exact", max_delay=2)
        shape = InputShape(name="t", seq_len=32, global_batch=W, kind="train")
        state_sds, _, _, _ = make_train_specs(
            model, cfg, "adamw", shape, LOGICAL_RULES_SINGLE_POD, mesh)
        assert state_sds.grad_buf == ()

    def test_profile_leaves_land_on_worker_axis(self, mesh):
        from repro.distributed.sharding import LOGICAL_RULES_SINGLE_POD
        from repro.distributed.specs import _flat_state_specs

        W = 8
        prof_abs = jax.eval_shape(lambda: worker_profile(W, delay=1))
        specs = _flat_state_specs(prof_abs, W, LOGICAL_RULES_SINGLE_POD, mesh)
        for leaf in jax.tree_util.tree_leaves(specs):
            assert leaf.shape == (W,)
            assert leaf.sharding.spec == P(("data",))


# ---------------------------------------------------------------------------
# hypothesis invariants (same gating convention as test_properties.py)
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           alpha=st.sampled_from([0.0, 0.125, 0.25]))
    def test_full_participation_zero_delay_is_identity(seed, alpha):
        """Any profile with p_report=1 and delay=0 (skew 0) reproduces the
        profile=None trajectory bit-identically, for any seed/α."""
        prob = make_quadratic_problem(d=8, sigma=1.0, L=8.0, V=1.0, seed=2)
        cfg = SolverConfig(m=8, T=40, eta=0.05, alpha=alpha,
                           aggregator="byzantine_sgd", attack="sign_flip")
        key = jax.random.PRNGKey(seed)
        scn = scenario_static("sign_flip")
        base = run_sgd(prob, cfg, key, adversary=_adv(scn, alpha=alpha))
        armed_cfg = SolverConfig(m=8, T=40, eta=0.05, alpha=alpha,
                                 aggregator="byzantine_sgd",
                                 attack="sign_flip", max_delay=4,
                                 partial_participation=True)
        armed = run_sgd(prob, armed_cfg, key,
                        adversary=_adv(scn, alpha=alpha,
                                       profile=profile_iid(8)))
        assert _bytes(armed.gaps) == _bytes(base.gaps)
        assert _bytes(armed.x_final) == _bytes(base.x_final)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), m=st.integers(3, 24))
    def test_masked_median_full_mask_matches_jnp(seed, m):
        x = jax.random.normal(jax.random.PRNGKey(seed), (m,)) * 3.0
        full = masked_median(x, jnp.ones(m, dtype=bool))
        assert _bytes(full) == _bytes(jnp.median(x))

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           m=st.integers(4, 16), n_mask=st.integers(1, 3))
    def test_masked_median_equals_median_of_subset(seed, m, n_mask):
        n_mask = min(n_mask, m - 1)
        x = jax.random.normal(jax.random.PRNGKey(seed), (m,)) * 3.0
        mask = jnp.arange(m) >= n_mask
        sub = jnp.median(x[n_mask:])
        np.testing.assert_allclose(np.asarray(masked_median(x, mask)),
                                   np.asarray(sub), rtol=1e-6, atol=1e-7)

except ImportError:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise
