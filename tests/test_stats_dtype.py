"""The statistics-precision axis (DESIGN.md §5 Numerics): bf16 guard
statistics must *decide* like the f32 reference.

The tentpole contract of the ``SolverConfig.stats_dtype`` axis is that
halving the filter pipeline's HBM traffic does not change which workers
the filter keeps: long multi-step attack runs pin the bf16 filter
decisions (the full n_alive trace, the final alive set, the Byzantine
assignment) to the f32 oracle across the dense / fused / dp_exact
backends, with the ``gram_resync_every`` re-derivation both on and off
— drift in the *incremental* Gram is exactly what the resync exists to
bound, so the off case is the harsher one.  The single allowed
divergence is the documented one-step crossing jitter of DESIGN.md §5
Numerics (threshold-marginal martingale crossings may detect one step
later under bf16 — the dtype analogue of the §3 sketch slack).
(``dp_sketch`` decisions carry that sketch slack themselves, so it gets
a convergence contract, not bit-equal decisions.)

Satellite coverage rides along: the kernel-level ``B_new`` storage
dtype, the tree harness' cast-once-at-ravel hook, the roofline dtype
dimension, and the campaign ``fused@bf16`` variant spelling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.byzantine_sgd import resolve_stats_dtype
from repro.core.guard_backends import make_guard_backend, parse_backend_spec
from repro.core.solver import SolverConfig, run_sgd
from repro.core.tree_harness import TreeHarness
from repro.data.problems import make_quadratic_problem
from repro.kernels.fused_guard import fused_guard_pallas
from repro.roofline.guard_cost import backend_cost, stats_elem_bytes
from repro.scenarios import expand_variants

# the committed campaign attack set (benchmarks/bench_scenarios.py
# scenario_zoo statics) — the shapes the acceptance criterion names
ATTACKS = ["sign_flip", "alie", "inner_product", "hidden_shift"]


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=1)


def _cfg(**kw):
    base = dict(m=16, T=100, eta=0.05, alpha=0.25,
                aggregator="byzantine_sgd", attack="sign_flip")
    base.update(kw)
    return SolverConfig(**base)


def _assert_traces_match(f32: np.ndarray, bf16: np.ndarray, tag: str):
    """n_alive traces must be step-for-step equal, except for the one
    documented slack of the dtype axis (DESIGN.md §5 Numerics): a
    threshold-*marginal* martingale crossing (inner_product's geometry)
    may land one step later/earlier under bf16 rounding.  Any mismatched
    step must therefore be pure crossing jitter — the bf16 value equals
    the f32 value of an adjacent step — and there can be at most one
    jittered crossing per run.  A spurious drop (a value the f32 trace
    never takes around that step) still fails."""
    mism = np.nonzero(f32 != bf16)[0]
    assert mism.size <= 1, (tag, mism)
    for k in mism:
        neighbors = {f32[k - 1]} if k > 0 else set()
        if k + 1 < f32.size:
            neighbors.add(f32[k + 1])
        assert bf16[k] in neighbors, (tag, k, f32[k - 1:k + 2], bf16[k])


def _backend_cfgs(resync):
    """(backend, guard_opts) grid of the drift oracle.  dense has no
    incremental Gram (it re-derives from B every step — the resync taken
    to its limit), so it appears once."""
    if resync is None:
        return [("dense", ())]
    return [
        ("fused", (("gram_resync_every", resync),)),
        ("dp_exact", (("auto_v", False), ("gram_resync_every", resync))),
    ]


class TestDriftOracle:
    @pytest.mark.parametrize("attack", ATTACKS)
    @pytest.mark.parametrize("resync", [None, 8, 0],
                             ids=["dense", "resync8", "noresync"])
    def test_bf16_decisions_match_f32(self, quad, attack, resync):
        """Long attacked runs: identical filter decisions at every step.

        ``resync=8`` fires the f32 re-derivation many times inside T=100;
        ``resync=0`` never does — the accumulated incremental-Gram
        rounding alone must stay below the decision margins."""
        for backend, opts in _backend_cfgs(resync):
            key = jax.random.PRNGKey(3)
            res = {}
            for sdt in ("f32", "bf16"):
                cfg = _cfg(attack=attack, guard_backend=backend,
                           guard_opts=opts, stats_dtype=sdt)
                res[sdt] = run_sgd(quad, cfg, key)
            tag = f"{backend}/{attack}"
            np.testing.assert_array_equal(
                np.asarray(res["bf16"].byz_mask),
                np.asarray(res["f32"].byz_mask), err_msg=tag)
            _assert_traces_match(np.asarray(res["f32"].n_alive),
                                 np.asarray(res["bf16"].n_alive), tag)
            np.testing.assert_array_equal(
                np.asarray(res["bf16"].final_alive),
                np.asarray(res["f32"].final_alive), err_msg=tag)
            # trajectories track to bf16 resolution (decisions equal ⇒ ξ
            # differs only by the stats rounding)
            np.testing.assert_allclose(
                np.asarray(res["bf16"].x_avg), np.asarray(res["f32"].x_avg),
                rtol=2e-2, atol=2e-2, err_msg=tag)
            if attack == "sign_flip":
                # non-vacuity: the filter actually fired on this run
                assert int(res["f32"].n_alive[-1]) < 16, tag
                assert not bool(res["f32"].ever_filtered_good), tag
                assert not bool(res["bf16"].ever_filtered_good), tag

    def test_dp_sketch_bf16_filters_and_converges(self, quad):
        """Sketch decisions carry documented slack (DESIGN.md §3), so the
        bf16 contract is the same as its f32 one: isolate the attackers,
        converge, never drop a good worker."""
        cfg = _cfg(T=150, guard_backend="dp_sketch", stats_dtype="bf16",
                   guard_opts=(("sketch_dim", 8),))
        res = run_sgd(quad, cfg, jax.random.PRNGKey(2))
        n_byz = int(np.asarray(res.byz_mask).sum())
        assert int(res.n_alive[-1]) == cfg.m - n_byz
        assert not bool(res.ever_filtered_good)
        gap = float(quad.f(res.x_avg) - quad.f(quad.x_star))
        assert gap < 0.2, gap


class TestStatsDtypePlumbing:
    def test_unknown_stats_dtype_raises(self, quad):
        with pytest.raises(KeyError, match="unknown stats_dtype"):
            resolve_stats_dtype("fp8")
        with pytest.raises(KeyError, match="unknown stats_dtype"):
            make_guard_backend("dense", quad, _cfg(stats_dtype="f16"))

    def test_guard_state_b_storage_dtype(self, quad):
        """Every backend stores its B martingale in the stats dtype."""
        for backend in ("dense", "fused", "dp_exact", "dp_sketch"):
            for sdt, want in (("f32", jnp.float32), ("bf16", jnp.bfloat16)):
                state0, _ = make_guard_backend(
                    backend, quad, _cfg(guard_backend=backend,
                                        stats_dtype=sdt))
                b_leaves = jax.tree_util.tree_leaves(state0.B)
                assert all(l.dtype == want for l in b_leaves), (backend, sdt)

    def test_fused_kernel_b_new_in_storage_dtype(self):
        m, d = 8, 300
        g = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
        B = jnp.zeros((m, d), jnp.bfloat16)
        gram_g, cross, a_inc, b_new = fused_guard_pallas(
            g.astype(jnp.bfloat16), B, jnp.zeros((d,), jnp.bfloat16),
            d_block=128, interpret=True)
        assert b_new.dtype == jnp.bfloat16
        # accumulators stay f32 regardless of the streamed strips' dtype
        assert gram_g.dtype == cross.dtype == a_inc.dtype == jnp.float32
        np.testing.assert_allclose(
            np.asarray(b_new, np.float32),
            np.asarray(g.astype(jnp.bfloat16), np.float32))

    def test_parse_backend_spec(self):
        assert parse_backend_spec("fused") == ("fused", None)
        assert parse_backend_spec("fused@bf16") == ("fused", "bf16")
        with pytest.raises(KeyError, match="unknown stats_dtype"):
            parse_backend_spec("fused@f64")

    def test_expand_variants_dtype_axis(self):
        cfgs = expand_variants(_cfg(), ["mean", "byzantine_sgd"],
                               backends=["fused", "fused@bf16"])
        assert set(cfgs) == {"mean", "byzantine_sgd@fused",
                             "byzantine_sgd@fused@bf16"}
        assert cfgs["byzantine_sgd@fused"].stats_dtype == "f32"
        v = cfgs["byzantine_sgd@fused@bf16"]
        assert (v.guard_backend, v.stats_dtype) == ("fused", "bf16")
        # explicit full spelling passes through too
        cfgs = expand_variants(_cfg(), ["byzantine_sgd@dp_exact@bf16"])
        v = cfgs["byzantine_sgd@dp_exact@bf16"]
        assert (v.guard_backend, v.stats_dtype) == ("dp_exact", "bf16")

    def test_stats_dtype_registries_agree(self):
        """The solver-side dtype table and the (jax-free) roofline byte
        table name the same axis: same keys, bytes == jnp itemsize."""
        from repro.core.byzantine_sgd import STATS_DTYPES
        from repro.roofline.guard_cost import STATS_DTYPE_BYTES
        assert set(STATS_DTYPES) == set(STATS_DTYPE_BYTES)
        for name in STATS_DTYPES:
            assert STATS_DTYPE_BYTES[name] == resolve_stats_dtype(name).itemsize

    def test_roofline_dtype_dimension(self):
        m, d = 32, 1 << 20
        assert stats_elem_bytes("bf16") == 2 and stats_elem_bytes("f32") == 4
        c32 = backend_cost("fused", m, d, "f32")
        c16 = backend_cost("fused", m, d, "bf16")
        # the ISSUE-5 headline criterion at the headline shape
        assert c16.stats_bytes <= 0.55 * c32.stats_bytes
        assert c16.step_bytes * 2 == c32.step_bytes
        # flops are dtype-independent (accumulation stays f32)
        assert c16.flops == c32.flops

    def test_tree_harness_cast_once_at_ravel(self):
        tree = {"a": jnp.ones((3, 5), jnp.float32),
                "b": jnp.zeros((3, 7), jnp.float32)}
        h = TreeHarness(jax.tree_util.tree_map(lambda l: l[0], tree))
        flat = h.ravel_workers(tree, dtype=jnp.bfloat16)
        assert flat.dtype == jnp.bfloat16 and flat.shape == (3, h.d)
        # padding stays zero; values round-trip through the template dtype
        np.testing.assert_array_equal(np.asarray(flat[:, 12:], np.float32), 0)
        back = h.unravel(h.ravel(jax.tree_util.tree_map(lambda l: l[0], tree),
                                 dtype=jnp.bfloat16))
        assert back["a"].dtype == jnp.float32
