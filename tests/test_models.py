"""Model-layer unit tests: attention equivalences, SSD vs naive recurrence,
MoE dispatch, decode-vs-forward agreement."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.models.attention import chunked_attention
from repro.models.common import cross_entropy, rms_norm
from repro.models.moe import moe_apply, moe_defs
from repro.models.ssm import _ssd_scan
from repro.models.common import init_params


def naive_attention(q, k, v, causal=True, window=None):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    R = H // KV
    qg = q.reshape(B, S, KV, R, hd).astype(jnp.float32)
    s = jnp.einsum("bqkrh,bckh->bkrqc", qg, k.astype(jnp.float32)) / np.sqrt(hd)
    i = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= i[:, None] >= i[None, :]
    if window is not None:
        mask &= i[:, None] - i[None, :] < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrqc,bckh->bkrqh", w, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, S, H, hd)


class TestChunkedAttention:
    @pytest.mark.parametrize("S,chunk", [(64, 16), (64, 64), (60, 16), (128, 32)])
    @pytest.mark.parametrize("H,KV", [(4, 4), (8, 2)])
    def test_matches_naive_causal(self, rng, S, chunk, H, KV):
        B, hd = 2, 16
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, KV, hd))
        v = jax.random.normal(ks[2], (B, S, KV, hd))
        pos = jnp.arange(S)
        got = chunked_attention(q, k, v, pos, pos, causal=True, chunk=chunk)
        want = naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_sliding_window_matches_naive(self, rng):
        B, S, H, hd = 1, 96, 4, 8
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, H, hd))
        v = jax.random.normal(ks[2], (B, S, H, hd))
        pos = jnp.arange(S)
        got = chunked_attention(q, k, v, pos, pos, causal=True, window=16, chunk=32)
        want = naive_attention(q, k, v, causal=True, window=16)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_non_causal(self, rng):
        B, S, H, hd = 1, 48, 2, 8
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, H, hd))
        v = jax.random.normal(ks[2], (B, S, H, hd))
        pos = jnp.arange(S)
        got = chunked_attention(q, k, v, pos, pos, causal=False, chunk=16)
        want = naive_attention(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


class TestSSD:
    def test_matches_naive_recurrence(self, rng):
        """Chunked SSD == exact sequential state-space recurrence."""
        B, S, H, P, G, N = 2, 64, 4, 8, 1, 16
        ks = jax.random.split(rng, 4)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(rng, 9), (B, S, G, N)) * 0.5

        y_chunk, state_chunk = _ssd_scan(x, dt, A, Bm, Cm, chunk=16)

        # naive: h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t ; y_t = C_t h_t
        R = H // G
        Bf = jnp.repeat(Bm, R, axis=2)
        Cf = jnp.repeat(Cm, R, axis=2)
        h = jnp.zeros((B, H, N, P))
        ys = []
        for t in range(S):
            a = jnp.exp(A[None] * dt[:, t])                       # (B,H)
            h = a[..., None, None] * h + jnp.einsum(
                "bhn,bhp->bhnp", Bf[:, t], dt[:, t][..., None] * x[:, t])
            ys.append(jnp.einsum("bhn,bhnp->bhp", Cf[:, t], h))
        y_naive = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(y_chunk, y_naive, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(state_chunk, h, rtol=2e-3, atol=2e-3)

    def test_initial_state_continuation(self, rng):
        """Running two halves with carried state == one full pass."""
        B, S, H, P, G, N = 1, 64, 2, 4, 1, 8
        ks = jax.random.split(rng, 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
        Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
        y_full, s_full = _ssd_scan(x, dt, A, Bm, Cm, chunk=16)
        y1, s1 = _ssd_scan(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], 16)
        y2, s2 = _ssd_scan(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:], 16,
                           initial_state=s1)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(s2, s_full, rtol=2e-3, atol=2e-3)


class TestMoE:
    def _cfg(self, **kw):
        base = dict(name="t", arch_type="moe", source="t", n_layers=1, d_model=32,
                    n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                    n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=2.0)
        base.update(kw)
        return ModelConfig(**base)

    def test_output_shape_and_aux(self, rng):
        cfg = self._cfg()
        p = init_params(rng, moe_defs(cfg), jnp.float32)
        x = 0.1 * jax.random.normal(rng, (2, 8, 32))
        out, aux = moe_apply(p, cfg, x)
        assert out.shape == x.shape
        assert float(aux) >= 1.0 - 1e-3   # Switch aux ≥ 1 at balance

    def test_capacity_drop_is_graceful(self, rng):
        cfg = self._cfg(capacity_factor=0.1)   # force drops
        p = init_params(rng, moe_defs(cfg), jnp.float32)
        x = 0.1 * jax.random.normal(rng, (2, 16, 32))
        out, aux = moe_apply(p, cfg, x)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_shared_expert_always_active(self, rng):
        cfg = self._cfg(n_shared_experts=1)
        p = init_params(rng, moe_defs(cfg), jnp.float32)
        x = 0.1 * jax.random.normal(rng, (1, 4, 32))
        out, _ = moe_apply(p, cfg, x)
        # zeroing routed experts must keep shared-expert contribution
        p2 = dict(p)
        p2["down"] = jnp.zeros_like(p["down"])
        out2, _ = moe_apply(p2, cfg, x)
        assert float(jnp.max(jnp.abs(out2))) > 0.0


class TestCommon:
    def test_rms_norm_unit_scale(self, rng):
        x = jax.random.normal(rng, (4, 32)) * 7.0
        y = rms_norm(x, jnp.ones((32,)), 1e-6)
        rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_cross_entropy_perfect_prediction(self):
        logits = jnp.full((2, 4, 8), -20.0)
        labels = jnp.asarray([[1, 2, 3, 0], [4, 5, 6, 7]])
        logits = logits.at[
            jnp.arange(2)[:, None], jnp.arange(4)[None, :], labels
        ].set(20.0)
        loss, _ = cross_entropy(logits, labels, z_loss=0.0)
        assert float(loss) < 1e-3


def test_tied_embeddings_option(rng):
    cfg = dataclasses.replace(get_config("internlm2-1.8b").reduced(), tie_embeddings=True)
    model = build_model(cfg)
    params = model.init(rng)
    assert "lm_head" not in params
    batch = {"tokens": jnp.zeros((1, 32), jnp.int32), "labels": jnp.zeros((1, 32), jnp.int32)}
    loss, _ = jax.jit(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss)


class TestQuantKVCache:
    def test_int8_cache_close_to_bf16(self, rng):
        """§Perf serving lever: per-step decode with int8 cache tracks the
        bf16 cache within quantization tolerance (teacher-forced)."""
        import dataclasses
        from repro.configs import get_config
        cfg = get_config("llama3.2-3b").reduced()
        outs = {}
        for dt in ["bfloat16", "int8"]:
            c = dataclasses.replace(cfg, kv_cache_dtype=dt)
            model = build_model(c)
            params = model.init(jax.random.PRNGKey(0))
            batch = {"tokens": jnp.arange(2 * 32).reshape(2, 32) % c.vocab_size}
            lp, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_len=64))(params, batch)
            ld, _ = jax.jit(model.decode_step)(params, cache, jnp.full((2, 1), 5, jnp.int32))
            outs[dt] = np.asarray(ld)
        a, b = outs["bfloat16"], outs["int8"]
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 0.1, rel
        assert (a.argmax(-1) == b.argmax(-1)).mean() == 1.0

    def test_quantize_roundtrip_bounded(self, rng):
        from repro.models.attention import _quantize
        x = jax.random.normal(rng, (4, 8, 2, 16))
        q, s = _quantize(x)
        deq = q.astype(jnp.float32) * s.astype(jnp.float32)[..., None]
        err = jnp.max(jnp.abs(deq - x)) / jnp.max(jnp.abs(x))
        assert float(err) < 1.0 / 100  # absmax int8: ≤ scale/2 per element
