"""Sharding rules + spec builders (logical→mesh mapping invariants).

These run on a single CPU device using AbstractMesh-free tiny meshes is not
possible (1 device), so we validate the pure logic: divisibility fallback,
conflict resolution, spec construction from ParamDefs, and the roofline
HLO collective parser on synthetic HLO text.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (
    LOGICAL_RULES_SINGLE_POD,
    logical_to_spec,
)
from repro.models.model import model_defs
from repro.roofline.analysis import collective_bytes_from_hlo, active_params, model_flops
from repro.configs.base import INPUT_SHAPES


class FakeMesh:
    """Duck-typed mesh exposing .shape mapping (enough for logical_to_spec)."""
    def __init__(self, **axes):
        self.shape = axes


MESH = FakeMesh(data=16, model=16)


class TestLogicalToSpec:
    def test_basic_mapping(self):
        spec = logical_to_spec(("embed", "heads", None), (4096, 64, 128),
                               LOGICAL_RULES_SINGLE_POD, MESH)
        assert spec == P(None, "model", None)

    def test_divisibility_fallback(self):
        # kv=2 heads don't divide model=16 → replicate
        spec = logical_to_spec(("embed", "kv_heads", None), (4096, 2, 128),
                               LOGICAL_RULES_SINGLE_POD, MESH)
        assert spec == P(None, None, None)

    def test_conflict_earlier_dim_wins(self):
        rules = dict(LOGICAL_RULES_SINGLE_POD, cache_seq="model", kv_heads="model")
        spec = logical_to_spec(("batch", "cache_seq", "kv_heads", None),
                               (128, 32768, 16, 128), rules, MESH)
        assert spec == P("data", "model", None, None)

    def test_tuple_axes(self):
        rules = dict(LOGICAL_RULES_SINGLE_POD, worker=("pod", "data"))
        mesh = FakeMesh(pod=2, data=16, model=16)
        spec = logical_to_spec(("worker", None), (32, 7), rules, mesh)
        assert spec == P(("pod", "data"), None)

    def test_no_rules_means_replicated(self):
        spec = logical_to_spec(("embed", "heads"), (8, 8), None, None)
        assert spec == P(None, None)


class TestParamDefsCoverage:
    @pytest.mark.parametrize("arch", ["llama3.2-3b", "kimi-k2-1t-a32b", "jamba-v0.1-52b"])
    def test_all_leaves_have_axes_matching_rank(self, arch):
        defs = model_defs(get_config(arch))
        from repro.models.common import ParamDef, is_def
        for leaf in jax.tree_util.tree_leaves(defs, is_leaf=is_def):
            assert len(leaf.axes) == len(leaf.shape), leaf

    def test_kimi_param_count_near_1t(self):
        total, active = active_params(get_config("kimi-k2-1t-a32b"))
        assert 0.8e12 < total < 1.3e12, total
        assert 20e9 < active < 45e9, active

    def test_dense_active_equals_total(self):
        total, active = active_params(get_config("llama3.2-3b"))
        assert total == active
        assert 2.5e9 < total < 4.5e9


class TestHloCollectiveParser:
    HLO = """
  %ag = f32[128,256]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = (f32[64]{0}, f32[32]{0}) all-reduce(%a, %b), replica_groups=[8,2]<=[16], to_apply=%add
  %rs = bf16[4,8]{1,0} reduce-scatter(%c), replica_groups=[1,4]<=[4], dimensions={0}
  %cp = f32[10]{0} collective-permute(%d), source_target_pairs={{0,1}}
  %done = f32[1]{0} all-gather-done(%h)
"""

    def test_kinds_and_sizes(self):
        stats = collective_bytes_from_hlo(self.HLO)
        ag = 128 * 256 * 4 * 15 // 16
        ar = 2 * (64 + 32) * 4 * 1 // 2
        rs = 4 * 8 * 2 * 3
        cp = 40
        assert stats.by_kind["all-gather"] == ag
        assert stats.by_kind["all-reduce"] == ar
        assert stats.by_kind["reduce-scatter"] == rs
        assert stats.by_kind["collective-permute"] == cp
        assert stats.total_bytes == ag + ar + rs + cp


class TestModelFlops:
    def test_train_flops_scale(self):
        mf = model_flops(get_config("llama3.2-3b"), INPUT_SHAPES["train_4k"])
        # 6 · ~3.4B · 1M tokens ≈ 2.1e16
        assert 1e16 < mf < 4e16

    def test_decode_flops_tiny(self):
        mf = model_flops(get_config("llama3.2-3b"), INPUT_SHAPES["decode_32k"])
        assert mf < 1e13
