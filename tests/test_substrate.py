"""Optimizers, schedules, checkpointing, data pipeline, utils."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.problems import (
    make_least_squares_problem,
    make_logistic_problem,
    make_quadratic_problem,
)
from repro.data.synthetic import SyntheticTokens, make_worker_batch
from repro.optim import adamw, cosine_schedule, linear_warmup_cosine, momentum, projected_sgd, sgd
from repro.utils import (
    clip_by_global_norm,
    project_ball,
    tree_add,
    tree_norm,
    tree_vdot,
)


class TestOptimizers:
    def _quad_min(self, opt, steps=400):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"x": jnp.zeros(3)}
        state = opt.init(params)
        for i in range(steps):
            g = {"x": 2.0 * (params["x"] - target)}
            upd, state = opt.update(g, state, params, jnp.asarray(i))
            params = tree_add(params, upd)
        return float(jnp.max(jnp.abs(params["x"] - target)))

    def test_sgd(self):
        assert self._quad_min(sgd(0.1)) < 1e-3

    def test_momentum(self):
        assert self._quad_min(momentum(0.02, beta=0.9)) < 1e-3

    def test_adamw(self):
        assert self._quad_min(adamw(0.05)) < 1e-2

    def test_grad_clip_bounds_step(self):
        opt = sgd(1.0, grad_clip=0.5)
        upd, _ = opt.update({"x": jnp.asarray([100.0, 0.0])}, {}, {"x": jnp.zeros(2)},
                            jnp.asarray(0))
        assert abs(float(tree_norm(upd)) - 0.5) < 1e-5

    def test_projected_sgd_stays_in_ball(self):
        x1 = {"x": jnp.zeros(2)}
        opt = projected_sgd(1.0, x1, D=1.0)
        params = {"x": jnp.asarray([0.9, 0.0])}
        state = opt.init(params)
        upd, _ = opt.update({"x": jnp.asarray([-5.0, 0.0])}, state, params, jnp.asarray(0))
        new = tree_add(params, upd)
        assert float(tree_norm(new)) <= 1.0 + 1e-5

    def test_adamw_weight_decay_shrinks(self):
        opt = adamw(0.1, weight_decay=0.1)
        params = {"x": jnp.asarray([10.0])}
        state = opt.init(params)
        upd, _ = opt.update({"x": jnp.asarray([0.0])}, state, params, jnp.asarray(0))
        assert float(upd["x"][0]) < 0.0


class TestSchedules:
    def test_cosine_endpoints(self):
        s = cosine_schedule(1.0, 100, final_frac=0.1)
        assert abs(float(s(jnp.asarray(0))) - 1.0) < 1e-5
        assert abs(float(s(jnp.asarray(100))) - 0.1) < 1e-5

    def test_warmup_ramps(self):
        s = linear_warmup_cosine(1.0, warmup=10, total_steps=100)
        assert float(s(jnp.asarray(0))) < 0.11
        assert float(s(jnp.asarray(10))) > 0.9


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, rng):
        tree = {"a": jax.random.normal(rng, (4, 3)),
                "b": [jnp.arange(5), {"c": jnp.float32(2.5)}]}
        save_checkpoint(str(tmp_path), 7, tree)
        assert latest_step(str(tmp_path)) == 7
        restored, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 7
        for x, y in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_allclose(x, y)

    def test_structure_mismatch_raises(self, tmp_path, rng):
        save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros(3)})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), {"zz": jnp.zeros(3)})

    def test_latest_of_many(self, tmp_path):
        for s in (1, 5, 3):
            save_checkpoint(str(tmp_path), s, {"a": jnp.zeros(2)})
        assert latest_step(str(tmp_path)) == 5


class TestSyntheticData:
    def test_deterministic_per_worker_step(self):
        st = SyntheticTokens(vocab_size=97, seq_len=16, seed=3)
        a = st.sample(jnp.asarray(1), jnp.asarray(5), 4)
        b = st.sample(jnp.asarray(1), jnp.asarray(5), 4)
        np.testing.assert_array_equal(a, b)
        c = st.sample(jnp.asarray(2), jnp.asarray(5), 4)
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_batch_shapes_and_labels(self):
        st = SyntheticTokens(vocab_size=97, seq_len=16)
        batch = make_worker_batch(st, 4, 2, jnp.asarray(0))
        assert batch["tokens"].shape == (4, 2, 16)
        assert batch["labels"].shape == (4, 2, 16)
        assert int(jnp.max(batch["tokens"])) < 97

    def test_poisoning_flips_only_masked(self):
        st = SyntheticTokens(vocab_size=96, seq_len=8)
        mask = jnp.asarray([True, False, False, False])
        clean = make_worker_batch(st, 4, 2, jnp.asarray(0))
        pois = make_worker_batch(st, 4, 2, jnp.asarray(0), poison_mask=mask)
        assert not np.array_equal(np.asarray(clean["labels"][0]), np.asarray(pois["labels"][0]))
        np.testing.assert_array_equal(clean["labels"][1:], pois["labels"][1:])

    def test_learnable_structure(self):
        """Next token is a deterministic function of current + small noise —
        bigram mutual information should be high (sanity that a model can
        learn it)."""
        st = SyntheticTokens(vocab_size=64, seq_len=64, noise_levels=4)
        seq = np.asarray(st.sample(jnp.asarray(0), jnp.asarray(0), 8))
        nxt = (st.a * seq[:, :-1] + st.b) % st.vocab_size
        diff = (seq[:, 1:] - nxt) % st.vocab_size
        assert diff.max() < st.noise_levels


class TestProblems:
    def test_quadratic_properties(self):
        p = make_quadratic_problem(d=8, sigma=0.5, L=4.0, V=1.0)
        g = p.grad(p.x_star)
        assert float(jnp.linalg.norm(g)) < 1e-5
        # deviation bound holds a.s.
        for i in range(20):
            dev = p.stoch_grad(jax.random.PRNGKey(i), p.x1) - p.grad(p.x1)
            assert float(jnp.linalg.norm(dev)) <= p.V + 1e-5

    def test_least_squares_xstar(self):
        p = make_least_squares_problem(d=6, n_data=128, noise=0.01)
        assert float(jnp.linalg.norm(p.grad(p.x_star))) < 1e-4

    def test_logistic_gradient_correct(self):
        p = make_logistic_problem(d=5, n_data=64)
        gnum = jax.grad(p.f)(p.x1)
        np.testing.assert_allclose(p.grad(p.x1), gnum, rtol=1e-4, atol=1e-5)


class TestUtils:
    def test_project_ball(self, rng):
        x = {"a": jnp.asarray([3.0, 4.0])}
        c = {"a": jnp.zeros(2)}
        p = project_ball(x, c, 1.0)
        np.testing.assert_allclose(tree_norm(p), 1.0, rtol=1e-5)
        inside = project_ball({"a": jnp.asarray([0.1, 0.0])}, c, 1.0)
        np.testing.assert_allclose(inside["a"], [0.1, 0.0], rtol=1e-6)

    def test_tree_vdot_symmetric(self, rng):
        a = {"x": jax.random.normal(rng, (3, 3))}
        b = {"x": jax.random.normal(jax.random.fold_in(rng, 1), (3, 3))}
        np.testing.assert_allclose(tree_vdot(a, b), tree_vdot(b, a), rtol=1e-6)
