"""Tree harness (DESIGN.md §10): ravel/unravel round-trips, flat-vs-pytree
parity through the *trainer*, unified baselines, scenario adversaries in
training, and full-TrainState checkpoint resume.

The parity contract is the PR's acceptance bar: a single-leaf ``(d,)``
pytree problem driven through ``build_train_step`` — the tree harness, the
shared ``make_aggregator``, the flat attack zoo, the projected optimizer —
must reproduce ``run_sgd``'s filter decisions exactly and its iterates to
1e-5, for the ``dense``, ``fused`` and ``dp_exact(auto_v=False)`` guard
backends.  The trainer and the convex harness share every aggregation line
of code; what the test pins is the adapter (ravel/unravel + key plumbing).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.solver import (
    SolverConfig,
    byz_rank,
    ceil_byzantine_count,
    run_sgd,
)
from repro.core.tree_harness import FlatSpec, TreeHarness, VectorModel
from repro.data.problems import make_quadratic_problem
from repro.distributed.trainer import (
    TrainState,
    build_train_step,
    init_train_state,
    rank_from_mask,
)
from repro.optim.optimizers import projected_sgd, sgd


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=1)


def _tree(rng, W=None, seed_shift=0):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(rng, seed_shift), 3)
    lead = (W,) if W is not None else ()
    return {
        "a": jax.random.normal(k1, lead + (3, 5)),
        "b": {"c": jax.random.normal(k2, lead + (17,)),
              "d": jax.random.normal(k3, lead + (2, 2, 2)).astype(jnp.bfloat16)},
    }


class TestRavelUnravel:
    @pytest.mark.parametrize("pad_to", [1, 8, 128])
    def test_round_trip_multi_leaf(self, rng, pad_to):
        t = _tree(rng)
        h = TreeHarness(t, pad_to=pad_to)
        assert h.d_raw == 15 + 17 + 8
        assert h.d % pad_to == 0 and h.d >= h.d_raw
        back = h.unravel(h.ravel(t))
        assert jax.tree_util.tree_structure(back) == jax.tree_util.tree_structure(t)
        for l1, l2 in zip(jax.tree_util.tree_leaves(t),
                          jax.tree_util.tree_leaves(back)):
            assert l1.dtype == l2.dtype
            np.testing.assert_allclose(
                np.asarray(l1, np.float32), np.asarray(l2, np.float32),
                rtol=1e-6, atol=1e-6,
            )

    def test_round_trip_property_random_trees(self, rng):
        """Round-trip over a family of random multi-leaf trees (shapes and
        nesting vary per draw) — the property-test form of the contract."""
        for i in range(10):
            key = jax.random.fold_in(rng, 100 + i)
            ks = jax.random.split(key, 3)
            shapes = [tuple(int(s) for s in np.random.default_rng(i).integers(1, 5, size=n))
                      for n in (1, 2, 3)]
            t = [{"x": jax.random.normal(ks[j], shapes[j])} for j in range(3)]
            h = TreeHarness(t)
            back = h.unravel(h.ravel(t))
            for l1, l2 in zip(jax.tree_util.tree_leaves(t),
                              jax.tree_util.tree_leaves(back)):
                np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    def test_ravel_workers_matches_per_worker_ravel(self, rng):
        W = 5
        t = _tree(rng, W=W)
        h = TreeHarness(jax.tree_util.tree_map(lambda l: l[0], t))
        flat = h.ravel_workers(t)
        assert flat.shape == (W, h.d)
        for w in range(W):
            row = h.ravel(jax.tree_util.tree_map(lambda l: l[w], t))
            np.testing.assert_array_equal(np.asarray(flat[w]), np.asarray(row))

    def test_padding_is_zero(self, rng):
        t = _tree(rng)
        h = TreeHarness(t, pad_to=128)
        flat = h.ravel(t)
        np.testing.assert_array_equal(np.asarray(flat[h.d_raw:]), 0.0)

    def test_rank_from_mask_round_trip(self):
        mask = jnp.asarray([False, True, False, True, False])
        rank = rank_from_mask(mask)
        np.testing.assert_array_equal(
            np.asarray(rank < int(mask.sum())), np.asarray(mask)
        )


def _drive_trainer(problem, cfg, key0, T, *, V=None, D=None, adversary=None):
    """Run the trainer on ``VectorModel(problem)`` with run_sgd's *exact*
    key chain: same mask key, same per-step (gkey → worker noise, akey)
    splits, so the two paths see identical gradients and attack draws."""
    model = VectorModel(problem)
    opt = projected_sgd(cfg.eta, {"x": problem.x1}, problem.D)
    V = problem.V if V is None else V
    D = problem.D if D is None else D
    ts = jax.jit(build_train_step(model, opt, cfg, V=V, D=D,
                                  adversary=adversary))
    state = init_train_state(model, opt, cfg, jax.random.PRNGKey(0),
                             V=V, D=D, adversary=adversary)
    key, mask_key = jax.random.split(key0)
    rank = byz_rank(mask_key, cfg.m)
    zero = jnp.zeros((problem.d,))
    g0 = problem.grad(zero)
    rng = key
    n_alive = []
    for _ in range(T):
        rng, gkey, akey = jax.random.split(rng, 3)
        wk = jax.random.split(gkey, cfg.m)
        noise = jax.vmap(lambda kk: problem.stoch_grad(kk, zero) - g0)(wk)
        state, metrics = ts(state, {"noise": noise[:, None, :]}, rank, akey)
        n_alive.append(int(metrics["n_alive"]))
    return state, jnp.asarray(n_alive)


class TestFlatVsPytreeParity:
    @pytest.mark.parametrize("backend,gopts", [
        ("dense", ()),
        ("fused", ()),
        ("dp_exact", (("auto_v", False),)),
    ])
    def test_trainer_reproduces_run_sgd(self, quad, backend, gopts):
        cfg = SolverConfig(m=8, T=25, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip",
                           guard_backend=backend, guard_opts=gopts)
        key0 = jax.random.PRNGKey(5)
        res = run_sgd(quad, cfg, key0)
        state, n_alive = _drive_trainer(quad, cfg, key0, cfg.T)
        np.testing.assert_array_equal(np.asarray(n_alive),
                                      np.asarray(res.n_alive))
        np.testing.assert_array_equal(np.asarray(state.prev_alive),
                                      np.asarray(res.final_alive))
        # x_T through 25 filtered+projected steps — ξ parity to 1e-5
        np.testing.assert_allclose(np.asarray(state.params["x"]),
                                   np.asarray(res.x_final),
                                   rtol=1e-5, atol=1e-6)
        # last ξ round-trips through the harness padding
        assert state.prev_xi.shape[0] % 128 == 0
        np.testing.assert_array_equal(np.asarray(state.prev_xi[quad.d:]), 0.0)

    def test_trainer_reproduces_run_sgd_with_adversary(self, quad):
        """Scenario path: same parity through the adversary runtime (static
        sign_flip scenario ≡ the zoo attack, per the PR-2 equivalence)."""
        from repro.scenarios import ScenarioAdversary, scenario_static

        cfg = SolverConfig(m=8, T=20, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip",
                           guard_backend="dense")
        adv = ScenarioAdversary(scenario=scenario_static("sign_flip"),
                                alpha=jnp.float32(cfg.alpha))
        key0 = jax.random.PRNGKey(9)
        res = run_sgd(quad, cfg, key0, adversary=adv)
        state, n_alive = _drive_trainer(quad, cfg, key0, cfg.T, adversary=adv)
        np.testing.assert_array_equal(np.asarray(n_alive),
                                      np.asarray(res.n_alive))
        np.testing.assert_allclose(np.asarray(state.params["x"]),
                                   np.asarray(res.x_final),
                                   rtol=1e-5, atol=1e-6)


class TestUnifiedBaselines:
    def test_trainer_mean_matches_flat_mean(self, quad):
        cfg = SolverConfig(m=6, T=4, eta=0.05, alpha=0.0,
                           aggregator="mean", attack="none")
        model = VectorModel(quad)
        opt = sgd(cfg.eta)
        ts = jax.jit(build_train_step(model, opt, cfg, V=quad.V, D=quad.D))
        state = init_train_state(model, opt, cfg, jax.random.PRNGKey(0),
                                 V=quad.V, D=quad.D)
        noise = jax.random.normal(jax.random.PRNGKey(1), (cfg.m, quad.d))
        x0 = state.params["x"]
        state, _ = ts(state, {"noise": noise[:, None, :]},
                      jnp.full((cfg.m,), cfg.m, jnp.int32),
                      jax.random.PRNGKey(2))
        xi = jnp.mean(quad.grad(x0)[None, :] + noise, axis=0)
        np.testing.assert_allclose(np.asarray(state.params["x"]),
                                   np.asarray(x0 - cfg.eta * xi),
                                   rtol=1e-5, atol=1e-6)

    def test_trainer_krum_f_uses_ceil_convention(self):
        """The old trainer hard-coded n_byzantine = W//4; the unified path
        sizes Krum's f by ⌈αm⌉ (shared helper) — at m=10, α=0.25 that is
        3, not 2."""
        cfg = SolverConfig(m=10, T=5, eta=0.05, alpha=0.25,
                           aggregator="krum", attack="sign_flip")
        assert cfg.krum_f_default == ceil_byzantine_count(0.25, 10) == 3

    def test_dense_backend_requires_v(self, quad):
        cfg = SolverConfig(m=4, T=4, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", guard_backend="dense")
        model = VectorModel(quad)
        with pytest.raises(ValueError, match="auto-V"):
            build_train_step(model, sgd(0.05), cfg, V=0.0)


class TestScenarioInTrainer:
    def test_churn_rotates_byzantine_identity(self, quad):
        """Per-step masks from the scenario schedule: with churn, the
        ever-Byzantine set must grow past the instantaneous count."""
        from repro.scenarios import ScenarioAdversary, scenario_churn

        cfg = SolverConfig(m=8, T=12, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip",
                           guard_backend="dp_exact",
                           guard_opts=(("auto_v", False),))
        adv = ScenarioAdversary(
            scenario=scenario_churn("sign_flip", period=4, stride=2),
            alpha=jnp.float32(cfg.alpha),
        )
        state, _ = _drive_trainer(quad, cfg, jax.random.PRNGKey(3), cfg.T,
                                  adversary=adv)
        assert int(state.ever_byz.sum()) > cfg.n_byzantine

    def test_adaptive_adversary_updates_state(self, quad):
        from repro.scenarios import ScenarioAdversary, scenario_adaptive

        cfg = SolverConfig(m=8, T=10, eta=0.05, alpha=0.25,
                           aggregator="mean", attack="inner_product")
        adv = ScenarioAdversary(
            scenario=scenario_adaptive("inner_product", adapt_rate=0.5),
            alpha=jnp.float32(cfg.alpha),
        )
        state, _ = _drive_trainer(quad, cfg, jax.random.PRNGKey(4), cfg.T,
                                  adversary=adv)
        # against plain mean the magnitude search must have escalated
        assert float(state.adv.adapt_scale) != 1.0


class TestCheckpointResume:
    def test_resume_equals_uninterrupted(self, quad, tmp_path):
        """Full-TrainState checkpoint: save at step 6 of 12, restore into a
        fresh template, continue — bit-identical to the uninterrupted run
        (params AND optimizer moments AND guard martingales AND feedback)."""
        from repro.checkpoint import restore_checkpoint, save_checkpoint

        cfg = SolverConfig(m=8, T=12, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip",
                           guard_backend="dp_exact")
        model = VectorModel(quad)
        opt = projected_sgd(cfg.eta, {"x": quad.x1}, quad.D)
        ts = jax.jit(build_train_step(model, opt, cfg, V=quad.V, D=quad.D))

        def batch_and_key(i):
            kk = jax.random.fold_in(jax.random.PRNGKey(7), i)
            noise = jax.random.normal(kk, (cfg.m, quad.d))
            return {"noise": noise[:, None, :]}, jax.random.fold_in(kk, 1)

        rank = jnp.arange(cfg.m, dtype=jnp.int32)

        def run(state, lo, hi):
            for i in range(lo, hi):
                b, k = batch_and_key(i)
                state, _ = ts(state, b, rank, k)
            return state

        s_full = run(init_train_state(model, opt, cfg, jax.random.PRNGKey(0),
                                      V=quad.V, D=quad.D), 0, 12)
        s_half = run(init_train_state(model, opt, cfg, jax.random.PRNGKey(0),
                                      V=quad.V, D=quad.D), 0, 6)
        save_checkpoint(str(tmp_path), 6, s_half)
        template = init_train_state(model, opt, cfg, jax.random.PRNGKey(0),
                                    V=quad.V, D=quad.D)
        restored, step = restore_checkpoint(str(tmp_path), template)
        assert step == 6 and int(restored.step) == 6
        s_resumed = run(restored, 6, 12)
        for l1, l2 in zip(jax.tree_util.tree_leaves(s_full),
                          jax.tree_util.tree_leaves(s_resumed)):
            np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
