"""Guard flight recorder (DESIGN.md §12): the off-state contract and the
in-trace forensics.

The acceptance-critical property is that telemetry is *free when off*:
``telemetry=None`` and ``TelemetryConfig(enabled=False)`` must produce the
same jaxpr (no telemetry ops traced at all) and bit-identical results, and
arming the recorder must not change a single filter decision — the frames
are a read-only tap on the guard's own diagnostics.  The rest pins the
recorder's data path: the packed single-lane ring buffer, the
first-filter/survival summaries, the campaign timeline export, the
trainer's uniform metrics schema, and the JSONL/chrome-trace writers.
"""
import importlib.util
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_quadratic_problem
from repro.obs import (
    EventLog,
    FRAME_SCHEMA,
    PER_WORKER_KEYS,
    SCALAR_KEYS,
    Telemetry,
    TelemetryConfig,
    empty_frame,
    provenance_meta,
    ring_init,
    ring_push,
    ring_read,
    spans_by_name,
    telemetry_on,
    trace_span,
    write_chrome_trace,
)
from repro.scenarios import (
    expand_grid,
    run_campaign,
    scenario_adaptive,
    scenario_static,
)
from repro.scenarios.report import (
    _survival_curve,
    campaign_trace_events,
    filter_timelines,
    summarize_campaign,
)


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(d=8, sigma=1.0, L=8.0, V=1.0, seed=3)


def _cfg(**kw):
    base = dict(m=8, T=30, eta=0.05, alpha=0.25,
                aggregator="byzantine_sgd", attack="sign_flip")
    base.update(kw)
    return SolverConfig(**base)


# ---------------------------------------------------------------------------
# ring buffer
# ---------------------------------------------------------------------------

class TestRing:
    def _frame(self, m, step):
        frame = empty_frame(m)
        frame["alive"] = jnp.arange(m, dtype=jnp.float32)
        frame["step"] = jnp.asarray(float(step), jnp.float32)
        frame["n_alive"] = jnp.asarray(m - step, jnp.float32)
        return frame

    def test_packed_width(self):
        ring = ring_init(m=5, ring_size=4)
        assert ring.lanes.shape == (4, len(PER_WORKER_KEYS) * 5
                                    + len(SCALAR_KEYS))
        assert ring.m == 5

    def test_push_read_round_trip(self):
        ring = ring_init(m=3, ring_size=8)
        for s in range(1, 4):
            ring = ring_push(ring, self._frame(3, s))
        frames = ring_read(ring)
        assert len(frames) == 3
        assert set(frames[0]) == set(FRAME_SCHEMA)
        assert [float(f["step"]) for f in frames] == [1.0, 2.0, 3.0]
        np.testing.assert_array_equal(frames[0]["alive"], [0.0, 1.0, 2.0])
        assert np.isnan(frames[0]["thr_a"])   # NaN sentinel preserved

    def test_wrap_keeps_last_ring_size_in_order(self):
        ring = ring_init(m=2, ring_size=4)
        for s in range(1, 11):                 # 10 pushes into 4 slots
            ring = ring_push(ring, self._frame(2, s))
        frames = ring_read(ring)
        assert int(ring.head) == 10
        assert [float(f["step"]) for f in frames] == [7.0, 8.0, 9.0, 10.0]

    def test_config_gate(self):
        assert not telemetry_on(None)
        assert not telemetry_on(TelemetryConfig(enabled=False))
        assert telemetry_on(TelemetryConfig())


# ---------------------------------------------------------------------------
# off-state: trace-identical and bit-identical (acceptance criterion)
# ---------------------------------------------------------------------------

class TestOffStateEquivalence:
    @pytest.mark.parametrize("backend", ["dense", "fused"])
    def test_disabled_jaxpr_identical_to_none(self, quad, backend):
        cfg = _cfg(guard_backend=backend)
        key = jax.random.PRNGKey(0)
        j_none = jax.make_jaxpr(
            lambda k: run_sgd(quad, cfg, k, telemetry=None))(key)
        j_off = jax.make_jaxpr(
            lambda k: run_sgd(quad, cfg, k,
                              telemetry=TelemetryConfig(enabled=False)))(key)
        assert str(j_none) == str(j_off)

    def test_disabled_results_bit_identical(self, quad):
        cfg = _cfg()
        key = jax.random.PRNGKey(7)
        a = run_sgd(quad, cfg, key)
        b = run_sgd(quad, cfg, key, telemetry=TelemetryConfig(enabled=False))
        assert a.telemetry is None and b.telemetry is None
        np.testing.assert_array_equal(np.asarray(a.x_final),
                                      np.asarray(b.x_final))
        np.testing.assert_array_equal(np.asarray(a.gaps), np.asarray(b.gaps))

    @pytest.mark.parametrize("backend",
                             ["dense", "fused", "dp_exact", "dp_sketch"])
    def test_enabled_leaves_filter_decisions_unchanged(self, quad, backend):
        cfg = _cfg(guard_backend=backend,
                   guard_opts=(("sketch_dim", 8),))
        key = jax.random.PRNGKey(5)
        off = run_sgd(quad, cfg, key)
        on = run_sgd(quad, cfg, key, telemetry=TelemetryConfig(ring_size=16))
        np.testing.assert_array_equal(np.asarray(off.n_alive),
                                      np.asarray(on.n_alive))
        np.testing.assert_array_equal(np.asarray(off.final_alive),
                                      np.asarray(on.final_alive))
        np.testing.assert_array_equal(np.asarray(off.x_final),
                                      np.asarray(on.x_final))

    def test_enabled_baseline_aggregator_unchanged(self, quad):
        cfg = _cfg(aggregator="krum")
        key = jax.random.PRNGKey(5)
        off = run_sgd(quad, cfg, key)
        on = run_sgd(quad, cfg, key, telemetry=TelemetryConfig())
        np.testing.assert_array_equal(np.asarray(off.x_final),
                                      np.asarray(on.x_final))


# ---------------------------------------------------------------------------
# what the armed recorder captures
# ---------------------------------------------------------------------------

class TestRecorder:
    def test_guard_run_frames_and_summaries(self, quad):
        cfg = _cfg()
        res = run_sgd(quad, cfg, jax.random.PRNGKey(2),
                      telemetry=TelemetryConfig(ring_size=16))
        tel = res.telemetry
        assert isinstance(tel, Telemetry)
        assert tel.byz_alive.shape == (cfg.T,)
        frames = ring_read(tel.ring)
        assert len(frames) == 16                       # T=30 wrapped the ring
        assert float(frames[-1]["step"]) == cfg.T
        last = frames[-1]
        assert np.isfinite(last["thr_a"]) and np.isfinite(last["thr_b"])
        assert np.isfinite(last["dev_a"]).all()
        assert float(last["n_alive"]) == float(res.n_alive[-1])
        np.testing.assert_array_equal(
            last["alive"], np.asarray(res.final_alive, np.float32))
        assert np.isfinite(last["xi_norm"])

        # sign-flip at α=.25 gets every byz worker filtered; ffs marks the
        # byz workers with a positive step and the good workers with -1
        ffs = np.asarray(tel.first_filter_step)
        byz = np.asarray(res.byz_mask)
        assert (ffs[byz] > 0).all()
        assert (ffs[~byz] == -1).all()
        assert int(tel.byz_alive[-1]) == 0

    def test_baseline_frames_nan_thresholds(self, quad):
        res = run_sgd(quad, _cfg(aggregator="krum"), jax.random.PRNGKey(2),
                      telemetry=TelemetryConfig(ring_size=8))
        last = ring_read(res.telemetry.ring)[-1]
        assert np.isnan(last["thr_a"]) and np.isnan(last["dev_a"]).all()
        assert np.isfinite(last["n_alive"])

    def test_dp_backend_reports_v_est(self, quad):
        res = run_sgd(quad, _cfg(guard_backend="dp_exact"),
                      jax.random.PRNGKey(2),
                      telemetry=TelemetryConfig(ring_size=8))
        assert np.isfinite(float(ring_read(res.telemetry.ring)[-1]["v_est"]))


# ---------------------------------------------------------------------------
# campaign plumbing + report sections
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_campaign(quad):
    grid = expand_grid(
        [("static_sign_flip", scenario_static("sign_flip")),
         ("adaptive_inner_product",
          scenario_adaptive("inner_product", adapt_rate=0.5))],
        alphas=[0.25], seeds=[0, 1],
    )
    return run_campaign(quad, _cfg(T=40), grid, ["byzantine_sgd"],
                        telemetry=TelemetryConfig(ring_size=8)), grid


class TestCampaign:
    def test_runstats_telemetry_none_when_off(self, quad):
        grid = expand_grid([("s", scenario_static("sign_flip"))],
                           alphas=[0.25], seeds=[0])
        result = run_campaign(quad, _cfg(), grid, ["byzantine_sgd"])
        (stats,) = result.stats.values()
        assert stats.telemetry is None

    def test_runstats_telemetry_block(self, traced_campaign):
        result, grid = traced_campaign
        (stats,) = result.stats.values()
        tel = stats.telemetry
        assert set(tel) >= {"ring", "first_filter_step", "byz_alive",
                            "byz_mask"}
        n = grid.n_runs
        assert tel["first_filter_step"].shape == (n, 8)
        assert tel["byz_alive"].shape == (n, 40)
        assert tel["ring"].lanes.shape[0] == n      # vmapped ring

    def test_filter_timelines_rows(self, traced_campaign):
        result, grid = traced_campaign
        rows = filter_timelines(result)
        assert len(rows) == 2                       # one per scenario×alpha
        row = {r["scenario"]: r for r in rows}["static_sign_flip"]
        assert row["n_seeds"] == 2
        assert row["n_byz_caught"] == row["n_byz_workers"] > 0
        assert row["first_filter_byz_med"] > 0
        curve = row["byz_survival"]
        assert curve[0][0] == 1 and curve[-1][0] == 40
        assert curve[-1][1] == 0                    # all byz gone by T

    def test_summarize_campaign_attaches_timelines(self, traced_campaign,
                                                   quad):
        result, _ = traced_campaign
        record = summarize_campaign(result, quad, _cfg(T=40))
        assert "filter_timelines" in record

    def test_campaign_trace_events(self, traced_campaign):
        result, _ = traced_campaign
        log = EventLog(tool="test")
        n = campaign_trace_events(
            result, log,
            select=lambda e: e["scenario"] == "adaptive_inner_product")
        assert n == 2                               # 2 seeds selected
        kinds = {e["type"] for e in log.events}
        assert kinds == {"guard_step", "timeline"}
        steps = [e for e in log.events if e["type"] == "guard_step"]
        assert len(steps) == 2 * 8                  # ring_size per cell
        tl = next(e for e in log.events if e["type"] == "timeline")
        assert tl["byz_survival"][0][0] == 1

    def test_survival_curve_compression(self):
        series = np.array([4, 4, 4, 2, 2, 0, 0, 0])
        assert _survival_curve(series) == [[1, 4], [4, 2], [6, 0], [8, 0]]
        dense = np.arange(200, 0, -1)
        assert len(_survival_curve(dense, max_points=64)) <= 64


# ---------------------------------------------------------------------------
# event log + chrome trace + spans
# ---------------------------------------------------------------------------

class TestEventLog:
    def test_jsonl_round_trip(self, tmp_path):
        log = EventLog(tool="test", scenario="s")
        log.event("counter", name="serve/throughput", tokens_per_s=12.5)
        log.guard_step({"step": 1.0, "n_alive": jnp.asarray(8.0),
                        "dev_a": np.array([0.1, np.nan])}, run="r")
        log.add_meta(telemetry_overhead_frac=0.017)
        path = tmp_path / "t.jsonl"
        log.write_jsonl(str(path))
        meta, events = EventLog.read_jsonl(str(path))
        assert meta["tool"] == "test"
        assert meta["telemetry_overhead_frac"] == 0.017
        assert {"commit", "jax_version", "device_kind"} <= set(meta)
        assert len(events) == 2
        assert events[1]["dev_a"] == [0.1, None]    # NaN → null sentinel

    def test_chrome_trace_projection(self, tmp_path):
        log = EventLog(tool="test")
        with trace_span("train/chunk", log=log, lo=0, hi=4):
            pass
        log.guard_step({"step": 3.0, "n_alive": 7.0, "xi_norm": 0.5},
                       run="r")
        out = tmp_path / "t.json"
        log.write_chrome_trace(str(out))
        trace = json.loads(out.read_text())
        phases = {ev["ph"] for ev in trace["traceEvents"]}
        assert {"X", "C"} <= phases
        counter = next(ev for ev in trace["traceEvents"]
                       if ev["ph"] == "C" and "n_alive" in ev["name"])
        assert counter["ts"] == 3

    def test_trace_span_without_log(self):
        with trace_span("guard/filter"):
            x = jnp.ones(3).sum()
        assert float(x) == 3.0

    def test_spans_by_name(self):
        log = EventLog(tool="test")
        for _ in range(3):
            with trace_span("train/step", log=log):
                pass
        rec = spans_by_name(log.events)["train/step"]
        assert rec["count"] == 3
        assert rec["total_s"] >= 0.0

    def test_provenance_meta_keys(self):
        meta = provenance_meta()
        assert {"commit", "timestamp", "jax_version", "jaxlib_version",
                "backend", "device_kind", "n_devices"} <= set(meta)


# ---------------------------------------------------------------------------
# trainer: uniform metrics schema + tel/ channel
# ---------------------------------------------------------------------------

class TestTrainerMetrics:
    @pytest.fixture(scope="class")
    def lm(self):
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("internlm2-1.8b").reduced(max_d_model=32)
        return cfg, build_model(cfg)

    def _run_step(self, lm, scfg, telemetry=None):
        from repro.distributed.trainer import (
            build_train_step, init_train_state, rank_from_mask,
        )
        from repro.optim import adamw
        from repro.data.synthetic import SyntheticTokens, make_worker_batch
        cfg, model = lm
        rng = jax.random.PRNGKey(0)
        opt = adamw(1e-3)
        ts = jax.jit(build_train_step(model, opt, scfg, telemetry=telemetry))
        state = init_train_state(model, opt, scfg, rng)
        stream = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16)
        batch = make_worker_batch(stream, scfg.m, 1, jnp.asarray(0))
        rank = rank_from_mask(jnp.arange(scfg.m) < scfg.n_byzantine)
        return ts(state, batch, rank, jax.random.fold_in(rng, 0))

    @pytest.mark.parametrize("agg,backend", [
        ("byzantine_sgd", "dp_exact"), ("mean", "dense"),
    ])
    def test_v_est_key_uniform_across_aggregators(self, lm, agg, backend):
        scfg = SolverConfig(m=4, T=4, eta=1e-3, alpha=0.25, aggregator=agg,
                            attack="sign_flip", guard_backend=backend)
        _, metrics = self._run_step(lm, scfg)
        assert "v_est" in metrics
        v = float(metrics["v_est"])
        if agg == "byzantine_sgd":
            assert np.isfinite(v)                   # dp auto-V estimate
        else:
            assert np.isnan(v)                      # NaN sentinel, not absent

    def test_tel_metrics_present_only_when_armed(self, lm):
        scfg = SolverConfig(m=4, T=4, eta=1e-3, alpha=0.25,
                            aggregator="byzantine_sgd", attack="sign_flip",
                            guard_backend="dp_exact")
        _, off = self._run_step(lm, scfg)
        assert not any(k.startswith("tel/") for k in off)
        _, on = self._run_step(lm, scfg, telemetry=TelemetryConfig())
        for key in FRAME_SCHEMA:
            assert f"tel/{key}" in on
        assert on["tel/alive"].shape == (4,)
        assert float(on["tel/step"]) == 1.0
        # armed telemetry must not perturb the training metrics
        np.testing.assert_array_equal(np.asarray(off["loss_good_workers"]),
                                      np.asarray(on["loss_good_workers"]))
        np.testing.assert_array_equal(np.asarray(off["n_alive"]),
                                      np.asarray(on["n_alive"]))


# ---------------------------------------------------------------------------
# renderer + benchmark provenance
# ---------------------------------------------------------------------------

def _load_render_trace():
    path = (pathlib.Path(__file__).resolve().parents[1]
            / "scripts" / "render_trace.py")
    spec = importlib.util.spec_from_file_location("render_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestRenderer:
    def test_render_synthetic_trace(self):
        rt = _load_render_trace()
        log = EventLog(tool="test", telemetry_overhead_frac=0.02)
        with trace_span("guard/filter", log=log):
            pass
        log.event("roofline", backend="dense", m=8, d=8,
                  measured_step_us=10.0, modeled_step_us=2.0,
                  measured_over_model=5.0)
        log.guard_step({"step": 2.0, "n_alive": 6.0, "xi_norm": 0.4,
                        "thr_a": 9.0, "thr_b": 4.0,
                        "dev_a": [0.1, 8.0], "dist_b": [0.2, 5.0],
                        "alive": [1.0, 0.0]}, run="s/a0.25/agg/s0")
        log.event("timeline", run="s/a0.25/agg/s0",
                  first_filter_step=[-1, 2], byz_mask=[False, True],
                  byz_survival=[[1, 1], [2, 0], [4, 0]])
        text = rt.render(log.meta, log.events)
        assert "telemetry_overhead_frac" in text
        assert "first-filter (byz): [2]" in text
        assert "guard/filter" in text
        assert "5.0x" in text

    def test_sparkline_and_survival_expansion(self):
        rt = _load_render_trace()
        vals = rt._survival_values(
            {"byz_survival": [[1, 2], [3, 0], [5, 0]]}, [])
        assert vals == [2.0, 2.0, 0.0, 0.0, 0.0]
        assert len(rt._sparkline([0.0, 1.0, 2.0], width=48)) == 3
        assert rt._sparkline([2.0, 0.0])[0] == "█"


class TestBenchProvenance:
    def test_write_json_injects_meta(self, tmp_path):
        import benchmarks.common as common
        path = tmp_path / "BENCH_x.json"
        common.write_json(str(path), {"result_us": 1.0})
        rec = json.loads(path.read_text())
        assert rec["result_us"] == 1.0
        assert {"commit", "jax_version", "device_kind"} <= set(rec["meta"])

    def test_write_json_keeps_caller_meta(self, tmp_path):
        import benchmarks.common as common
        path = tmp_path / "BENCH_y.json"
        common.write_json(str(path), {"meta": {"custom": 1}})
        assert json.loads(path.read_text())["meta"] == {"custom": 1}
