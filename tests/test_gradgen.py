"""On-device gradient generation (DESIGN.md §14).

Four layers of pinning, innermost out:

1. the counter-based PRNG: our pure-``jnp`` threefry-2x32 against the
   Random123 known-answer vectors AND jax's own ``threefry_2x32`` — the
   key-chain contract that makes in-kernel strips reproduce the host
   sampler;
2. the differential oracle: the generating Pallas kernels against the
   materialize-then-sweep host references in interpret mode — the
   regenerated *strips* are bit-exact (same threefry body, same
   expression chain); the Gram/A/B reductions follow the fused-guard
   suite's tolerance convention (block-wise accumulation order differs
   from the oracle's single reduction by ~1 ulp);
3. the host sampler: generated honest strips against
   ``Problem.stoch_grad``'s own expression chain;
4. end-to-end: ``run_sgd(generate='kernel')`` against the materializing
   fused path across the scenario zoo — bit-exact for every non-adaptive
   dynamic; the feedback-adaptive and heterogeneous runs carry a ~1-ulp
   documented tolerance (the adversary's byz-row feedback and the rank-1
   skew term fuse differently inside the two traces).

Plus the off-state guarantee: ``generate='off'`` (the default) lowers to
a trace in which the GenSpec contributes nothing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import (
    heterogenize_generated,
    make_generated_problem,
)
from repro.kernels import gradgen, ops, ref
from repro.kernels.fused_guard import fused_guard_gen_pallas, gen_xi_pallas
from repro.scenarios import spec
from repro.scenarios.adversary import ScenarioAdversary


# ---------------------------------------------------------------------------
# layer 1 — the PRNG itself
# ---------------------------------------------------------------------------

# Random123 v1.09 known-answer vectors for threefry2x32, 20 rounds:
# (ctr0, ctr1, key0, key1) -> (out0, out1)
_KAT = [
    ((0x00000000, 0x00000000, 0x00000000, 0x00000000),
     (0x6B200159, 0x99BA4EFE)),
    ((0xFFFFFFFF, 0xFFFFFFFF, 0xFFFFFFFF, 0xFFFFFFFF),
     (0x1CB996FC, 0xBB002BE7)),
    ((0x243F6A88, 0x85A308D3, 0x13198A2E, 0x03707344),
     (0xC4923A9C, 0x483DF7A0)),
]


@pytest.mark.parametrize("inputs,want", _KAT)
def test_threefry_random123_kat(inputs, want):
    c0, c1, k0, k1 = inputs
    x0, x1 = gradgen.threefry2x32(k0, k1, c0, c1)
    assert (int(x0), int(x1)) == want


def test_threefry_matches_jax_prng():
    """Same bits as jax's own threefry-2x32 — the host key chain
    (jax.random.split → key data) feeds our counter stream unchanged."""
    from jax._src import prng as jax_prng

    key = jnp.asarray([0xDEADBEEF, 0x12345678], jnp.uint32)
    n = 64
    counts = jnp.arange(n, dtype=jnp.uint32)
    want = jax_prng.threefry_2x32(key, counts)
    x0, x1 = gradgen.threefry2x32(key[0], key[1],
                                  counts[: n // 2], counts[n // 2:])
    got = jnp.concatenate([x0, x1])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_centered_uniform_open_interval():
    bits = jnp.asarray([0, 1, 0x7FFFFFFF, 0x80000000, 0xFFFFFFFF],
                       jnp.uint32)
    u = np.asarray(gradgen.centered_uniform(bits))
    assert np.all(u > -1.0) and np.all(u < 1.0)
    # symmetric lattice: bitwise-complement bits mirror around 0
    comp = np.asarray(gradgen.centered_uniform(~bits))
    np.testing.assert_allclose(u, -comp, atol=2 ** -22)


# ---------------------------------------------------------------------------
# shared builders
# ---------------------------------------------------------------------------

def _rel_close(got, want, tol=1e-5):
    """Same convention as tests/test_fused_guard.py: ‖got − want‖ ≤
    tol·‖want‖ (+tol absolute for near-zero targets)."""
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    err = np.linalg.norm(got - want)
    assert err <= tol * np.linalg.norm(want) + tol, (err, np.linalg.norm(want))

def _gen_inputs(m, d, *, skew=False, seed=0):
    """A concrete (problem-derived) input set for the generating kernels,
    with an ALIE coalition on the first quarter of the fleet."""
    from repro.core.attacks import alie_z_max

    prob = make_generated_problem(d=d, sigma=1.0, L=8.0, V=1.0, seed=seed)
    if skew:
        prob = heterogenize_generated(prob, m=m, skew_max=0.4, seed=seed + 1)
    g = prob.gen
    keys = gradgen.key_bits(jax.random.split(jax.random.PRNGKey(seed + 7), m))
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 9), (d,),
                                jnp.float32)
    mask = jnp.arange(m) < max(m // 4, 1)
    slot = jnp.where(mask, 1, 0).astype(jnp.int32)
    tg = gradgen.mean_grad(g.h, x, g.x_star)
    params = (
        jnp.zeros((gradgen.GEN_NPARAMS,), jnp.float32)
        .at[gradgen.P_ID_A].set(4.0)
        .at[gradgen.P_Z_A].set(alie_z_max(m, jnp.sum(mask)))
        .at[gradgen.P_TGNRM].set(jnp.maximum(jnp.linalg.norm(tg), 1e-12))
        .at[gradgen.P_NSCALE].set(g.noise_scale)
    )
    skewsign = (0.3 * g.het_sign if skew
                else jnp.zeros((m,), jnp.float32))
    return prob, x, keys, skewsign, slot, params, mask


# ---------------------------------------------------------------------------
# layer 2 — generating kernels vs the jitted host oracle (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,d", [(8, 64), (16, 555), (16, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gen_sweep_kernel_matches_jitted_oracle(m, d, dtype):
    prob, x, keys, skewsign, slot, params, _ = _gen_inputs(m, d)
    g = prob.gen
    key = jax.random.PRNGKey(m * 1000 + d)
    B = (3.0 * jax.random.normal(key, (m, d), jnp.float32)).astype(dtype)
    delta = jax.random.normal(jax.random.PRNGKey(1), (d,),
                              jnp.float32).astype(dtype)
    got = fused_guard_gen_pallas(
        B, delta, x, g.h, g.x_star, g.het_dir, keys, skewsign, slot,
        params, d_block=256, interpret=True)
    want = jax.jit(ref.fused_guard_gen_ref)(
        B, delta, x, g.h, g.x_star, g.het_dir, keys, skewsign, slot, params)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-5
    for a, b in zip(got, want):
        _rel_close(a, b, tol)
    # the regenerated strip itself (B_new − B) is exact: same threefry
    # body, same expression chain, elementwise update
    np.testing.assert_array_equal(np.asarray(got[-1]), np.asarray(want[-1]))


@pytest.mark.parametrize("m,d", [(8, 64), (16, 555)])
@pytest.mark.parametrize("stats_dtype", ["float32", "bfloat16"])
def test_gen_xi_kernel_matches_jitted_oracle(m, d, stats_dtype):
    prob, x, keys, skewsign, slot, params, mask = _gen_inputs(m, d)
    g = prob.gen
    w_xi = jnp.where(mask, 0.0, 1.0 / m).astype(jnp.float32)
    w_byz = mask.astype(jnp.float32)
    got = gen_xi_pallas(
        w_xi, w_byz, x, g.h, g.x_star, g.het_dir, keys, skewsign, slot,
        params, d_block=256, interpret=True, stats_dtype=stats_dtype)
    want = jax.jit(ref.gen_xi_ref, static_argnames="stats_dtype")(
        w_xi, w_byz, x, g.h, g.x_star, g.het_dir, keys, skewsign, slot,
        params, stats_dtype=stats_dtype)
    tol = 1e-2 if stats_dtype == "bfloat16" else 1e-5
    for a, b in zip(got, want):
        _rel_close(a, b, tol)


def test_gen_sweep_kernel_skewed_strip():
    """Rank-1 heterogeneity folds in bit-exactly (± signs are exact)."""
    m, d = 16, 512
    prob, x, keys, skewsign, slot, params, _ = _gen_inputs(m, d, skew=True)
    g = prob.gen
    B = jnp.zeros((m, d), jnp.float32)
    delta = jnp.zeros((d,), jnp.float32)
    got = fused_guard_gen_pallas(
        B, delta, x, g.h, g.x_star, g.het_dir, keys, skewsign, slot,
        params, d_block=128, interpret=True)
    want = jax.jit(ref.fused_guard_gen_ref)(
        B, delta, x, g.h, g.x_star, g.het_dir, keys, skewsign, slot, params)
    # zero B, zero delta: B_new IS the generated skewed strip — exact
    np.testing.assert_array_equal(np.asarray(got[-1]), np.asarray(want[-1]))
    for a, b in zip(got, want):
        _rel_close(a, b, 1e-5)


def test_ops_dispatch_and_oracle_registry():
    assert "fused_guard_gen" in ops.ORACLES
    assert "gen_xi" in ops.ORACLES


# ---------------------------------------------------------------------------
# layer 3 — generated honest rows ARE the host sampler
# ---------------------------------------------------------------------------

def test_honest_rows_match_host_stoch_grad():
    m, d = 16, 777
    prob = make_generated_problem(d=d, sigma=1.0, L=8.0, V=1.0, seed=3)
    g = prob.gen
    wkeys = jax.random.split(jax.random.PRNGKey(11), m)
    x = 0.2 * jax.random.normal(jax.random.PRNGKey(13), (d,), jnp.float32)

    host = jax.jit(lambda x: jax.vmap(
        lambda k: prob.stoch_grad(k, x))(wkeys))(x)
    gen = jax.jit(ref.gen_rows_ref)(
        x, g.h, g.x_star, g.het_dir, gradgen.key_bits(wkeys),
        jnp.zeros((m,), jnp.float32), jnp.zeros((m,), jnp.int32),
        jnp.zeros((gradgen.GEN_NPARAMS,), jnp.float32)
        .at[gradgen.P_TGNRM].set(1.0)
        .at[gradgen.P_NSCALE].set(g.noise_scale))
    np.testing.assert_array_equal(np.asarray(host), np.asarray(gen))


def test_het_rows_match_host_het_grad():
    m, d = 16, 333
    prob = heterogenize_generated(
        make_generated_problem(d=d, sigma=1.0, L=8.0, V=1.0, seed=5),
        m=m, skew_max=0.5, seed=6)
    g = prob.gen
    profile = spec.profile_linear_skew(m, 0.5)
    wkeys = jax.random.split(jax.random.PRNGKey(17), m)
    x = 0.2 * jax.random.normal(jax.random.PRNGKey(19), (d,), jnp.float32)

    host = jax.jit(lambda x: jax.vmap(
        lambda k, s, w: prob.het_grad(k, x, s, w))(
            wkeys, profile.skew, jnp.arange(m)))(x)
    gen = jax.jit(ref.gen_rows_ref)(
        x, g.h, g.x_star, g.het_dir, gradgen.key_bits(wkeys),
        profile.skew * g.het_sign, jnp.zeros((m,), jnp.int32),
        jnp.zeros((gradgen.GEN_NPARAMS,), jnp.float32)
        .at[gradgen.P_TGNRM].set(1.0)
        .at[gradgen.P_NSCALE].set(g.noise_scale))
    np.testing.assert_array_equal(np.asarray(host), np.asarray(gen))


# ---------------------------------------------------------------------------
# layer 4 — end-to-end: generate='kernel' vs the materializing fused path
# ---------------------------------------------------------------------------

# (name, scenario, exact): ``exact`` marks dynamics whose two traces are
# bit-identical.  ALIE-family attacks consume honest mean/std statistics
# that the gen path reduces in-kernel per strip while the materializing
# path reduces host-side over full rows — a different (but equally valid)
# reduction order, so those runs agree to ~1 ulp rather than bit-for-bit.
_E2E_SCENARIOS = [
    ("static_sign_flip", spec.scenario_static("sign_flip"), True),
    ("static_alie", spec.scenario_static("alie"), False),
    ("static_alie_update", spec.scenario_static("alie_update"), False),
    ("static_constant_drift", spec.scenario_static("constant_drift"), True),
    ("static_hidden_shift", spec.scenario_static("hidden_shift"), True),
    ("static_inner_product", spec.scenario_static("inner_product"), True),
    ("retreat_on_filter", spec.scenario_static("retreat_on_filter"), True),
    ("coalition", spec.scenario_coalition("sign_flip", "alie", 0.5), False),
    ("churn", spec.scenario_churn("sign_flip", period=20, stride=2), True),
    ("late_join", spec.scenario_late_join("alie", 15), False),
    ("lie_low", spec.scenario_lie_low_then_strike("inner_product", 20), True),
]


def _run_pair(problem, scn, *, profile=None, T=40, alpha=0.25, seed=3):
    adv = ScenarioAdversary(scn, jnp.asarray(alpha, jnp.float32), profile)
    out = {}
    for gen in ("off", "kernel"):
        cfg = SolverConfig(m=16, alpha=alpha, T=T, eta=0.05,
                           aggregator="byzantine_sgd",
                           guard_backend="fused", generate=gen)
        out[gen] = run_sgd(problem, cfg, jax.random.PRNGKey(seed),
                           adversary=adv)
    return out["off"], out["kernel"]


@pytest.fixture(scope="module")
def genprob():
    return make_generated_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)


@pytest.mark.parametrize("name,scn,exact", _E2E_SCENARIOS,
                         ids=[n for n, _, _ in _E2E_SCENARIOS])
def test_e2e_gen_matches_materializing(genprob, name, scn, exact):
    a, b = _run_pair(genprob, scn)
    # filter decisions are identical in every scenario, exact or not
    np.testing.assert_array_equal(np.asarray(a.n_alive),
                                  np.asarray(b.n_alive))
    np.testing.assert_array_equal(np.asarray(a.byz_mask),
                                  np.asarray(b.byz_mask))
    if exact:
        np.testing.assert_array_equal(np.asarray(a.gaps),
                                      np.asarray(b.gaps))
        np.testing.assert_array_equal(np.asarray(a.x_final),
                                      np.asarray(b.x_final))
    else:
        np.testing.assert_allclose(np.asarray(a.gaps), np.asarray(b.gaps),
                                   rtol=0, atol=1e-6)
        np.testing.assert_allclose(np.asarray(a.x_final),
                                   np.asarray(b.x_final),
                                   rtol=0, atol=1e-6)


def test_e2e_adaptive_documented_tolerance(genprob):
    """Feedback-adaptive magnitude: the adversary's byz-row feedback is
    computed in-kernel on the gen path and fuses differently from the
    host reduction — filter decisions stay identical; iterates agree to
    ~1 ulp."""
    a, b = _run_pair(genprob, spec.scenario_adaptive("inner_product", 0.5),
                     T=60)
    np.testing.assert_array_equal(np.asarray(a.n_alive),
                                  np.asarray(b.n_alive))
    np.testing.assert_array_equal(np.asarray(a.byz_mask),
                                  np.asarray(b.byz_mask))
    np.testing.assert_allclose(np.asarray(a.gaps), np.asarray(b.gaps),
                               rtol=0, atol=1e-6)


def test_e2e_heterogeneous_documented_tolerance():
    prob = heterogenize_generated(
        make_generated_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0),
        m=16, skew_max=0.5, seed=1)
    profile = spec.profile_linear_skew(16, 0.5)
    a, b = _run_pair(prob, spec.scenario_static("alie"), profile=profile,
                     T=60)
    np.testing.assert_array_equal(np.asarray(a.n_alive),
                                  np.asarray(b.n_alive))
    np.testing.assert_allclose(np.asarray(a.gaps), np.asarray(b.gaps),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a.x_final),
                               np.asarray(b.x_final), rtol=0, atol=1e-6)


def test_e2e_telemetry_armed_matches(genprob):
    """The gen path's guard frames ride the same flight-recorder schema;
    arming telemetry must not change decisions on either path."""
    from repro.obs import TelemetryConfig

    scn = spec.scenario_static("alie")
    adv = ScenarioAdversary(scn, jnp.asarray(0.25, jnp.float32), None)
    tel = TelemetryConfig(ring_size=16)
    cfg = SolverConfig(m=16, alpha=0.25, T=40, eta=0.05,
                       aggregator="byzantine_sgd", guard_backend="fused",
                       generate="kernel")
    off = run_sgd(genprob, cfg, jax.random.PRNGKey(3), adversary=adv)
    on = run_sgd(genprob, cfg, jax.random.PRNGKey(3), adversary=adv,
                 telemetry=tel)
    assert on.telemetry is not None
    np.testing.assert_array_equal(np.asarray(off.n_alive),
                                  np.asarray(on.n_alive))
    np.testing.assert_array_equal(np.asarray(off.x_final),
                                  np.asarray(on.x_final))


# ---------------------------------------------------------------------------
# off-state: the GenSpec contributes nothing to the default trace
# ---------------------------------------------------------------------------

def test_off_state_trace_ignores_gen_spec(genprob):
    scn = spec.scenario_static("alie")
    adv = ScenarioAdversary(scn, jnp.asarray(0.25, jnp.float32), None)
    cfg = SolverConfig(m=16, alpha=0.25, T=10, eta=0.05,
                       aggregator="byzantine_sgd", guard_backend="fused")
    j_with = jax.make_jaxpr(
        lambda k: run_sgd(genprob, cfg, k, adversary=adv))(
            jax.random.PRNGKey(0))
    j_without = jax.make_jaxpr(
        lambda k: run_sgd(genprob._replace(gen=None), cfg, k,
                          adversary=adv))(jax.random.PRNGKey(0))
    assert str(j_with) == str(j_without)


def test_off_state_default_is_off():
    assert SolverConfig(m=8, T=10, eta=0.1).generate == "off"


# ---------------------------------------------------------------------------
# validation — every unsupported composition fails loudly
# ---------------------------------------------------------------------------

def _gen_cfg(**kw):
    base = dict(m=16, alpha=0.25, T=10, eta=0.05,
                aggregator="byzantine_sgd", guard_backend="fused",
                generate="kernel")
    base.update(kw)
    return SolverConfig(**base)


def _adv(attack="alie"):
    return ScenarioAdversary(spec.scenario_static(attack),
                             jnp.asarray(0.25, jnp.float32), None)


class TestValidation:
    def test_bad_generate_value(self, genprob):
        with pytest.raises(ValueError, match="generate must be"):
            run_sgd(genprob, _gen_cfg(generate="device"),
                    jax.random.PRNGKey(0), adversary=_adv())

    def test_needs_generatable_problem(self, genprob):
        with pytest.raises(ValueError, match="counter-generatable"):
            run_sgd(genprob._replace(gen=None), _gen_cfg(),
                    jax.random.PRNGKey(0), adversary=_adv())

    def test_needs_scenario_adversary(self, genprob):
        with pytest.raises(ValueError, match="scenario adversary"):
            run_sgd(genprob, _gen_cfg(), jax.random.PRNGKey(0))

    def test_needs_fused_guard(self, genprob):
        with pytest.raises(ValueError, match="guard_backend='fused'"):
            run_sgd(genprob, _gen_cfg(guard_backend="dense"),
                    jax.random.PRNGKey(0), adversary=_adv())

    def test_rejects_staleness(self, genprob):
        with pytest.raises(ValueError, match="staleness"):
            run_sgd(genprob, _gen_cfg(max_delay=2), jax.random.PRNGKey(0),
                    adversary=_adv())

    def test_rejects_unsupported_attack_id(self, genprob):
        with pytest.raises(ValueError, match="not in-kernel generatable"):
            run_sgd(genprob, _gen_cfg(), jax.random.PRNGKey(0),
                    adversary=_adv("random_gaussian"))

    def test_het_profile_needs_generated_skew(self, genprob):
        profile = spec.profile_linear_skew(16, 0.3)
        adv = ScenarioAdversary(spec.scenario_static("alie"),
                                jnp.asarray(0.25, jnp.float32), profile)
        bad = genprob._replace(
            het_grad=lambda key, x, skew, w: genprob.stoch_grad(key, x))
        with pytest.raises(ValueError, match="heterogenize_generated"):
            run_sgd(bad, _gen_cfg(), jax.random.PRNGKey(0), adversary=adv)


class TestHeterogenizeGenerated:
    def test_requires_gen_problem(self):
        from repro.data.problems import make_quadratic_problem

        quad = make_quadratic_problem(d=8, sigma=1.0, L=4.0, V=1.0, seed=0)
        with pytest.raises(ValueError):
            heterogenize_generated(quad, m=8, skew_max=0.5)

    def test_requires_even_m(self):
        prob = make_generated_problem(d=8)
        with pytest.raises(ValueError):
            heterogenize_generated(prob, m=7, skew_max=0.5)

    def test_requires_nonnegative_skew(self):
        prob = make_generated_problem(d=8)
        with pytest.raises(ValueError):
            heterogenize_generated(prob, m=8, skew_max=-0.1)

    def test_zero_sum_bias(self):
        prob = heterogenize_generated(make_generated_problem(d=8), m=8,
                                      skew_max=0.5, seed=2)
        # alternating ±1 signs: the fleet-sum of the bias is exactly zero,
        # so the global optimum is unchanged
        assert int(jnp.sum(prob.gen.het_sign)) == 0
        assert prob.V > 1.0  # inflated by the realized skew


def test_generated_problem_grad_consistency():
    prob = make_generated_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=4)
    x = jax.random.normal(jax.random.PRNGKey(2), (16,), jnp.float32)
    np.testing.assert_allclose(np.asarray(prob.grad(x)),
                               np.asarray(jax.grad(prob.f)(x)),
                               rtol=1e-5, atol=1e-6)
    assert float(prob.gen.noise_scale) == pytest.approx(
        1.0 / np.sqrt(16.0))
