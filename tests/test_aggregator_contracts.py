"""Property-based conformance contracts for every registered aggregator.

Each rule in :func:`repro.core.aggregators.aggregator_names` (stateless +
stateful) plus two bucketing compositions is driven through the solver's
:func:`repro.core.solver.make_aggregator` protocol — the same entry point
campaigns and the LM trainer use — and held to the invariants the
Byzantine-robustness literature assumes without stating:

* **permutation invariance** — worker identity carries no information for
  an identity-blind rule (bucketing is excluded: its random bucket
  assignment is a function of row order by construction);
* **honest-unanimity fixed point** — when every worker sends the same
  vector v (and stateful centers already sit at v), the aggregate is v;
* **translation equivariance** — agg(x + t) = agg(x) + t, jointly in the
  carried center for stateful rules;
* **hull bounds** — coordinate-wise rules stay in the per-coordinate
  [min, max] envelope; geometric rules (whose output is a convex
  combination of rows) satisfy ‖out‖₂ ≤ max_i ‖x_i‖₂.

Requires ``hypothesis``; skipped when absent unless ``REQUIRE_HYPOTHESIS``
is set (the CI tier-1 environment sets it, so the suite can never be
silently skipped there).
"""
import os

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise
    pytest.skip("hypothesis not installed", allow_module_level=True)

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aggregators import aggregator_names
from repro.core.solver import Problem, SolverConfig, make_aggregator

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

# bucketing needs s | m; keep m even across the whole roster so one
# strategy serves every spec
BUCKETED = ("bucket2:krum", "bucket2:trimmed_mean")
ROSTER = aggregator_names() + BUCKETED
# output bounded per-coordinate by the input's [min, max] envelope
COORDINATEWISE = {"mean", "coordinate_median", "trimmed_mean",
                  "bucket2:trimmed_mean"}
# output is a convex combination of rows (possibly after pre-averaging,
# possibly including the carried center, which the tests pin to 0 or a row)
NORM_BOUNDED = {"mean", "krum", "multi_krum", "medoid", "geometric_median",
                "autogm", "centered_clip", "bucket2:krum"}


def _problem(d: int) -> Problem:
    zero = jnp.zeros((d,))
    return Problem(d=d, f=lambda x: 0.0, grad=lambda x: zero,
                   stoch_grad=lambda k, x: zero, x1=zero, x_star=zero,
                   D=10.0, V=1.0)


def _protocol(name: str, m: int, d: int):
    cfg = SolverConfig(m=m, T=1, eta=0.1, alpha=0.25, aggregator=name,
                       attack="none")
    return make_aggregator(_problem(d), cfg)


def _aggregate(name, x, state=None):
    m, d = x.shape
    state0, step = _protocol(name, m, d)
    zero = jnp.zeros((d,))
    _, xi, n_alive, alive = step(state0 if state is None else state,
                                 jnp.asarray(x), zero, zero)
    return np.asarray(xi), int(n_alive), np.asarray(alive)


def _center_at(name, state0, v):
    """Place any carried (d,) float center at v (centered clipping); leave
    every other leaf (PRNG keys, dummy scalars, inner states) untouched."""
    return jax.tree.map(
        lambda leaf: v if (hasattr(leaf, "shape") and leaf.shape == v.shape
                           and jnp.issubdtype(leaf.dtype, jnp.floating))
        else leaf,
        state0,
    )


def grids(m_opts=(4, 6, 8, 12), d_max=10):
    return st.tuples(
        st.sampled_from(m_opts), st.integers(1, d_max),
        st.integers(0, 2**31 - 1),
    ).map(lambda t: np.asarray(
        jax.random.normal(jax.random.PRNGKey(t[2]), (t[0], t[1])) * 3.0,
        np.float32,
    ))


@pytest.mark.parametrize("name", ROSTER)
@given(x=grids())
def test_protocol_shape_and_finiteness(name, x):
    """The make_aggregator contract itself: finite (d,) output, m alive."""
    xi, n_alive, alive = _aggregate(name, x)
    assert xi.shape == (x.shape[1],)
    assert np.all(np.isfinite(xi))
    assert n_alive == x.shape[0]
    assert alive.shape == (x.shape[0],) and alive.all()


@pytest.mark.parametrize("name",
                         [n for n in ROSTER if not n.startswith("bucket")])
@given(x=grids())
def test_permutation_invariance(name, x):
    perm = np.asarray(
        jax.random.permutation(jax.random.PRNGKey(7), x.shape[0]))
    a, _, _ = _aggregate(name, x)
    b, _, _ = _aggregate(name, x[perm])
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ROSTER)
@given(data=st.tuples(st.sampled_from((4, 6, 8)), st.integers(1, 10),
                      st.integers(0, 2**31 - 1)))
def test_honest_unanimity_fixed_point(name, data):
    m, d, seed = data
    v = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (d,)) * 3.0,
                   np.float32)
    x = np.tile(v, (m, 1))
    state0, step = _protocol(name, m, d)
    state = _center_at(name, state0, jnp.asarray(v))
    zero = jnp.zeros((d,))
    _, xi, _, _ = step(state, jnp.asarray(x), zero, zero)
    np.testing.assert_allclose(np.asarray(xi), v, rtol=1e-5, atol=1e-5)


# equivariance is exact in real arithmetic for every rule; the Weiszfeld
# family re-weights rows by 1/dist, which amplifies f32 rounding of the
# translated inputs, so the iterative rules get a looser band
_EQUIV_TOL = {"geometric_median": 5e-2, "autogm": 5e-2}


@pytest.mark.parametrize("name", ROSTER)
@given(x=grids(), tseed=st.integers(0, 2**31 - 1))
def test_translation_equivariance(name, x, tseed):
    d = x.shape[1]
    t = np.asarray(jax.random.normal(jax.random.PRNGKey(tseed), (d,)) * 5.0,
                   np.float32)
    m = x.shape[0]
    state0, step = _protocol(name, m, d)
    zero = jnp.zeros((d,))
    _, a, _, _ = step(state0, jnp.asarray(x), zero, zero)
    # stateful centers translate jointly with the inputs (a center at 0 on x
    # corresponds to a center at t on x + t); no-op for everything else
    state_t = _center_at(name, state0, jnp.asarray(t))
    _, b, _, _ = step(state_t, jnp.asarray(x + t[None]), zero, zero)
    tol = _EQUIV_TOL.get(name, 1e-3)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a) + t,
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("name", sorted(COORDINATEWISE))
@given(x=grids())
def test_coordinatewise_envelope(name, x):
    xi, _, _ = _aggregate(name, x)
    assert (xi >= x.min(axis=0) - 1e-4).all()
    assert (xi <= x.max(axis=0) + 1e-4).all()


@pytest.mark.parametrize("name", sorted(NORM_BOUNDED))
@given(x=grids())
def test_norm_bounded_by_largest_row(name, x):
    """Convex-hull membership ⇒ ‖out‖ ≤ max_i ‖x_i‖ (centered clipping's
    zero-initialized center only shrinks the bound)."""
    xi, _, _ = _aggregate(name, x)
    assert np.linalg.norm(xi) <= np.linalg.norm(x, axis=1).max() + 1e-3
