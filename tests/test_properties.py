"""Hypothesis property-based tests on system invariants.

Skipped when hypothesis is absent unless ``REQUIRE_HYPOTHESIS`` is set —
the CI tier-1 environment sets it, so a missing dependency there is a loud
failure instead of a silent skip.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise
    pytest.skip("hypothesis not installed", allow_module_level=True)
from hypothesis import given, settings, strategies as st

from repro.core.aggregators import (
    aggregate_coordinate_median,
    aggregate_krum,
    aggregate_trimmed_mean,
)
from repro.core.attacks import ATTACKS, apply_attack
from repro.core.byzantine_sgd import (
    counting_median_index,
    pairwise_sq_dists_from_gram,
)
from repro.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def arrays(m_min=3, m_max=12, d_min=1, d_max=16):
    return st.tuples(
        st.integers(m_min, m_max), st.integers(d_min, d_max), st.integers(0, 2**31 - 1)
    ).map(lambda t: np.asarray(
        jax.random.normal(jax.random.PRNGKey(t[2]), (t[0], t[1])) * 3.0
    ))


@given(arrays())
def test_coordinate_median_within_range(x):
    out = np.asarray(aggregate_coordinate_median(jnp.asarray(x)))
    assert (out >= x.min(axis=0) - 1e-5).all()
    assert (out <= x.max(axis=0) + 1e-5).all()


@given(arrays(m_min=5))
def test_trimmed_mean_within_untrimmed_range(x):
    out = np.asarray(aggregate_trimmed_mean(jnp.asarray(x), trim_fraction=0.2))
    s = np.sort(x, axis=0)
    b = int(0.2 * x.shape[0])
    assert (out >= s[b] - 1e-5).all()
    assert (out <= s[x.shape[0] - b - 1] + 1e-5).all()


@given(arrays(m_min=4))
def test_krum_returns_input_row(x):
    out = np.asarray(aggregate_krum(jnp.asarray(x), n_byzantine=1))
    dists = np.abs(x - out[None]).sum(axis=1)
    assert dists.min() < 1e-5


@given(arrays())
def test_pairwise_dists_symmetric_nonneg(x):
    g = jnp.asarray(x) @ jnp.asarray(x).T
    d2 = np.asarray(pairwise_sq_dists_from_gram(g))
    assert (d2 >= 0).all()
    np.testing.assert_allclose(d2, d2.T, rtol=1e-4, atol=1e-4)


@given(arrays(m_min=5), st.floats(0.5, 50.0))
def test_counting_median_majority_property(x, radius):
    """If the counting median reports found=True, the returned point must
    genuinely have a strict majority within the radius."""
    g = jnp.asarray(x) @ jnp.asarray(x).T
    d2 = pairwise_sq_dists_from_gram(g)
    idx, found = counting_median_index(d2, jnp.asarray(radius))
    if bool(found):
        m = x.shape[0]
        cnt = int(jnp.sum(d2[idx] <= radius * radius))
        assert cnt * 2 > m


@given(arrays(m_min=2), st.integers(4, 64), st.integers(0, 5))
def test_countsketch_linear(x, k, salt):
    """Sketching is linear: sk(a+b) == sk(a) + sk(b)."""
    xa = jnp.asarray(x)
    s_sum = ref.countsketch_ref(xa + xa, k, salt)
    s_twice = 2.0 * ref.countsketch_ref(xa, k, salt)
    np.testing.assert_allclose(s_sum, s_twice, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# attack-zoo invariants: every attack is a pure overwrite of Byzantine rows
# ---------------------------------------------------------------------------

_ZOO = sorted(set(ATTACKS) - {"mirror"})  # mirror needs ctx['mirror_grads']


def _attack_ctx(x, seed):
    m, d = x.shape
    return {
        "true_grad": jnp.asarray(x).mean(axis=0),
        "V": 1.0,
        "step": jnp.asarray(seed % 7),
        "alive": jnp.asarray(
            jax.random.bernoulli(jax.random.PRNGKey(seed + 1), 0.8, (m,))
        ),
        "n_alive": jnp.asarray(m),
        "prev_xi": jnp.zeros((d,)),
    }


@pytest.mark.parametrize("name", _ZOO)
@given(arrays(m_min=3), st.integers(0, 2**31 - 1))
def test_attack_honest_rows_bit_identical(name, x, seed):
    """Attacks may only overwrite Byzantine rows — honest rows must come
    back bit-for-bit, not approximately (broadcasting through jnp.where
    guarantees this; a repeat+add would not)."""
    m = x.shape[0]
    mask = np.asarray(jax.random.bernoulli(jax.random.PRNGKey(seed), 0.4, (m,)))
    out = apply_attack(name, jax.random.PRNGKey(seed + 2), jnp.asarray(x),
                       jnp.asarray(mask), _attack_ctx(x, seed))
    assert out.shape == x.shape
    np.testing.assert_array_equal(np.asarray(out)[~mask], x[~mask])


@pytest.mark.parametrize("name", _ZOO)
@given(arrays(m_min=3), st.integers(0, 2**31 - 1))
def test_attack_respects_empty_mask(name, x, seed):
    """With no Byzantine workers the attack is the identity."""
    mask = jnp.zeros((x.shape[0],), bool)
    out = apply_attack(name, jax.random.PRNGKey(seed), jnp.asarray(x),
                       mask, _attack_ctx(x, seed))
    np.testing.assert_array_equal(np.asarray(out), x)


@given(arrays(m_min=3), st.integers(0, 2**31 - 1), st.floats(0.05, 1.0))
def test_hidden_shift_within_claimed_deviation(x, seed, c):
    """hidden_shift claims its rows are valid-looking gradients: within
    c·V of the true gradient (so they pass the ∇-check for c ≤ 1)."""
    m = x.shape[0]
    mask = np.asarray(jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (m,)))
    ctx = _attack_ctx(x, seed)
    out = apply_attack("hidden_shift", jax.random.PRNGKey(seed), jnp.asarray(x),
                       jnp.asarray(mask), ctx, c=float(c))
    dev = np.linalg.norm(np.asarray(out)[mask] - np.asarray(ctx["true_grad"]),
                         axis=-1)
    assert (dev <= c * ctx["V"] + 1e-4).all()


@given(arrays(m_min=4), st.integers(0, 2**31 - 1))
def test_filtered_mean_in_convex_hull_coordinatewise(x, seed):
    mask = np.asarray(jax.random.bernoulli(jax.random.PRNGKey(seed), 0.7, (x.shape[0],)))
    if mask.sum() == 0:
        return
    out = np.asarray(ref.filtered_mean_ref(jnp.asarray(x), jnp.asarray(mask), float(mask.sum())))
    sel = x[mask.astype(bool)]
    assert (out >= sel.min(axis=0) - 1e-4).all()
    assert (out <= sel.max(axis=0) + 1e-4).all()
