"""Distributed guard + trainer: exact vs sketch agreement, attack filtering,
the unified flat-view trainer (DESIGN.md §10), spec builders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.solver import SolverConfig
from repro.distributed.byzantine_dp import (
    DPGuardConfig,
    apply_tree_attack,
    guard_step,
    init_guard_state,
    sketch_tree,
    worker_cross_gram,
    worker_sq_norms,
    worker_vdot,
)
from repro.distributed.trainer import (
    build_train_step,
    init_train_state,
    rank_from_mask,
)
from repro.models import build_model
from repro.optim import adamw, sgd


def tree_of(rng, W, scale=1.0):
    k1, k2 = jax.random.split(rng)
    return {
        "a": scale * jax.random.normal(k1, (W, 8, 4)),
        "b": {"c": scale * jax.random.normal(k2, (W, 16))},
    }


class TestTreeAlgebra:
    def test_worker_vdot_matches_flat(self, rng):
        W = 6
        g = tree_of(rng, W)
        h = tree_of(jax.random.fold_in(rng, 1), W)
        got = worker_vdot(g, h)
        flat_g = jnp.concatenate([g["a"].reshape(W, -1), g["b"]["c"]], axis=1)
        flat_h = jnp.concatenate([h["a"].reshape(W, -1), h["b"]["c"]], axis=1)
        np.testing.assert_allclose(got, jnp.sum(flat_g * flat_h, axis=1), rtol=1e-5)

    def test_cross_gram_matches_flat(self, rng):
        W = 5
        g = tree_of(rng, W)
        flat = jnp.concatenate([g["a"].reshape(W, -1), g["b"]["c"]], axis=1)
        np.testing.assert_allclose(worker_cross_gram(g), flat @ flat.T, rtol=1e-5)

    def test_sketch_preserves_distances_approximately(self, rng):
        W, k = 6, 2048
        g = tree_of(rng, W, scale=1.0)
        s = sketch_tree(g, k)
        flat = jnp.concatenate([g["a"].reshape(W, -1), g["b"]["c"]], axis=1)
        true_gram = flat @ flat.T
        est_gram = s @ s.T
        # diag exact in the guard; here check cross terms are in the ballpark
        scale = float(jnp.mean(jnp.abs(true_gram)))
        assert float(jnp.max(jnp.abs(est_gram - true_gram))) < 5.0 * scale


class TestTreeAttacks:
    def test_sign_flip_only_byz(self, rng):
        W = 4
        g = tree_of(rng, W)
        byz = jnp.asarray([True, False, False, True])
        out = apply_tree_attack("sign_flip", rng, g, byz, scale=2.0)
        np.testing.assert_allclose(out["a"][0], -2.0 * g["a"][0], rtol=1e-6)
        np.testing.assert_allclose(out["a"][1], g["a"][1], rtol=1e-6)

    @pytest.mark.parametrize("name", ["none", "sign_flip", "noise", "constant_drift", "scaled_copy"])
    def test_all_attacks_shape_preserving(self, rng, name):
        W = 4
        g = tree_of(rng, W)
        byz = jnp.asarray([True, False, False, False])
        out = apply_tree_attack(name, rng, g, byz)
        assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(g)


class TestUnifiedBaselineAggregators:
    """Stateless baselines now ride the same flat view as the solver
    (``make_aggregator`` on the ravelled (W, d) gradients) — the tree-level
    ``aggregate_baseline`` with its hard-coded ``W // 4`` Byzantine count is
    gone.  Checked through the core aggregators on the ravelled trees."""

    def _flat(self, g):
        from repro.core.tree_harness import TreeHarness

        h = TreeHarness(jax.tree_util.tree_map(lambda l: l[0], g))
        return h, h.ravel_workers(g)

    def test_mean_on_ravelled_tree(self, rng):
        from repro.core.aggregators import aggregate_mean

        g = tree_of(rng, 5)
        h, flat = self._flat(g)
        out = h.unravel(aggregate_mean(flat))
        np.testing.assert_allclose(out["a"], jnp.mean(g["a"], 0), rtol=1e-6)

    def test_krum_avoids_outlier_on_ravelled_tree(self, rng):
        from repro.core.aggregators import aggregate_krum

        g = tree_of(rng, 6, scale=0.1)
        g["a"] = g["a"].at[2].add(100.0)   # outlier worker 2
        h, flat = self._flat(g)
        out = h.unravel(aggregate_krum(flat, n_byzantine=1))
        dists = [float(jnp.sum(jnp.abs(out["a"] - g["a"][i]))) for i in range(6)]
        assert np.argmin(dists) != 2

    def test_trimmed_mean_robust_on_ravelled_tree(self, rng):
        from repro.core.aggregators import aggregate_trimmed_mean

        g = tree_of(rng, 8, scale=0.1)
        g["a"] = g["a"].at[0].set(1e6)
        h, flat = self._flat(g)
        out = h.unravel(aggregate_trimmed_mean(flat, trim_fraction=0.25))
        assert float(jnp.max(jnp.abs(out["a"]))) < 10.0


class TestGuardModes:
    @pytest.mark.parametrize("mode", ["exact", "sketch"])
    def test_guard_filters_outlier(self, rng, mode):
        W = 8
        cfg = DPGuardConfig(n_workers=W, T=50, mode=mode, sketch_dim=1024,
                            auto_v=True)
        params = {"w": jnp.zeros((8, 4))}
        state = init_guard_state(cfg, params)
        for step in range(5):
            g = {"w": 0.01 * jax.random.normal(jax.random.fold_in(rng, step), (W, 8, 4))
                 + jnp.ones((W, 8, 4)) * 0.1}
            g["w"] = g["w"].at[3].set(25.0)     # persistent gross outlier
            state, xi, diag = guard_step(cfg, state, g, params, params)
        assert not bool(state.alive[3])
        assert int(jnp.sum(state.alive)) == W - 1

    def test_exact_and_sketch_agree_on_clear_attack(self, rng):
        W = 8
        params = {"w": jnp.zeros((16,))}
        masks = {}
        for mode in ["exact", "sketch"]:
            cfg = DPGuardConfig(n_workers=W, T=50, mode=mode, sketch_dim=4096, auto_v=True)
            state = init_guard_state(cfg, params)
            for step in range(5):
                g = {"w": 0.01 * jax.random.normal(jax.random.fold_in(rng, step), (W, 16))}
                g["w"] = g["w"].at[0].set(-30.0)
                state, _, _ = guard_step(cfg, state, g, params, params)
            masks[mode] = np.asarray(state.alive)
        np.testing.assert_array_equal(masks["exact"], masks["sketch"])

    @pytest.mark.parametrize("lp", [False, True])
    def test_exact_incremental_gram_matches_recompute(self, rng, lp):
        """DESIGN.md §5: the rank-updated gram_B must track the from-scratch
        B Bᵀ contraction across steps (drift ≪ filter thresholds), and the
        two exact-mode variants must make identical filter decisions."""
        W = 6
        params = {"a": jnp.zeros((8, 4)), "b": {"c": jnp.zeros((16,))}}
        states = {}
        for incremental in [True, False]:
            cfg = DPGuardConfig(n_workers=W, T=50, mode="exact", auto_v=True,
                                incremental_gram=incremental,
                                low_precision_stats=lp)
            state = init_guard_state(cfg, params)
            for step in range(6):
                g = tree_of(jax.random.fold_in(rng, step), W, scale=0.1)
                g = jax.tree_util.tree_map(lambda x: x + 0.3, g)
                g["a"] = g["a"].at[1].set(-20.0)      # persistent attacker
                if lp:   # lp statistics consume native-dtype gradients
                    g = jax.tree_util.tree_map(
                        lambda x: x.astype(jnp.bfloat16), g
                    )
                state, xi, _ = guard_step(cfg, state, g, params, params)
            states[incremental] = (state, xi)
        s_inc, xi_inc = states[True]
        s_rec, xi_rec = states[False]
        np.testing.assert_array_equal(np.asarray(s_inc.alive), np.asarray(s_rec.alive))
        tol = 1e-2 if lp else 1e-5   # lp rounds the local B operand to bf16
        err = float(jnp.linalg.norm(s_inc.gram_B - s_rec.gram_B)
                    / jnp.maximum(jnp.linalg.norm(s_rec.gram_B), 1e-12))
        assert err < tol, err
        np.testing.assert_allclose(
            np.asarray(jax.tree_util.tree_leaves(xi_inc)[0]),
            np.asarray(jax.tree_util.tree_leaves(xi_rec)[0]),
            rtol=1e-4, atol=1e-4,
        )

    def test_gram_resync_zeroes_drift(self, rng):
        """On a resync step gram_B is re-derived from B, so it must equal
        the recompute-mode value bit-for-bit (both are the same f32
        contraction), even with bf16 lp gradients driving drift between."""
        W = 5
        params = {"w": jnp.zeros((12,))}
        grams = {}
        for incremental in [True, False]:
            cfg = DPGuardConfig(n_workers=W, T=50, mode="exact", auto_v=True,
                                incremental_gram=incremental,
                                low_precision_stats=True,
                                gram_resync_every=4)
            state = init_guard_state(cfg, params)
            for step in range(4):   # step 4 is a resync step (k_new == 4)
                g = {"w": (0.3 + 0.05 * jax.random.normal(
                    jax.random.fold_in(rng, step), (W, 12))).astype(jnp.bfloat16)}
                state, _, _ = guard_step(cfg, state, g, params, params)
            grams[incremental] = np.asarray(state.gram_B)
        np.testing.assert_array_equal(grams[True], grams[False])


class TestTrainerIntegration:
    @pytest.mark.slow
    def test_byzantine_training_beats_mean_under_attack(self, rng):
        cfg = get_config("internlm2-1.8b").reduced(max_d_model=128)
        model = build_model(cfg)
        from repro.data.synthetic import SyntheticTokens, make_worker_batch
        stream = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32)
        W = 8
        rank = rank_from_mask(jnp.arange(W) < 2)
        losses = {}
        for agg in ["byzantine_sgd", "mean"]:
            scfg = SolverConfig(m=W, T=40, eta=3e-3, alpha=0.25,
                                aggregator=agg, attack="sign_flip",
                                mean_over_alive=True,
                                guard_backend="dp_exact")
            opt = adamw(3e-3, grad_clip=1.0)
            ts = jax.jit(build_train_step(model, opt, scfg))
            state = init_train_state(model, opt, scfg, rng)
            for i in range(40):
                batch = make_worker_batch(stream, W, 2, jnp.asarray(i))
                state, m = ts(state, batch, rank, jax.random.fold_in(rng, i))
            losses[agg] = float(m["loss_good_workers"])
        assert losses["byzantine_sgd"] < losses["mean"] - 0.05
