"""Integration: paper claims on convex problems (Theorems 3.8/4.2 behaviour)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.epoch_solver import EpochSolverConfig, solve_strongly_convex
from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_logistic_problem, make_quadratic_problem


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=1)


def gap(problem, cfg, seed=0):
    res = run_sgd(problem, cfg, jax.random.PRNGKey(seed))
    return float(problem.f(res.x_avg) - problem.f(problem.x_star)), res


class TestNoByzantine:
    def test_sgd_converges(self, quad):
        g, _ = gap(quad, SolverConfig(m=8, T=2000, eta=0.05, alpha=0.0,
                                      aggregator="mean", attack="none"))
        assert g < 5e-3

    def test_guard_matches_mean_when_honest(self, quad):
        """α=0: ByzantineSGD must match plain SGD (criterion 3 of §1.2)."""
        g_mean, _ = gap(quad, SolverConfig(m=8, T=2000, eta=0.05, aggregator="mean", attack="none"))
        g_byz, res = gap(quad, SolverConfig(m=8, T=2000, eta=0.05,
                                            aggregator="byzantine_sgd", attack="none"))
        assert g_byz < max(5 * g_mean, 5e-3)
        assert int(res.n_alive[-1]) == 8  # honest workers never filtered


class TestUnderAttack:
    @pytest.mark.parametrize("attack", ["sign_flip", "random_gaussian", "alie", "constant_drift"])
    def test_guard_converges_under_attack(self, quad, attack):
        g, res = gap(quad, SolverConfig(m=16, T=2000, eta=0.05, alpha=0.25,
                                        aggregator="byzantine_sgd", attack=attack))
        assert g < 2e-2, f"{attack}: gap {g}"
        assert not bool(res.ever_filtered_good)

    def test_mean_fails_under_attack(self, quad):
        g, _ = gap(quad, SolverConfig(m=16, T=2000, eta=0.05, alpha=0.25,
                                      aggregator="mean", attack="sign_flip"))
        assert g > 0.1  # naive mean is destroyed — the paper's premise

    def test_alpha_045_still_converges(self, quad):
        """Optimal breakdown point: works for any α < 1/2."""
        g, res = gap(quad, SolverConfig(m=20, T=3000, eta=0.05, alpha=0.45,
                                        aggregator="byzantine_sgd", attack="sign_flip"))
        assert g < 5e-2
        assert not bool(res.ever_filtered_good)

    def test_hidden_shift_damage_bounded(self, quad):
        """Lemma 3.6: attackers inside the thresholds cause only O(αDV/√T)
        extra error — convergence must still happen."""
        g, _ = gap(quad, SolverConfig(m=16, T=4000, eta=0.02, alpha=0.25,
                                      aggregator="byzantine_sgd", attack="hidden_shift"))
        assert g < 5e-2


class TestScaling:
    def test_error_decreases_with_T(self, quad):
        cfgs = [SolverConfig(m=16, T=T, eta=0.05, alpha=0.25,
                             aggregator="byzantine_sgd", attack="sign_flip")
                for T in (250, 4000)]
        g_small, _ = gap(quad, cfgs[0])
        g_large, _ = gap(quad, cfgs[1])
        assert g_large < g_small

    @pytest.mark.slow
    def test_epoch_solver_reaches_epsilon(self, quad):
        cfg = EpochSolverConfig(m=16, alpha=0.25, epsilon=2e-3, attack="sign_flip",
                                t_scale=0.05, max_t_per_epoch=4000)
        res = solve_strongly_convex(quad, cfg, jax.random.PRNGKey(0))
        assert res.per_epoch_gap[-1] < 5e-3


@pytest.mark.slow
def test_logistic_regression_under_attack():
    """Validates the Theorem 3.9 guarantee quantitatively: logistic V is a
    ball-wide a.s. bound (≈2·max‖aᵢ‖ — conservative), so sign-flips whose
    per-step deviation sits under 4V can hide; the theorem prices exactly
    that in as the O(αDV/√T) term. The measured gap must respect it."""
    import math
    prob = make_logistic_problem(d=10, n_data=256, reg=1e-2, seed=2)
    cfg = SolverConfig(m=16, T=8000, eta=0.1, alpha=0.25,
                       aggregator="byzantine_sgd", attack="sign_flip")
    res = run_sgd(prob, cfg, jax.random.PRNGKey(0))
    g = float(prob.f(res.x_avg) - prob.f(prob.x_star))
    theorem_term = cfg.alpha * prob.D * prob.V / math.sqrt(cfg.T)
    assert g < 3.0 * theorem_term, (g, theorem_term)
    assert not bool(res.ever_filtered_good)
