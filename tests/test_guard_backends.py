"""Guard-backend axis (DESIGN.md §9): end-to-end parity through the solver
scan and the campaign runner.

PR 1 tested the fused pipeline only at the ``ByzantineGuard.step`` level;
these tests drive every registered backend through ``run_sgd`` (multi-step
attack runs, the scan carrying each backend's own state pytree) and through
a vmapped one-jit campaign, pinning the oracle contracts:

* ``fused`` ≡ ``dense`` to float tolerance over a whole attacked run;
* ``dp_exact`` (``auto_v=False``) ≡ ``dense`` on the flat harness — the
  distributed guard is the same filter, produced from Gram contractions;
* ``dp_sketch`` makes the same filter decisions on clearly-separated
  attacks and converges under them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.guard_backends import guard_backend_names, make_guard_backend
from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_quadratic_problem
from repro.scenarios import (
    expand_grid,
    expand_variants,
    run_campaign,
    scenario_churn,
    scenario_static,
    summarize_campaign,
)


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=1)


def _cfg(**kw):
    base = dict(m=16, T=60, eta=0.05, alpha=0.25,
                aggregator="byzantine_sgd", attack="sign_flip")
    base.update(kw)
    return SolverConfig(**base)


class TestRegistry:
    def test_all_four_backends_registered(self):
        assert set(guard_backend_names()) >= {
            "dense", "fused", "dp_exact", "dp_sketch"
        }

    def test_unknown_backend_raises(self, quad):
        with pytest.raises(KeyError, match="unknown guard backend"):
            make_guard_backend("nope", quad, _cfg(guard_backend="nope"))

    def test_shared_opts_filtered_per_backend(self, quad):
        """One guard_opts tuple serves a multi-backend sweep: knobs a
        backend doesn't declare are dropped (sketch_dim must not crash
        dense/fused), while a knob unknown to every backend raises."""
        cfg = _cfg(guard_opts=(("sketch_dim", 256), ("auto_v", False),
                               ("gram_resync_every", 2)))
        for name in ["dense", "fused", "dp_exact", "dp_sketch"]:
            state0, step = make_guard_backend(name, quad, cfg)
            assert step is not None, name
        with pytest.raises(KeyError, match="unknown guard_opts"):
            make_guard_backend(
                "dense", quad, _cfg(guard_opts=(("sketchdim", 1),))
            )

    def test_backend_step_contract(self, quad):
        """Every backend honors (state, grads, x, x1) -> (state, ξ, n, alive)."""
        cfg = _cfg()
        grads = 0.1 + 0.05 * jax.random.normal(
            jax.random.PRNGKey(0), (cfg.m, quad.d))
        x1 = jnp.zeros((quad.d,))
        for name in guard_backend_names():
            state0, step = make_guard_backend(name, quad, cfg)
            state, xi, n_alive, alive = step(state0, grads, x1, x1)
            assert xi.shape == (quad.d,), name
            assert alive.shape == (cfg.m,) and alive.dtype == bool, name
            assert int(n_alive) == cfg.m, name  # honest step filters nobody


class TestEndToEndParity:
    @pytest.mark.parametrize("attack", ["sign_flip", "alie"])
    def test_fused_matches_dense_through_scan(self, quad, attack):
        """The fused Pallas pipeline must reproduce the dense trajectory
        through a full multi-step attacked run — scan-carried incremental
        Gram, resync cond, and fused filtered-mean included."""
        key = jax.random.PRNGKey(5)
        res_d = run_sgd(quad, _cfg(attack=attack, guard_backend="dense"), key)
        res_f = run_sgd(quad, _cfg(attack=attack, guard_backend="fused"), key)
        np.testing.assert_array_equal(np.asarray(res_d.byz_mask),
                                      np.asarray(res_f.byz_mask))
        np.testing.assert_array_equal(np.asarray(res_d.final_alive),
                                      np.asarray(res_f.final_alive))
        np.testing.assert_allclose(np.asarray(res_f.gaps),
                                   np.asarray(res_d.gaps),
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(res_f.x_avg),
                                   np.asarray(res_d.x_avg),
                                   rtol=1e-4, atol=1e-6)

    def test_dp_exact_matches_dense_oracle(self, quad):
        """The distributed exact guard on the flat harness IS the dense
        filter (auto_v off, V known): identical filter decisions, matching
        trajectories."""
        key = jax.random.PRNGKey(7)
        res_d = run_sgd(quad, _cfg(guard_backend="dense"), key)
        res_e = run_sgd(
            quad,
            _cfg(guard_backend="dp_exact", guard_opts=(("auto_v", False),)),
            key,
        )
        np.testing.assert_array_equal(np.asarray(res_d.final_alive),
                                      np.asarray(res_e.final_alive))
        np.testing.assert_allclose(np.asarray(res_e.gaps),
                                   np.asarray(res_d.gaps),
                                   rtol=1e-4, atol=1e-6)

    def test_dp_exact_recompute_gram_also_matches(self, quad):
        """incremental_gram=False is the drift oracle — same answer."""
        key = jax.random.PRNGKey(7)
        res_i = run_sgd(
            quad,
            _cfg(guard_backend="dp_exact", guard_opts=(("auto_v", False),)),
            key,
        )
        res_r = run_sgd(
            quad,
            _cfg(guard_backend="dp_exact",
                 guard_opts=(("auto_v", False), ("incremental_gram", False))),
            key,
        )
        np.testing.assert_allclose(np.asarray(res_i.gaps),
                                   np.asarray(res_r.gaps),
                                   rtol=1e-5, atol=1e-7)

    @pytest.mark.parametrize("backend,opts", [
        ("dp_sketch", ()),
        ("dp_sketch", (("auto_v", False),)),
        # k=8 < d=16: real CountSketch compression, not the lossless
        # k > d degenerate case the default sketch_dim gives at tiny d
        ("dp_sketch", (("sketch_dim", 8),)),
    ])
    def test_dp_sketch_filters_and_converges(self, quad, backend, opts):
        """The sketch guard (auto-V on or off, with and without genuine
        compression) must drop the sign-flippers and converge on the flat
        harness."""
        cfg = _cfg(T=200, guard_backend=backend, guard_opts=opts)
        res = run_sgd(quad, cfg, jax.random.PRNGKey(2))
        n_byz = int(np.asarray(res.byz_mask).sum())
        assert int(res.n_alive[-1]) == cfg.m - n_byz
        assert not bool(res.ever_filtered_good)
        gap = float(quad.f(res.x_avg) - quad.f(quad.x_star))
        assert gap < 0.1, gap


class TestCampaignBackendAxis:
    def test_backend_axis_expands_guard_only(self):
        cfgs = expand_variants(_cfg(), ["mean", "byzantine_sgd"],
                               backends=["dense", "fused"])
        assert set(cfgs) == {"mean", "byzantine_sgd@dense",
                             "byzantine_sgd@fused"}
        assert cfgs["byzantine_sgd@fused"].guard_backend == "fused"
        assert cfgs["mean"].aggregator == "mean"

    def test_explicit_at_spelling_and_bad_agg(self):
        cfgs = expand_variants(_cfg(), ["byzantine_sgd@dp_sketch"])
        assert cfgs["byzantine_sgd@dp_sketch"].guard_backend == "dp_sketch"
        with pytest.raises(ValueError, match="guard backends"):
            expand_variants(_cfg(), ["krum@fused"])

    def test_one_campaign_sweeps_three_backends(self, quad):
        """One run_campaign call, three guard realizations + a baseline,
        under a dynamic (churn) and a static scenario — the stats keys carry
        the backend, dense/fused agree run-for-run, and the report grows a
        bound-check row per backend variant."""
        cfg = _cfg(T=50)
        grid = expand_grid(
            [("sf", scenario_static("sign_flip")),
             ("churn", scenario_churn("sign_flip", period=25, stride=4))],
            alphas=[0.25], seeds=[0],
        )
        result = run_campaign(quad, cfg, grid, ["mean", "byzantine_sgd"],
                              backends=["dense", "fused", "dp_sketch"])
        assert set(result.stats) == {
            "mean", "byzantine_sgd@dense", "byzantine_sgd@fused",
            "byzantine_sgd@dp_sketch",
        }
        np.testing.assert_allclose(
            np.asarray(result.stats["byzantine_sgd@dense"].gap_avg),
            np.asarray(result.stats["byzantine_sgd@fused"].gap_avg),
            rtol=1e-4, atol=1e-7,
        )
        rec = summarize_campaign(result, quad, cfg)
        bound_aggs = {r["aggregator"] for r in rec["guard_bound"]}
        assert bound_aggs == {"byzantine_sgd@dense", "byzantine_sgd@fused",
                              "byzantine_sgd@dp_sketch"}

    def test_campaign_matches_eager_per_backend(self, quad):
        """Vmapped campaign rows reproduce eager run_sgd for a non-dense
        backend (the same contract TestCampaign pins for dense)."""
        from repro.scenarios import ScenarioAdversary

        cfg = _cfg(T=40, guard_backend="dp_sketch")
        scn = scenario_static("sign_flip")
        grid = expand_grid([("sf", scn)], alphas=[0.25], seeds=[0, 1])
        result = run_campaign(quad, cfg, grid, ["byzantine_sgd@dp_sketch"])
        for i, e in enumerate(result.entries):
            adv = ScenarioAdversary(scenario=scn, alpha=jnp.float32(e["alpha"]))
            res = run_sgd(quad, cfg, jax.random.PRNGKey(e["seed"]),
                          adversary=adv)
            gap = float(quad.f(res.x_avg) - quad.f(quad.x_star))
            got = float(result.stats["byzantine_sgd@dp_sketch"].gap_avg[i])
            assert got == pytest.approx(gap, rel=1e-5), e
