"""Run-axis chunking of the one-jit campaign (DESIGN.md §14).

The contract: ``chunk_size`` is a *memory* knob, not a semantics knob —
``lax.map`` over chunks of the vmapped grid produces bit-identical stats
to the flat vmap at every chunk size (1, uneven, ≥ n), including the
armed flight-recorder rings.  Per-run math is untouched; padding repeats
the last run and is sliced off.

Caveat pinned here deliberately by *omission*: stateless-aggregator
variants (e.g. ``mean``) can differ by ~1 ulp at ``chunk_size=1`` — XLA
rewrites the width-1 batch dim through the reduction differently.  The
guard variants (the mega campaign's subject) are bit-stable at every
chunk size, and those are what this suite pins.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.solver import SolverConfig
from repro.data.problems import make_generated_problem
from repro.obs import TelemetryConfig
from repro.scenarios.campaign import (
    expand_variants,
    run_campaign,
)
from repro.scenarios.spec import (
    expand_grid,
    profile_iid,
    profile_linear_skew,
    scenario_churn,
    scenario_static,
)

M, T = 16, 25
BACKENDS = ("fused", "gen")


@pytest.fixture(scope="module")
def setup():
    prob = make_generated_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=0)
    cfg = SolverConfig(m=M, alpha=0.25, T=T, eta=0.05)
    grid = expand_grid(
        [("static_sign_flip", scenario_static("sign_flip")),
         ("churn", scenario_churn("sign_flip", period=10, stride=2))],
        alphas=[0.125, 0.25],
        seeds=range(3),
    )  # 12 runs — indivisible by 5, so the uneven-chunk path pads
    return prob, cfg, grid


def _leaves(result):
    """(path, leaf) pairs over every variant's stats incl. telemetry."""
    return jax.tree_util.tree_leaves_with_path(result.stats)


@pytest.mark.parametrize("chunk_size", [1, 5, 12, 64])
def test_chunked_bit_identical(setup, chunk_size):
    prob, cfg, grid = setup
    tel = TelemetryConfig(ring_size=8)
    flat = run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                        backends=BACKENDS, telemetry=tel)
    chunked = run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                           backends=BACKENDS, telemetry=tel,
                           chunk_size=chunk_size)
    assert set(chunked.stats) == {f"byzantine_sgd@{b}" for b in BACKENDS}
    for (path, a), (_, b) in zip(_leaves(flat), _leaves(chunked)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"chunk_size={chunk_size} diverges at "
                    f"{jax.tree_util.keystr(path)}")


def test_chunked_with_profiles_axis(setup):
    prob, cfg, _ = setup
    grid = expand_grid(
        [("static_sign_flip", scenario_static("sign_flip"))],
        alphas=[0.25], seeds=range(3),
        profiles=[("iid", profile_iid(M)),
                  ("skew", profile_linear_skew(M, 0.4))],
    )  # 6 runs, profile leaves ride the chunked axes too
    flat = run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                        backends=("fused",))
    chunked = run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                           backends=("fused",), chunk_size=4)
    for (path, a), (_, b) in zip(_leaves(flat), _leaves(chunked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_size_below_one_rejected(setup):
    prob, cfg, grid = setup
    with pytest.raises(ValueError, match="chunk_size"):
        run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                     backends=("fused",), chunk_size=0)


def test_memory_field_populated_or_none(setup):
    prob, cfg, grid = setup
    res = run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                       backends=("fused",), chunk_size=4)
    if res.memory is not None:  # CPU/TPU expose it; some backends may not
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "peak_bytes"):
            assert isinstance(res.memory[k], int) and res.memory[k] >= 0


def test_chunking_bounds_temp_memory(setup):
    """The point of the knob: temp bytes of the chunked program scale with
    the chunk, not the grid (run only where memory_analysis is exposed)."""
    prob, cfg, grid = setup
    flat = run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                        backends=("fused",))
    small = run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                         backends=("fused",), chunk_size=2)
    if flat.memory is None or small.memory is None:
        pytest.skip("backend exposes no memory_analysis")
    assert (small.memory["temp_size_in_bytes"]
            < flat.memory["temp_size_in_bytes"])


# ---------------------------------------------------------------------------
# the campaign axis spelling of on-device generation
# ---------------------------------------------------------------------------

def test_expand_variants_gen_spelling():
    base = SolverConfig(m=M, alpha=0.25, T=T, eta=0.05)
    cfgs = expand_variants(base, ["byzantine_sgd"],
                           backends=["fused", "gen", "gen@bf16"])
    assert set(cfgs) == {"byzantine_sgd@fused", "byzantine_sgd@gen",
                         "byzantine_sgd@gen@bf16"}
    g = cfgs["byzantine_sgd@gen"]
    assert g.guard_backend == "fused" and g.generate == "kernel"
    gb = cfgs["byzantine_sgd@gen@bf16"]
    assert (gb.guard_backend == "fused" and gb.generate == "kernel"
            and gb.stats_dtype == "bf16")
    # the materializing fused variant is untouched by the pseudo-backend
    f = cfgs["byzantine_sgd@fused"]
    assert f.guard_backend == "fused" and f.generate == "off"


def test_gen_not_a_registry_backend():
    """On-device generation is a property of how the fused guard sources
    its rows, not a separate step contract — it must never appear in the
    guard-backend registry."""
    from repro.core.guard_backends import guard_backend_names

    assert "gen" not in guard_backend_names()


def test_gen_variant_matches_fused_in_campaign(setup):
    prob, cfg, grid = setup
    res = run_campaign(prob, cfg, grid, ["byzantine_sgd"],
                       backends=("fused", "gen"), chunk_size=5)
    a = res.stats["byzantine_sgd@fused"]
    b = res.stats["byzantine_sgd@gen"]
    # filter decisions identical; iterates to ~1 ulp — with both variants
    # unrolled into ONE campaign program they sit in different fusion
    # contexts, so the standalone bit-exactness (tests/test_gradgen.py)
    # relaxes to tolerance here
    np.testing.assert_array_equal(np.asarray(a.n_alive_final),
                                  np.asarray(b.n_alive_final))
    np.testing.assert_array_equal(np.asarray(a.detect_latency),
                                  np.asarray(b.detect_latency))
    np.testing.assert_allclose(np.asarray(a.gap_final),
                               np.asarray(b.gap_final), rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# expand_grid failure modes — mega grids need loud axis errors
# ---------------------------------------------------------------------------

def test_expand_grid_mismatched_profile_m():
    with pytest.raises(ValueError) as ei:
        expand_grid(
            [("s", scenario_static("sign_flip"))],
            alphas=[0.25], seeds=[0],
            profiles=[("a", profile_linear_skew(8, 0.4)),
                      ("b", profile_linear_skew(16, 0.4))],
        )
    msg = str(ei.value)
    assert "profiles" in msg and ".skew" in msg and "(16,)" in msg


def test_expand_grid_empty():
    with pytest.raises(ValueError, match="empty grid"):
        expand_grid([], alphas=[0.25], seeds=[0])
