"""Unit tests for the paper's Algorithm 1 (filter + aggregation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.byzantine_sgd import (
    ByzantineGuard,
    GuardConfig,
    GuardState,
    counting_median_index,
    filter_update,
    pairwise_sq_dists_from_gram,
)


def make_guard(m=16, T=100, V=1.0, D=5.0):
    return ByzantineGuard(GuardConfig(m=m, T=T, V=V, D=D))


class TestGeometry:
    def test_pairwise_from_gram_matches_direct(self, rng):
        x = jax.random.normal(rng, (12, 40))
        gram = x @ x.T
        d2 = pairwise_sq_dists_from_gram(gram)
        direct = jnp.sum((x[:, None] - x[None, :]) ** 2, axis=-1)
        np.testing.assert_allclose(d2, direct, rtol=1e-4, atol=1e-4)

    def test_pairwise_nonnegative_zero_diag(self, rng):
        x = 100.0 * jax.random.normal(rng, (8, 5))
        d2 = pairwise_sq_dists_from_gram(x @ x.T)
        assert float(jnp.min(d2)) >= 0.0
        np.testing.assert_allclose(jnp.diagonal(d2), 0.0, atol=1e-2)

    def test_counting_median_picks_cluster_point(self, rng):
        # 9 clustered points + 3 distant outliers: the median must be a
        # cluster member (every cluster point has > m/2 within radius)
        cluster = 0.1 * jax.random.normal(rng, (9, 4))
        outliers = 50.0 + jax.random.normal(rng, (3, 4))
        x = jnp.concatenate([cluster, outliers])
        d2 = pairwise_sq_dists_from_gram(x @ x.T)
        idx, found = counting_median_index(d2, jnp.asarray(2.0))
        assert bool(found)
        assert int(idx) < 9

    def test_counting_median_fallback_is_medoid(self, rng):
        # radius too small for any majority → fall back to global medoid
        x = jax.random.normal(rng, (6, 3)) * 10
        d2 = pairwise_sq_dists_from_gram(x @ x.T)
        idx, found = counting_median_index(d2, jnp.asarray(1e-6))
        assert not bool(found)
        scores = jnp.sum(jnp.sqrt(d2), axis=1)
        assert int(idx) == int(jnp.argmin(scores))


class TestGuardStep:
    def test_honest_workers_all_survive(self, rng):
        guard = make_guard(m=8)
        state = guard.init(d=16)
        x1 = jnp.zeros((16,))
        x = x1
        for k in range(20):
            key = jax.random.fold_in(rng, k)
            noise = jax.random.normal(key, (8, 16))
            noise = noise / jnp.linalg.norm(noise, axis=1, keepdims=True)  # ||dev||=1=V
            grads = jnp.ones((8, 16)) * 0.1 + 0.5 * noise
            state, xi, diag = guard.step(state, grads, x, x1)
            x = x - 0.05 * xi
        assert int(jnp.sum(state.alive)) == 8

    def test_large_outlier_filtered_immediately(self, rng):
        guard = make_guard(m=8, V=1.0)
        state = guard.init(d=16)
        x1 = jnp.zeros((16,))
        grads = jnp.ones((8, 16)) * 0.1
        grads = grads.at[3].set(100.0)  # gross outlier
        state, xi, diag = guard.step(state, grads, x1, x1)
        assert not bool(state.alive[3])
        assert int(jnp.sum(state.alive)) == 7

    def test_filtered_worker_never_returns(self, rng):
        guard = make_guard(m=8, V=1.0)
        state = guard.init(d=4)
        x1 = jnp.zeros((4,))
        bad = jnp.ones((8, 4)) * 0.1
        bad = bad.at[0].set(50.0)
        state, _, _ = guard.step(state, bad, x1, x1)
        assert not bool(state.alive[0])
        # behaves honestly afterwards — good_k ⊆ good_{k-1} keeps it out
        honest = jnp.ones((8, 4)) * 0.1
        state, _, _ = guard.step(state, honest, x1, x1)
        assert not bool(state.alive[0])

    def test_xi_is_filtered_mean_over_m(self, rng):
        guard = make_guard(m=4, V=1.0)
        state = guard.init(d=3)
        x1 = jnp.zeros((3,))
        grads = jnp.stack([
            jnp.asarray([1.0, 0, 0]),
            jnp.asarray([1.1, 0, 0]),
            jnp.asarray([0.9, 0, 0]),
            jnp.asarray([500.0, 0, 0]),   # filtered
        ])
        state, xi, _ = guard.step(state, grads, x1, x1)
        # paper's ξ divides by m (=4), not |good|
        np.testing.assert_allclose(xi[0], 3.0 / 4.0, rtol=1e-5)

    def test_slow_drift_caught_by_martingale(self, rng):
        """A worker whose per-step deviation stays within the ∇-check but
        accumulates a one-directional bias must eventually trip the B check
        (the cross-iteration martingale — the paper's key mechanism)."""
        # bias b = 1.9/step vs threshold 𝔗_B(k) = 4V√(kC): the martingale
        # catches at k ≈ (4V√C / b)² ≈ 340 steps — run 800 to be safe.
        m, d = 8, 16
        guard = ByzantineGuard(GuardConfig(m=m, T=800, V=2.0, D=5.0))
        state = guard.init(d)
        x1 = jnp.zeros((d,))
        u = jnp.ones((d,)) / np.sqrt(d)
        caught_at = None
        for k in range(800):
            key = jax.random.fold_in(rng, k)
            noise = jax.random.normal(key, (m, d))
            noise = noise / jnp.linalg.norm(noise, axis=1, keepdims=True)
            grads = 0.1 * jnp.ones((m, d)) + 1.0 * noise
            grads = grads.at[0].set(0.1 * jnp.ones((d,)) + 1.9 * u)  # biased, |dev| < V
            state, _, _ = guard.step(state, grads, x1, x1)
            if not bool(state.alive[0]):
                caught_at = k
                break
        assert caught_at is not None, "drift attacker was never caught"
        assert int(jnp.sum(state.alive)) == m - 1  # no good worker lost


class TestThresholds:
    def test_anytime_vs_fixed(self):
        cfg_a = GuardConfig(m=8, T=100, V=1.0, D=2.0, threshold_mode="anytime")
        cfg_f = GuardConfig(m=8, T=100, V=1.0, D=2.0, threshold_mode="fixed")
        ta_a, tb_a = cfg_a.thresholds(jnp.asarray(4))
        ta_f, tb_f = cfg_f.thresholds(jnp.asarray(4))
        assert float(ta_a) < float(ta_f)  # anytime is tighter early
        ta_a100, _ = cfg_a.thresholds(jnp.asarray(100))
        np.testing.assert_allclose(float(ta_a100), float(ta_f), rtol=1e-6)

    def test_threshold_formula(self):
        cfg = GuardConfig(m=8, T=64, V=2.0, D=3.0, delta=1e-3)
        ta, tb = cfg.thresholds(jnp.asarray(64))
        C = np.log(16 * 8 * 64 / 1e-3)
        np.testing.assert_allclose(float(ta), 4 * 3.0 * 2.0 * np.sqrt(64 * C), rtol=1e-6)
        np.testing.assert_allclose(float(tb), 4 * 2.0 * np.sqrt(64 * C), rtol=1e-6)
