"""Filter-semantics regression pins: Krum's ⌈αm⌉ default and the
Theorem-3.8 iterate average.

Two long-standing off-by-ones, each pinned at an input where the right and
wrong conventions actually differ:

* Krum's default f floored (``int(α·m)``) while its contract says ⌈αm⌉ —
  at m = 10, α = 0.25 the floor under-counts the Byzantine set a robust f
  must cover;
* ``x_avg`` accumulated x₂…x_{T+1}, excluding x₁ — on a 2-step run the two
  conventions disagree by (x₁ − x₃)/2.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_quadratic_problem


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0, seed=1)


class TestKrumDefaultF:
    def test_ceil_at_non_integer_alpha_m(self):
        """m=10, α=0.25: α·m = 2.5 → the Krum default must be ⌈2.5⌉ = 3,
        covering the realized Byzantine count, not ⌊2.5⌋ = 2."""
        cfg = SolverConfig(m=10, T=10, eta=0.1, alpha=0.25, aggregator="krum")
        assert cfg.krum_f_default == 3
        assert cfg.n_byzantine == 2  # the mask still floors (whole workers)

    @pytest.mark.parametrize("m,alpha,want", [
        (16, 0.25, 4),   # integer α·m: ceil == floor
        (16, 0.0, 1),    # floored at 1 — Krum needs f ≥ 1
        (8, 0.3, 3),     # 2.4 → 3
        (20, 0.45, 9),   # 9.0 exactly (f32-safe: no spurious round-up)
    ])
    def test_ceil_values(self, m, alpha, want):
        cfg = SolverConfig(m=m, T=10, eta=0.1, alpha=alpha, aggregator="krum")
        assert cfg.krum_f_default == want

    def test_krum_f_override_still_wins(self, quad):
        """cfg.krum_f bypasses the default — both runs must execute."""
        key = jax.random.PRNGKey(0)
        cfg = SolverConfig(m=10, T=20, eta=0.05, alpha=0.25,
                           aggregator="krum", attack="sign_flip")
        res_default = run_sgd(quad, cfg, key)
        res_f2 = run_sgd(quad, cfg._replace(krum_f=2), key)
        assert np.isfinite(np.asarray(res_default.gaps)).all()
        assert np.isfinite(np.asarray(res_f2.gaps)).all()
        # f changes the neighbour count, so the selections genuinely differ
        assert not np.allclose(np.asarray(res_default.gaps),
                               np.asarray(res_f2.gaps))


class TestIterateAverage:
    def _cfg(self, T):
        return SolverConfig(m=8, T=T, eta=0.2, alpha=0.0,
                            aggregator="mean", attack="none")

    def test_x_avg_is_mean_of_first_T_iterates(self, quad):
        """Two-step run: x̄ = (x₁ + x₂)/2 per the paper's (1/T)Σ_{k≤T} x_k.
        x₂ is observable as the T=1 run's final iterate (identical RNG
        stream for the shared prefix)."""
        key = jax.random.PRNGKey(3)
        x2 = run_sgd(quad, self._cfg(1), key).x_final
        res = run_sgd(quad, self._cfg(2), key)
        want = (quad.x1 + x2) / 2.0
        np.testing.assert_allclose(np.asarray(res.x_avg), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)
        # the old convention — (x₂ + x₃)/2 — must NOT match
        wrong = (x2 + res.x_final) / 2.0
        assert not np.allclose(np.asarray(res.x_avg), np.asarray(wrong),
                               rtol=1e-6)

    def test_single_step_average_is_x1(self, quad):
        """T=1: the average of {x₁} is x₁ — the gradient at x₁ has not yet
        entered any averaged iterate."""
        res = run_sgd(quad, self._cfg(1), jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(res.x_avg),
                                   np.asarray(quad.x1), rtol=1e-6)
