"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED variant of the same family, runs one forward/train step and one
decode step on CPU with finite outputs and correct shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

B, S = 2, 64


def _batch(cfg):
    batch = {
        "tokens": jnp.full((B, S), 3, jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.frontend != "none" and not cfg.enc_dec:
        batch["frontend"] = 0.1 * jnp.ones((B, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)
    if cfg.enc_dec:
        batch["frontend"] = 0.1 * jnp.ones((B, cfg.enc_seq_len, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return request.param, cfg, model, params


def test_reduced_constraints(arch_setup):
    name, cfg, model, params = arch_setup
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4


def test_loss_and_grad_finite(arch_setup):
    name, cfg, model, params = arch_setup
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss_fn, has_aux=True)
    )(params, batch)
    assert jnp.isfinite(loss), name
    assert loss.shape == ()
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), name


def test_train_step_reduces_loss(arch_setup):
    """One SGD step on the same batch must reduce loss (sanity of grads)."""
    name, cfg, model, params = arch_setup
    batch = _batch(cfg)
    loss_fn = lambda p: model.loss_fn(p, batch)[0]
    g = jax.jit(jax.grad(loss_fn))(params)
    l0 = float(jax.jit(loss_fn)(params))
    p2 = jax.tree_util.tree_map(lambda p, gg: p - 0.05 * gg.astype(p.dtype), params, g)
    l1 = float(jax.jit(loss_fn)(p2))
    assert l1 < l0, f"{name}: {l0} -> {l1}"


def test_decode_step_shapes_and_finite(arch_setup):
    name, cfg, model, params = arch_setup
    cache = model.init_cache(B, 128, jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), name


def test_prefill_then_decode_consistent(arch_setup):
    """Prefill cache + one decode step ≈ forward logits at position S
    (teacher-forced): validates cache layout end-to-end."""
    name, cfg, model, params = arch_setup
    batch = _batch(cfg)
    logits_p, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_len=128))(params, batch)
    tok = jnp.argmax(logits_p[:, -1], axis=-1)[:, None].astype(jnp.int32)
    logits_d, _ = jax.jit(model.decode_step)(params, cache, tok)
    assert bool(jnp.all(jnp.isfinite(logits_d))), name


def test_long_context_variant_uses_ring_cache():
    cfg = get_config("llama3.2-3b").reduced()
    cfg = dataclasses.replace(cfg, sliding_window=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 32, jnp.float32)   # window-sized ring
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(40):                            # > window → must wrap
        logits, cache = jax.jit(model.decode_step)(params, cache, tok)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["layers"][0].pos[0]) == 40
