"""End-to-end behaviour tests for the whole system.

The headline claim, at LM scale: training with ByzantineSGD aggregation
under attack (α = 1/4 sign-flipping workers) converges like clean training,
while naive mean aggregation degrades; the guard identifies exactly the
Byzantine workers and never drops an honest one.  The guard is selected
through the unified backend axis (``guard_backend``, DESIGN.md §9/§10) and
the step loop is the chunked ``lax.scan`` driver.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import run_training


@pytest.mark.slow
def test_e2e_guard_filters_and_learns():
    state, hist = run_training(
        "internlm2-1.8b", reduced=True, workers=8, per_worker_batch=2,
        seq_len=64, steps=40, alpha=0.25, attack="sign_flip",
        aggregator="byzantine_sgd", guard_backend="dp_exact", lr=3e-3,
        d_model=128,
    )
    first, last = hist[0], hist[-1]
    assert last["loss_good_workers"] < first["loss_good_workers"]
    assert int(last["n_alive"]) == 6            # both attackers removed
    assert int(last["byz_alive"]) == 0
    assert all(int(h["good_filtered"]) == 0 for h in hist)


@pytest.mark.slow
def test_e2e_label_flip_data_poisoning():
    """Data-level poisoning: corrupted workers compute honest gradients of
    corrupted data; the guard still isolates them via the martingales."""
    state, hist = run_training(
        "internlm2-1.8b", reduced=True, workers=8, per_worker_batch=2,
        seq_len=64, steps=50, alpha=0.25, attack="label_flip",
        aggregator="byzantine_sgd", guard_backend="dp_exact", lr=3e-3,
        d_model=128,
    )
    assert hist[-1]["loss_good_workers"] < hist[0]["loss_good_workers"]
    assert all(int(h["good_filtered"]) == 0 for h in hist)


@pytest.mark.slow
def test_e2e_sketch_mode_on_moe():
    """Scalable sketch guard on an MoE arch (expert-parallel gradients)."""
    state, hist = run_training(
        "deepseek-v2-lite-16b", reduced=True, workers=8, per_worker_batch=1,
        seq_len=64, steps=30, alpha=0.25, attack="random_gaussian",
        aggregator="byzantine_sgd", guard_backend="dp_sketch", lr=3e-3,
        d_model=128,
    )
    assert hist[-1]["loss_good_workers"] < hist[0]["loss_good_workers"]
    assert int(hist[-1]["byz_alive"]) == 0


@pytest.mark.slow
def test_e2e_scenario_churn_in_training():
    """The Remark-2.3 scenario engine drives LM training: under churn the
    Byzantine identity rotates mid-run and the ever-Byzantine count exceeds
    the instantaneous one, with no honest worker filtered."""
    state, hist = run_training(
        "mamba2-130m", reduced=True, workers=8, per_worker_batch=1,
        seq_len=32, steps=30, alpha=0.25, attack="sign_flip",
        aggregator="byzantine_sgd", guard_backend="dp_exact",
        scenario="churn", lr=3e-3, d_model=64,
    )
    assert int(state.ever_byz.sum()) > int(hist[0]["n_byz"])
    assert all(int(h["good_filtered"]) == 0 for h in hist)


@pytest.mark.slow
def test_e2e_resume_equals_uninterrupted(tmp_path):
    """Full-TrainState checkpointing through the real launcher: a run
    stopped at step 10 of 20 and resumed matches the uninterrupted run
    bit-for-bit."""
    kw = dict(reduced=True, workers=4, per_worker_batch=1, seq_len=16,
              steps=20, alpha=0.25, attack="sign_flip",
              guard_backend="dp_sketch", d_model=64, log_every=5)
    s_full, _ = run_training("mamba2-130m", **kw)
    ck = str(tmp_path / "ck")
    run_training("mamba2-130m", stop_after=10, ckpt_dir=ck, **kw)
    s_resumed, _ = run_training("mamba2-130m", ckpt_dir=ck, resume=True, **kw)
    for l1, l2 in zip(jax.tree_util.tree_leaves(s_full.params),
                      jax.tree_util.tree_leaves(s_resumed.params)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(s_full.guard.B),
                                  np.asarray(s_resumed.guard.B))


@pytest.mark.slow
def test_e2e_serving_roundtrip():
    from repro.launch.serve import run_serving
    gen = run_serving("jamba-v0.1-52b", batch=2, prompt_len=32, gen_tokens=8,
                      cache_len=64)
    assert gen.shape == (2, 8)
