"""End-to-end behaviour tests for the whole system.

The headline claim, at LM scale: training with ByzantineSGD aggregation
under attack (α = 1/4 sign-flipping workers) converges like clean training,
while naive mean aggregation degrades; the guard identifies exactly the
Byzantine workers and never drops an honest one.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.train import run_training


@pytest.mark.slow
def test_e2e_guard_filters_and_learns():
    state, hist = run_training(
        "internlm2-1.8b", reduced=True, workers=8, per_worker_batch=2,
        seq_len=64, steps=40, alpha=0.25, attack="sign_flip",
        aggregator="byzantine_sgd", guard_mode="exact", lr=3e-3, d_model=128,
    )
    first, last = hist[0], hist[-1]
    assert last["loss_good_workers"] < first["loss_good_workers"]
    assert int(last["n_alive"]) == 6            # both attackers removed
    assert int(last["byz_alive"]) == 0
    assert all(int(h["good_filtered"]) == 0 for h in hist)


@pytest.mark.slow
def test_e2e_label_flip_data_poisoning():
    """Data-level poisoning: corrupted workers compute honest gradients of
    corrupted data; the guard still isolates them via the martingales."""
    state, hist = run_training(
        "internlm2-1.8b", reduced=True, workers=8, per_worker_batch=2,
        seq_len=64, steps=50, alpha=0.25, attack="label_flip",
        aggregator="byzantine_sgd", guard_mode="exact", lr=3e-3, d_model=128,
    )
    assert hist[-1]["loss_good_workers"] < hist[0]["loss_good_workers"]
    assert all(int(h["good_filtered"]) == 0 for h in hist)


@pytest.mark.slow
def test_e2e_sketch_mode_on_moe():
    """Scalable sketch guard on an MoE arch (expert-parallel gradients)."""
    state, hist = run_training(
        "deepseek-v2-lite-16b", reduced=True, workers=8, per_worker_batch=1,
        seq_len=64, steps=30, alpha=0.25, attack="noise",
        aggregator="byzantine_sgd", guard_mode="sketch", lr=3e-3, d_model=128,
    )
    assert hist[-1]["loss_good_workers"] < hist[0]["loss_good_workers"]
    assert int(hist[-1]["byz_alive"]) == 0


@pytest.mark.slow
def test_e2e_serving_roundtrip():
    from repro.launch.serve import run_serving
    gen = run_serving("jamba-v0.1-52b", batch=2, prompt_len=32, gen_tokens=8,
                      cache_len=64)
    assert gen.shape == (2, 8)
