"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.countsketch import countsketch_pallas
from repro.kernels.pairdist import gram_pallas
from repro.kernels.robust_reduce import (
    coordinate_median_pallas,
    filtered_mean_pallas,
    trimmed_mean_pallas,
)

SHAPES = [(4, 64), (8, 1000), (16, 4096), (17, 5555), (33, 257), (64, 2048)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _data(m, d, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, d), jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram(m, d, dtype):
    x = _data(m, d, dtype)
    got = gram_pallas(x, d_block=512, interpret=True)
    want = ref.gram_ref(x)
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 2e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_coordinate_median(m, d, dtype):
    x = _data(m, d, dtype)
    got = coordinate_median_pallas(x, d_block=512, interpret=True)
    np.testing.assert_allclose(got, ref.coordinate_median_ref(x), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("n_trim", [1, 2])
def test_trimmed_mean(m, d, n_trim):
    if 2 * n_trim >= m:
        pytest.skip("overtrim")
    x = _data(m, d, jnp.float32)
    got = trimmed_mean_pallas(x, n_trim, d_block=512, interpret=True)
    np.testing.assert_allclose(got, ref.trimmed_mean_ref(x, n_trim), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_filtered_mean(m, d, dtype):
    x = _data(m, d, dtype)
    mask = jax.random.bernoulli(jax.random.PRNGKey(1), 0.6, (m,))
    got = filtered_mean_pallas(x, mask, float(m), d_block=512, interpret=True)
    want = ref.filtered_mean_ref(x, mask, float(m))
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("k", [16, 64, 128])
@pytest.mark.parametrize("salt", [0, 7])
def test_countsketch(m, d, k, salt):
    x = _data(m, d, jnp.float32)
    got = countsketch_pallas(x, k, salt=salt, d_block=512, interpret=True)
    want = ref.countsketch_ref(x, k, salt=salt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_countsketch_inner_product_unbiased():
    """Statistical property: E⟨s_x, s_y⟩ ≈ ⟨x, y⟩ over salts."""
    d, k = 5000, 256
    x = jax.random.normal(jax.random.PRNGKey(2), (1, d))
    y = jax.random.normal(jax.random.PRNGKey(3), (1, d))
    true = float((x @ y.T)[0, 0])
    ests = []
    for salt in range(24):
        sx = ref.countsketch_ref(x, k, salt=salt)
        sy = ref.countsketch_ref(y, k, salt=salt)
        ests.append(float((sx @ sy.T)[0, 0]))
    # per-estimate std ≈ ‖x‖‖y‖/√k (CountSketch variance); mean-of-24 shrinks √24
    se = float(jnp.linalg.norm(x) * jnp.linalg.norm(y)) / np.sqrt(k) / np.sqrt(len(ests))
    err = abs(np.mean(ests) - true)
    assert err < 3.0 * se, (err, se)


def test_ops_dispatch_cpu_interpret(rng):
    x = jax.random.normal(rng, (8, 300))
    np.testing.assert_allclose(ops.gram(x), ref.gram_ref(x), rtol=1e-4, atol=1e-4)
