"""Baseline aggregators: correctness + robustness semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import (
    aggregate_autogm,
    aggregate_coordinate_median,
    aggregate_geometric_median,
    aggregate_krum,
    aggregate_mean,
    aggregate_medoid,
    aggregate_trimmed_mean,
    bucket_means,
    get_aggregator,
    make_centered_clip,
)


@pytest.fixture
def clustered(rng):
    good = 0.1 * jax.random.normal(rng, (12, 8)) + 1.0
    bad = 100.0 * jnp.ones((4, 8))
    return jnp.concatenate([good, bad]), good


def test_mean_matches_numpy(rng):
    x = jax.random.normal(rng, (10, 5))
    np.testing.assert_allclose(aggregate_mean(x), np.mean(np.asarray(x), axis=0), rtol=1e-6)


def test_coordinate_median_matches_numpy(rng):
    x = jax.random.normal(rng, (9, 7))
    np.testing.assert_allclose(
        aggregate_coordinate_median(x), np.median(np.asarray(x), axis=0), rtol=1e-6
    )


def test_trimmed_mean_drops_extremes():
    x = jnp.asarray([[0.9], [1.0], [1.1], [1000.0], [-1000.0]])
    out = aggregate_trimmed_mean(x, trim_fraction=0.2)
    np.testing.assert_allclose(out, [1.0], rtol=1e-5)


def test_trimmed_mean_rejects_overtrim():
    with pytest.raises(ValueError):
        aggregate_trimmed_mean(jnp.ones((4, 2)), trim_fraction=0.5)


def test_krum_selects_cluster_member(clustered):
    x, good = clustered
    out = aggregate_krum(x, n_byzantine=4)
    assert float(jnp.max(jnp.abs(out))) < 10.0  # a good row, not the 100s


def test_multi_krum_averages_good(clustered):
    x, good = clustered
    out = aggregate_krum(x, n_byzantine=4, multi_k=4)
    assert float(jnp.max(jnp.abs(out - 1.0))) < 1.0


def test_medoid_is_actual_row(rng):
    x = jax.random.normal(rng, (8, 4))
    out = aggregate_medoid(x)
    dists = jnp.sum(jnp.abs(x - out[None]), axis=1)
    assert float(jnp.min(dists)) < 1e-6


def test_geometric_median_robust(clustered):
    x, good = clustered
    gm = aggregate_geometric_median(x, n_iters=32)
    assert float(jnp.linalg.norm(gm - 1.0)) < 1.5  # near the cluster, far from 100


def test_geometric_median_minimizes_objective(rng):
    x = jax.random.normal(rng, (12, 4))
    gm = aggregate_geometric_median(x, n_iters=64)
    def obj(y):
        return float(jnp.sum(jnp.linalg.norm(x - y[None], axis=1)))
    assert obj(gm) <= obj(jnp.mean(x, axis=0)) + 1e-3
    assert obj(gm) <= obj(aggregate_medoid(x)) + 1e-3


def test_registry_binds_kwargs(clustered):
    x, _ = clustered
    f = get_aggregator("krum", n_byzantine=4)
    np.testing.assert_allclose(f(x), aggregate_krum(x, n_byzantine=4))
    with pytest.raises(KeyError):
        get_aggregator("nope")


@pytest.mark.parametrize("name", ["mean", "coordinate_median", "medoid",
                                  "geometric_median", "autogm"])
def test_permutation_invariance(rng, name):
    x = jax.random.normal(rng, (10, 6))
    f = get_aggregator(name)
    perm = jax.random.permutation(jax.random.PRNGKey(7), 10)
    np.testing.assert_allclose(f(x), f(x[perm]), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# AutoGM
# ---------------------------------------------------------------------------

def test_autogm_robust(clustered):
    """The α-step's water-filling threshold zeroes the far cluster — AutoGM
    lands at least as close to the honest cluster as the geometric median."""
    x, good = clustered
    v = aggregate_autogm(x, n_outer=8, n_inner=16)
    gm = aggregate_geometric_median(x, n_iters=32)
    assert float(jnp.linalg.norm(v - 1.0)) <= float(jnp.linalg.norm(gm - 1.0)) + 1e-3
    assert float(jnp.linalg.norm(v - 1.0)) < 1.0


def test_autogm_large_lambda_recovers_geometric_median(rng):
    """λ → ∞ makes the ‖α‖² penalty dominate — uniform weights, i.e. the
    plain geometric median."""
    x = jax.random.normal(rng, (9, 5))
    v = aggregate_autogm(x, lamb=1e6, n_outer=4, n_inner=32)
    gm = aggregate_geometric_median(x, n_iters=64)
    np.testing.assert_allclose(np.asarray(v), np.asarray(gm), atol=1e-3)


# ---------------------------------------------------------------------------
# centered clipping
# ---------------------------------------------------------------------------

def test_centered_clip_converges_to_honest_mean(clustered):
    """Iterated from v₀ = 0, the carried center walks into the honest
    cluster and stays there; each 100-magnitude Byzantine row moves it at
    most τ per aggregation regardless of magnitude."""
    x, good = clustered
    state, step = make_centered_clip(x.shape[1], clip_tau=1.0, clip_iters=5)
    for _ in range(20):
        state, xi = step(state, x)
    # 4/16 rows at 100 pull the clipped mean by ≤ τ·(4/16) per inner iter
    assert float(jnp.linalg.norm(xi - jnp.mean(good, axis=0))) < 2.0
    assert float(jnp.max(jnp.abs(xi))) < 10.0


def test_centered_clip_bounded_influence():
    """An unbounded attack row moves the center by at most
    clip_iters · τ/m per step (clip_tau caps each row's contribution)."""
    d = 6
    honest = jnp.zeros((7, d))
    bad = 1e9 * jnp.ones((1, d))
    x = jnp.concatenate([honest, bad])
    state, step = make_centered_clip(d, clip_tau=1.0, clip_iters=5)
    state, xi = step(state, x)
    assert float(jnp.linalg.norm(xi)) <= 5 * 1.0 / 8 + 1e-5


def test_centered_clip_state_is_output():
    state, step = make_centered_clip(4)
    x = jnp.ones((6, 4))
    new_state, xi = step(state, x)
    np.testing.assert_array_equal(np.asarray(new_state), np.asarray(xi))


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_bucket_means_preserves_mean(rng):
    x = jax.random.normal(rng, (12, 5))
    b = bucket_means(x, 3, jax.random.PRNGKey(0))
    assert b.shape == (4, 5)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(b, axis=0)), np.asarray(jnp.mean(x, axis=0)),
        rtol=1e-5, atol=1e-6)


def test_bucket_means_rejects_non_divisor():
    with pytest.raises(ValueError):
        bucket_means(jnp.ones((10, 2)), 3, jax.random.PRNGKey(0))


def test_bucketing_dilutes_outliers(rng):
    """s = 2 pre-averaging halves a lone Byzantine row's magnitude and
    shrinks honest variance — Krum over buckets still picks a clean one."""
    good = 0.1 * jax.random.normal(rng, (14, 4)) + 1.0
    bad = 100.0 * jnp.ones((2, 4))
    x = jnp.concatenate([good, bad])
    b = bucket_means(x, 2, jax.random.PRNGKey(1))
    # at most 2 of the 8 buckets are contaminated
    n_dirty = int(jnp.sum(jnp.max(jnp.abs(b), axis=1) > 10.0))
    assert n_dirty <= 2
    out = aggregate_krum(b, n_byzantine=2)
    assert float(jnp.max(jnp.abs(out))) < 10.0


# ---------------------------------------------------------------------------
# Weiszfeld degenerate-input regression (the iterate-on-a-row singularity:
# unguarded 1/0 becomes NaN under jit)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agg", [aggregate_geometric_median, aggregate_autogm])
def test_weiszfeld_all_rows_identical(agg):
    x = 3.0 * jnp.ones((6, 4))
    out = jax.jit(agg)(x)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), 3.0, rtol=1e-5)


@pytest.mark.parametrize("agg", [aggregate_geometric_median, aggregate_autogm])
def test_weiszfeld_duplicated_row(rng, agg):
    """A duplicated row (colluding attackers sending identical vectors) can
    put the iterate exactly on a data point mid-iteration."""
    x = jax.random.normal(rng, (7, 4))
    x = jnp.concatenate([x, x[:1]])  # duplicate row 0
    out = jax.jit(agg)(x)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("agg", [aggregate_geometric_median, aggregate_autogm])
def test_weiszfeld_huge_magnitude_row(rng, agg):
    x = jax.random.normal(rng, (8, 4))
    x = x.at[0].set(1e8)
    out = jax.jit(agg)(x)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.max(jnp.abs(out))) < 1e4  # robust: not dragged away
