"""Baseline aggregators: correctness + robustness semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import (
    aggregate_coordinate_median,
    aggregate_geometric_median,
    aggregate_krum,
    aggregate_mean,
    aggregate_medoid,
    aggregate_trimmed_mean,
    get_aggregator,
)


@pytest.fixture
def clustered(rng):
    good = 0.1 * jax.random.normal(rng, (12, 8)) + 1.0
    bad = 100.0 * jnp.ones((4, 8))
    return jnp.concatenate([good, bad]), good


def test_mean_matches_numpy(rng):
    x = jax.random.normal(rng, (10, 5))
    np.testing.assert_allclose(aggregate_mean(x), np.mean(np.asarray(x), axis=0), rtol=1e-6)


def test_coordinate_median_matches_numpy(rng):
    x = jax.random.normal(rng, (9, 7))
    np.testing.assert_allclose(
        aggregate_coordinate_median(x), np.median(np.asarray(x), axis=0), rtol=1e-6
    )


def test_trimmed_mean_drops_extremes():
    x = jnp.asarray([[0.9], [1.0], [1.1], [1000.0], [-1000.0]])
    out = aggregate_trimmed_mean(x, trim_fraction=0.2)
    np.testing.assert_allclose(out, [1.0], rtol=1e-5)


def test_trimmed_mean_rejects_overtrim():
    with pytest.raises(ValueError):
        aggregate_trimmed_mean(jnp.ones((4, 2)), trim_fraction=0.5)


def test_krum_selects_cluster_member(clustered):
    x, good = clustered
    out = aggregate_krum(x, n_byzantine=4)
    assert float(jnp.max(jnp.abs(out))) < 10.0  # a good row, not the 100s


def test_multi_krum_averages_good(clustered):
    x, good = clustered
    out = aggregate_krum(x, n_byzantine=4, multi_k=4)
    assert float(jnp.max(jnp.abs(out - 1.0))) < 1.0


def test_medoid_is_actual_row(rng):
    x = jax.random.normal(rng, (8, 4))
    out = aggregate_medoid(x)
    dists = jnp.sum(jnp.abs(x - out[None]), axis=1)
    assert float(jnp.min(dists)) < 1e-6


def test_geometric_median_robust(clustered):
    x, good = clustered
    gm = aggregate_geometric_median(x, n_iters=32)
    assert float(jnp.linalg.norm(gm - 1.0)) < 1.5  # near the cluster, far from 100


def test_geometric_median_minimizes_objective(rng):
    x = jax.random.normal(rng, (12, 4))
    gm = aggregate_geometric_median(x, n_iters=64)
    def obj(y):
        return float(jnp.sum(jnp.linalg.norm(x - y[None], axis=1)))
    assert obj(gm) <= obj(jnp.mean(x, axis=0)) + 1e-3
    assert obj(gm) <= obj(aggregate_medoid(x)) + 1e-3


def test_registry_binds_kwargs(clustered):
    x, _ = clustered
    f = get_aggregator("krum", n_byzantine=4)
    np.testing.assert_allclose(f(x), aggregate_krum(x, n_byzantine=4))
    with pytest.raises(KeyError):
        get_aggregator("nope")


@pytest.mark.parametrize("name", ["mean", "coordinate_median", "medoid", "geometric_median"])
def test_permutation_invariance(rng, name):
    x = jax.random.normal(rng, (10, 6))
    f = get_aggregator(name)
    perm = jax.random.permutation(jax.random.PRNGKey(7), 10)
    np.testing.assert_allclose(f(x), f(x[perm]), rtol=1e-4, atol=1e-5)
