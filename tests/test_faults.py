"""Fault-domain robustness (DESIGN.md §15): the FaultPlan axis, the
sanitize stage, and the static off-state guarantees.

Three contracts are pinned here:

* **quarantine** — every registered baseline aggregator and every guard
  backend, fed a batch with an all-NaN row under ``sanitize="quarantine"``,
  returns a finite ξ and reports the poisoned row dead (``alive=False``,
  excluded from ``n_alive``);
* **fault plans** — schedule semantics (start/period), the top-rank victim
  convention (faults hit honest workers while Byzantine take the bottom),
  and per-mode corruption shapes, with mode 0 bit-identical to no plan;
* **off-state gating** — ``sanitize="off"`` traces contain no finiteness
  machinery (no-footprint jaxpr check), and an armed-but-inert plan /
  sanitize-on-clean-data run reproduces the ungated results exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import aggregator_names
from repro.core.solver import Problem, SolverConfig, make_aggregator, run_sgd
from repro.data.problems import make_quadratic_problem
from repro.scenarios import (
    ScenarioAdversary,
    apply_fault_plan,
    expand_grid,
    fault_bitflip,
    fault_garbage,
    fault_inf_rows,
    fault_nan_rows,
    fault_none,
    fault_rows,
    make_fault_plan,
    run_campaign,
    scenario_static,
)
from repro.scenarios.faults import FAULT_TABLE, fault_id

GUARD_BACKENDS = ("dense", "fused", "dp_exact", "dp_sketch")
M, D = 8, 12


def _problem(d: int = D) -> Problem:
    zero = jnp.zeros((d,))
    return Problem(d=d, f=lambda x: 0.0, grad=lambda x: zero,
                   stoch_grad=lambda k, x: zero, x1=zero, x_star=zero,
                   D=10.0, V=1.0)


def _step_once(cfg: SolverConfig, grads: jax.Array):
    state0, step = make_aggregator(_problem(grads.shape[1]), cfg)
    zero = jnp.zeros((grads.shape[1],))
    _, xi, n_alive, alive = step(state0, grads, zero, zero)
    return np.asarray(xi), int(n_alive), np.asarray(alive)


def _nan_row_batch(poison: int = 2) -> jax.Array:
    g = 0.1 + 0.05 * jax.random.normal(jax.random.PRNGKey(0), (M, D))
    return g.at[poison].set(jnp.nan)


class TestQuarantineContract:
    """One all-NaN row: finite ξ, poisoned row dead — for *every* rule."""

    @pytest.mark.parametrize("name", aggregator_names())
    def test_baseline_aggregators(self, name):
        cfg = SolverConfig(m=M, T=1, eta=0.1, alpha=0.25, aggregator=name,
                           attack="none", sanitize="quarantine")
        xi, n_alive, alive = _step_once(cfg, _nan_row_batch())
        assert np.all(np.isfinite(xi)), name
        assert not alive[2], name
        assert n_alive == M - 1, name

    @pytest.mark.parametrize("backend", GUARD_BACKENDS)
    def test_guard_backends(self, backend):
        cfg = SolverConfig(m=M, T=1, eta=0.1, alpha=0.25,
                           aggregator="byzantine_sgd", attack="none",
                           guard_backend=backend, sanitize="quarantine")
        xi, n_alive, alive = _step_once(cfg, _nan_row_batch())
        assert np.all(np.isfinite(xi)), backend
        assert not alive[2], backend
        assert n_alive == M - 1, backend

    @pytest.mark.parametrize("backend", GUARD_BACKENDS)
    def test_guard_kill_is_permanent(self, backend):
        """A quarantined worker stays dead on later clean steps — the
        carried alive mask closes the reporting-mask pass-through."""
        cfg = SolverConfig(m=M, T=4, eta=0.1, alpha=0.25,
                           aggregator="byzantine_sgd", attack="none",
                           guard_backend=backend, sanitize="quarantine")
        state, step = make_aggregator(_problem(), cfg)
        zero = jnp.zeros((D,))
        state, _, _, alive = step(state, _nan_row_batch(), zero, zero)
        assert not np.asarray(alive)[2]
        clean = 0.1 + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (M, D))
        state, xi, n_alive, alive = step(state, clean, zero, zero)
        assert not np.asarray(alive)[2], backend
        assert int(n_alive) == M - 1, backend
        assert np.all(np.isfinite(np.asarray(xi)))

    def test_inf_row_and_partial_nan(self):
        """±Inf rows and a single poisoned entry quarantine identically."""
        for backend in ("dense", "fused"):
            cfg = SolverConfig(m=M, T=1, eta=0.1, alpha=0.25,
                               aggregator="byzantine_sgd", attack="none",
                               guard_backend=backend, sanitize="quarantine")
            g = 0.1 + jnp.zeros((M, D))
            g = g.at[1].set(jnp.inf).at[5, 7].set(-jnp.inf)
            xi, n_alive, alive = _step_once(cfg, g)
            assert np.all(np.isfinite(xi))
            assert not alive[1] and not alive[5]
            assert n_alive == M - 2

    def test_bad_sanitize_value_raises(self):
        cfg = SolverConfig(m=M, T=1, eta=0.1, alpha=0.25, aggregator="mean",
                           attack="none", sanitize="drop")
        with pytest.raises(ValueError, match="sanitize"):
            make_aggregator(_problem(), cfg)


class TestFaultPlan:
    def test_mode_table_and_ids(self):
        assert FAULT_TABLE[0] == "none"
        for i, name in enumerate(FAULT_TABLE):
            assert fault_id(name) == i
        with pytest.raises(KeyError, match="unknown"):
            fault_id("rowhammer")

    def test_schedule_and_top_rank_victims(self):
        plan = fault_nan_rows(0.25, start_step=3, period=2)
        rank = jnp.arange(M)
        # before start: nobody; at start and every period after: top 2 ranks
        assert not np.any(fault_rows(plan, rank, jnp.int32(2)))
        hit = np.asarray(fault_rows(plan, rank, jnp.int32(3)))
        assert hit.tolist() == [False] * 6 + [True] * 2
        assert not np.any(fault_rows(plan, rank, jnp.int32(4)))
        assert np.any(fault_rows(plan, rank, jnp.int32(5)))

    def test_mode_none_is_bit_identical(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (M, D))
        out = apply_fault_plan(fault_none(), jax.random.PRNGKey(1), g,
                               jnp.arange(M), jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(g))

    def test_corruption_shapes_per_mode(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (M, D))
        rank, k = jnp.arange(M), jnp.int32(0)
        key = jax.random.PRNGKey(1)

        nan = np.asarray(apply_fault_plan(fault_nan_rows(0.25), key, g, rank, k))
        assert np.all(np.isnan(nan[6:])) and np.all(np.isfinite(nan[:6]))

        inf = np.asarray(apply_fault_plan(fault_inf_rows(0.25), key, g, rank, k))
        assert np.all(np.isinf(inf[6:]))
        assert np.any(inf[6:] > 0) and np.any(inf[6:] < 0)

        mag = 1e20
        garb = np.asarray(apply_fault_plan(
            fault_garbage(0.25, magnitude=mag), key, g, rank, k))
        assert np.all(np.isfinite(garb))  # garbage is the filter's job
        assert np.max(np.abs(garb[6:])) > 1e10
        np.testing.assert_array_equal(garb[:6], np.asarray(g)[:6])

        flip = np.asarray(apply_fault_plan(fault_bitflip(0.25), key, g, rank, k))
        np.testing.assert_array_equal(flip[:6], np.asarray(g)[:6])
        assert np.all(flip[6:] != np.asarray(g)[6:])  # some bit changed

    def test_faults_hit_honest_workers(self):
        """Victim region (top ranks) is disjoint from the Byzantine set
        (bottom ranks) until the fractions overlap."""
        adv = ScenarioAdversary(scenario=scenario_static("sign_flip"),
                                alpha=jnp.float32(0.25))
        rank = jnp.arange(M)
        byz = np.asarray(adv.mask_at(rank, jnp.int32(1)))
        hit = np.asarray(fault_rows(fault_nan_rows(0.25), rank, jnp.int32(1)))
        assert not np.any(byz & hit)


class TestOffStateGating:
    def test_sanitize_off_has_no_finiteness_footprint(self):
        """The default trace must not contain the sanitize machinery."""
        zero = jnp.zeros((D,))
        for agg, backend in [("mean", "dense"), ("byzantine_sgd", "dense"),
                             ("byzantine_sgd", "fused"),
                             ("byzantine_sgd", "dp_exact"),
                             ("byzantine_sgd", "dp_sketch")]:
            cfg = SolverConfig(m=M, T=4, eta=0.1, alpha=0.25, aggregator=agg,
                               attack="none", guard_backend=backend)
            state0, step = make_aggregator(_problem(), cfg)
            jaxpr = str(jax.make_jaxpr(step)(
                state0, jnp.zeros((M, D)), zero, zero))
            assert "is_finite" not in jaxpr, (agg, backend)

    def test_no_plan_has_no_fault_footprint(self):
        quad = make_quadratic_problem(d=D, sigma=1.0, L=8.0, V=1.0, seed=1)
        cfg = SolverConfig(m=M, T=8, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip")
        jaxpr = str(jax.make_jaxpr(
            lambda k: run_sgd(quad, cfg, k).x_final)(jax.random.PRNGKey(0)))
        assert "is_finite" not in jaxpr

    def test_inert_plan_matches_no_plan(self):
        """faults=fault_none() reproduces faults=None bit-for-bit."""
        quad = make_quadratic_problem(d=D, sigma=1.0, L=8.0, V=1.0, seed=1)
        cfg = SolverConfig(m=M, T=20, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip")
        key = jax.random.PRNGKey(7)
        adv = lambda plan: ScenarioAdversary(
            scenario=scenario_static("sign_flip"), alpha=jnp.float32(0.25),
            faults=plan)
        ref = run_sgd(quad, cfg, key, adversary=adv(None))
        armed = run_sgd(quad, cfg, key, adversary=adv(fault_none()))
        np.testing.assert_array_equal(np.asarray(ref.x_final),
                                      np.asarray(armed.x_final))

    @pytest.mark.parametrize("backend", GUARD_BACKENDS)
    def test_sanitize_on_clean_data_matches_off(self, backend):
        """With all-finite inputs the quarantine changes nothing."""
        quad = make_quadratic_problem(d=D, sigma=1.0, L=8.0, V=1.0, seed=1)
        key = jax.random.PRNGKey(7)
        res = {}
        for mode in ("off", "quarantine"):
            cfg = SolverConfig(m=M, T=20, eta=0.05, alpha=0.25,
                               aggregator="byzantine_sgd",
                               attack="sign_flip", guard_backend=backend,
                               sanitize=mode)
            res[mode] = np.asarray(run_sgd(quad, cfg, key).x_final)
        np.testing.assert_allclose(res["quarantine"], res["off"],
                                   rtol=1e-6, atol=1e-7)


class TestCampaignFaultAxis:
    def test_grid_stacks_and_records_fault_knobs(self):
        grid = expand_grid(
            [("static", scenario_static("sign_flip"))], [0.25], [0, 1],
            faults=[("none", None), ("nan", fault_nan_rows(0.25))],
        )
        assert grid.n_runs == 4
        assert grid.faults is not None
        # entries record the plan's *mode*, not the axis label
        assert [e.fault for e in grid.rows] == ["none", "nan_rows"] * 2
        assert [e.fault_frac for e in grid.rows] == [0.0, 0.25] * 2
        # no faults argument → no stacked axis, entries record "none"
        plain = expand_grid([("static", scenario_static("sign_flip"))],
                            [0.25], [0])
        assert plain.faults is None
        assert plain.rows[0].fault == "none"

    def test_campaign_cell_finite_under_nan_attack(self):
        """One jitted campaign over a fault axis: every leaderboard row
        finite, realized α reflects the quarantined victims."""
        quad = make_quadratic_problem(d=D, sigma=1.0, L=8.0, V=1.0, seed=1)
        cfg = SolverConfig(m=M, T=20, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip",
                           sanitize="quarantine")
        grid = expand_grid(
            [("static", scenario_static("sign_flip"))], [0.125], [0],
            faults=[("none", fault_none()),
                    ("nan", fault_nan_rows(0.25)),
                    ("inf", fault_inf_rows(0.25, period=2))],
        )
        result = run_campaign(quad, cfg, grid, ["byzantine_sgd", "mean"],
                              backends=["dense", "fused"])
        for name, stats in result.stats.items():
            gaps = np.asarray(stats.gap_final)
            assert np.all(np.isfinite(gaps)), name
        # fault victims count toward the realized ever-Byzantine count
        n_ever = np.asarray(result.stats["byzantine_sgd@dense"].n_byz_ever)
        assert n_ever[1] > n_ever[0]
        # ...so the sanitizer's kills never read as wrongly-filtered honest
        # workers
        efg = np.asarray(result.stats["byzantine_sgd@dense"].ever_filtered_good)
        assert not np.any(efg)
