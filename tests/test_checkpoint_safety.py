"""Crash-safety contract of repro.checkpoint (DESIGN.md §15).

Pins the fault-domain invariants the chaos harness exercises end-to-end:
atomic manifest+arrays commits, completeness-aware latest_step, checksum
verification with quarantine-and-fallback, explicit-step strictness,
legacy-format reads, stale-tmp hygiene, and keep-last retention.
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptError,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "a": r.normal(size=(4, 3)).astype(np.float32),
        "b": [np.arange(5), {"c": np.float32(2.5)}],
    }


def _assert_tree_close(got, want):
    assert np.allclose(np.asarray(got["a"]), want["a"])
    assert np.array_equal(np.asarray(got["b"][0]), want["b"][0])
    assert float(got["b"][1]["c"]) == float(want["b"][1]["c"])


def _truncate(path, keep=None):
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2 if keep is None else keep)


def _npz(d, step):
    return os.path.join(d, f"ckpt_{step:08d}.npz")


class TestAtomicityAndCompleteness:
    def test_latest_step_skips_truncated(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 3, _tree())
        save_checkpoint(d, 7, _tree(1))
        _truncate(_npz(d, 7))
        assert latest_step(d) == 3

    def test_latest_step_skips_zero_byte(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 2, _tree())
        open(_npz(d, 9), "wb").close()
        assert latest_step(d) == 2

    def test_no_tmp_left_after_save(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, _tree())
        assert [f for f in os.listdir(d) if ".tmp" in f] == []

    def test_empty_dir(self, tmp_path):
        assert latest_step(str(tmp_path)) is None
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(str(tmp_path), _tree())


class TestIntegrityFallback:
    def test_truncated_latest_falls_back_and_quarantines(self, tmp_path):
        d = str(tmp_path)
        t3, t7 = _tree(3), _tree(7)
        save_checkpoint(d, 3, t3)
        save_checkpoint(d, 7, t7)
        _truncate(_npz(d, 7))
        # a truncated npz is no longer a complete unit, so the walk starts
        # at step 3 without even needing the quarantine path
        got, step = restore_checkpoint(d, _tree())
        assert step == 3
        _assert_tree_close(got, t3)

    def test_checksum_corruption_falls_back_and_quarantines(self, tmp_path):
        d = str(tmp_path)
        t3, t7 = _tree(3), _tree(7)
        save_checkpoint(d, 3, t3)
        save_checkpoint(d, 7, t7)
        # silent corruption: rewrite leaf_0 with different data but keep the
        # original manifest (stale sha256) — the zip container stays valid,
        # only the checksum pass can catch this
        path = _npz(d, 7)
        with np.load(path) as data:
            arrays = {k: data[k] for k in data.files}
        arrays["leaf_0"] = arrays["leaf_0"] + 1.0
        np.savez(path, **arrays)
        with pytest.warns(RuntimeWarning, match="quarantined"):
            got, step = restore_checkpoint(d, _tree())
        assert step == 3
        _assert_tree_close(got, t3)
        assert os.path.exists(path + ".corrupt")
        assert not os.path.exists(path)
        # the quarantined unit stays invisible from here on
        assert latest_step(d) == 3

    def test_explicit_step_corruption_raises(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 3, _tree())
        save_checkpoint(d, 7, _tree(1))
        _truncate(_npz(d, 7), keep=os.path.getsize(_npz(d, 7)) - 16)
        with pytest.raises(CheckpointCorruptError):
            restore_checkpoint(d, _tree(), step=7)
        # the valid older step is still explicitly reachable
        got, step = restore_checkpoint(d, _tree(), step=3)
        assert step == 3

    def test_all_corrupt_raises_filenotfound(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, _tree())
        path = _npz(d, 1)
        with np.load(path) as data:
            arrays = {k: data[k] for k in data.files}
        arrays["leaf_0"] = arrays["leaf_0"] * 2.0
        np.savez(path, **arrays)
        with pytest.warns(RuntimeWarning):
            with pytest.raises(FileNotFoundError, match="quarantined"):
                restore_checkpoint(d, _tree())


class TestStructureMismatchLabels:
    def test_missing_and_extra_are_correct(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, {"a": np.zeros(3)})
        with pytest.raises(ValueError) as ei:
            restore_checkpoint(d, {"zz": np.zeros(3)})
        msg = str(ei.value)
        # "missing" = template keys the checkpoint lacks; "extra" = keys
        # the checkpoint has that the template does not (the pre-fix code
        # printed them swapped)
        missing_line = [l for l in msg.splitlines() if "missing" in l][0]
        extra_line = [l for l in msg.splitlines() if "extra" in l][0]
        assert "zz" in missing_line and "zz" not in extra_line
        assert "a" in extra_line and "a" not in missing_line

    def test_shape_mismatch(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, {"a": np.zeros(3)})
        with pytest.raises(ValueError, match="shape mismatch"):
            restore_checkpoint(d, {"a": np.zeros(4)})


class TestLegacyFormat:
    def _write_v1(self, d, step, tree):
        # the pre-PR on-disk layout: arrays-only npz + sidecar json manifest
        from repro.checkpoint.ckpt import _flatten_with_paths

        items = _flatten_with_paths(tree)
        arrays = {f"leaf_{i}": np.asarray(v) for i, (k, v) in enumerate(items)}
        np.savez(os.path.join(d, f"ckpt_{step:08d}.npz"), **arrays)
        with open(os.path.join(d, f"ckpt_{step:08d}.json"), "w") as f:
            json.dump({"step": step, "keys": [k for k, _ in items]}, f)

    def test_v1_restores(self, tmp_path):
        d = str(tmp_path)
        t = _tree(5)
        self._write_v1(d, 4, t)
        assert latest_step(d) == 4
        got, step = restore_checkpoint(d, _tree())
        assert step == 4
        _assert_tree_close(got, t)

    def test_v1_without_sidecar_is_incomplete(self, tmp_path):
        # the exact ordering hazard of the old writer: npz committed, crash
        # before the json — latest_step must not advertise the step
        d = str(tmp_path)
        self._write_v1(d, 4, _tree())
        os.remove(os.path.join(d, "ckpt_00000004.json"))
        assert latest_step(d) is None


class TestHygieneAndRetention:
    def test_stale_tmp_removed_on_save_and_restore(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, _tree())
        orphan = os.path.join(d, "ckpt_00000002.npz.tmp-99999")
        with open(orphan, "wb") as f:
            f.write(b"partial write from a dead process")
        save_checkpoint(d, 2, _tree(1))
        assert not os.path.exists(orphan)
        with open(orphan, "wb") as f:
            f.write(b"again")
        restore_checkpoint(d, _tree())
        assert not os.path.exists(orphan)

    def test_keep_last_prunes_oldest(self, tmp_path):
        d = str(tmp_path)
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(d, s, _tree(s), keep_last=3)
        kept = sorted(
            int(f[5:13]) for f in os.listdir(d) if f.endswith(".npz")
        )
        assert kept == [3, 4, 5]

    def test_keep_last_never_prunes_newest(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 9, _tree(), keep_last=1)
        assert latest_step(d) == 9
        restore_checkpoint(d, _tree())
