"""Quickstart: ByzantineSGD on a strongly convex problem, 60 seconds.

Reproduces the paper's core picture: with a quarter of the workers
adversarial, naive mini-batch SGD is destroyed; ByzantineSGD removes the
attackers within a few iterations and converges as if they were never
there.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_quadratic_problem


def main():
    prob = make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0)
    key = jax.random.PRNGKey(0)

    print(f"problem: d=16 quadratic, sigma=1, L=8, V=1, D={prob.D:.2f}")
    print(f"workers: m=16, alpha=0.25 (4 Byzantine, sign-flip attack)\n")
    print(f"{'aggregator':20s} {'f(x̄)−f(x*)':>12s} {'alive':>6s} {'good dropped':>13s}")

    for agg in ["mean", "krum", "coordinate_median", "byzantine_sgd"]:
        cfg = SolverConfig(m=16, T=2000, eta=0.05, alpha=0.25,
                           aggregator=agg, attack="sign_flip")
        res = run_sgd(prob, cfg, key)
        gap = float(prob.f(res.x_avg) - prob.f(prob.x_star))
        print(f"{agg:20s} {gap:12.6f} {int(res.n_alive[-1]):4d}/16 "
              f"{str(bool(res.ever_filtered_good)):>13s}")

    print("\nThe guard itself has interchangeable realizations (DESIGN.md §9):")
    print("dense 3-pass reference, fused one-pass Pallas pipeline, and the")
    print("distributed CountSketch guard — same filter decisions, fewer bytes.")
    for backend in ["dense", "fused", "dp_sketch"]:
        cfg = SolverConfig(m=16, T=500, eta=0.05, alpha=0.25,
                           aggregator="byzantine_sgd", attack="sign_flip",
                           guard_backend=backend)
        res = run_sgd(prob, cfg, key)
        gap = float(prob.f(res.x_avg) - prob.f(prob.x_star))
        print(f"  guard_backend={backend:10s} gap {gap:.6f}, "
              f"alive {int(res.n_alive[-1])}/16")

    print("\nByzantineSGD's per-worker martingale statistics (A_i, B_i) also")
    print("catch attackers that per-iteration rules cannot — try")
    print("  attack='hidden_shift'  (inside-the-noise colluders, Section 1.3)")
    cfg = SolverConfig(m=16, T=2000, eta=0.05, alpha=0.25,
                       aggregator="byzantine_sgd", attack="hidden_shift")
    res = run_sgd(prob, cfg, key)
    gap = float(prob.f(res.x_avg) - prob.f(prob.x_star))
    print(f"hidden_shift → gap {gap:.6f}, alive {int(res.n_alive[-1])}/16 "
          f"(damage bounded per Lemma 3.6)")


if __name__ == "__main__":
    main()
