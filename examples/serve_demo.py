"""Serving demo: batched prefill + greedy decode across architecture
families (GQA ring cache, MLA latent cache, Mamba2 O(1) state, Jamba
hybrid) — the CPU-scale twin of the decode-shape dry-runs.

    PYTHONPATH=src python examples/serve_demo.py
"""
from repro.launch.serve import run_serving


def main():
    for arch in ["internlm2-1.8b", "deepseek-v2-lite-16b", "mamba2-130m",
                 "jamba-v0.1-52b"]:
        run_serving(arch, batch=2, prompt_len=48, gen_tokens=12, cache_len=128)


if __name__ == "__main__":
    main()
