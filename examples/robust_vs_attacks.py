"""The robustness matrix: every aggregator × every attack, one table.

Shows where each baseline breaks (Krum under ALIE, coordinate median under
inner-product, mean under everything) and that ByzantineSGD holds across
the board — the paper's Section 1.4 discussion, made empirical.

    PYTHONPATH=src python examples/robust_vs_attacks.py
"""
import jax

from repro.core.solver import SolverConfig, run_sgd
from repro.data.problems import make_quadratic_problem

AGGREGATORS = ["mean", "krum", "coordinate_median", "trimmed_mean",
               "geometric_median", "byzantine_sgd"]
ATTACKS = ["none", "sign_flip", "random_gaussian", "alie", "inner_product",
           "hidden_shift"]


def main():
    prob = make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0)
    key = jax.random.PRNGKey(0)
    print("suboptimality f(x̄)−f(x*) after T=2000, m=16, α=0.25\n")
    header = f"{'':18s}" + "".join(f"{a:>16s}" for a in ATTACKS)
    print(header)
    for agg in AGGREGATORS:
        row = f"{agg:18s}"
        for attack in ATTACKS:
            cfg = SolverConfig(m=16, T=2000, eta=0.05,
                               alpha=0.0 if attack == "none" else 0.25,
                               aggregator=agg, attack=attack)
            res = run_sgd(prob, cfg, key)
            gap = float(prob.f(res.x_avg) - prob.f(prob.x_star))
            row += f"{gap:16.5f}"
        print(row)
    print("\n(μ-scale gaps = converged; ≥0.1 = broken by the attack)")


if __name__ == "__main__":
    main()
