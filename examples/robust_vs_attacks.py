"""The robustness matrix: every aggregator × every attack, one table.

Shows where each baseline breaks (Krum under ALIE, coordinate median under
inner-product, mean under everything) and that ByzantineSGD holds across
the board — the paper's Section 1.4 discussion, made empirical.

The whole matrix is ONE ``run_campaign`` call (a single jit(vmap) over
the attack grid, aggregator × guard-backend axes unrolled in the same
trace) instead of eagerly re-traced per-cell ``run_sgd`` calls; both
wall-clocks are printed.  The ``none`` column runs with the same α —
Byzantine workers that play ``none`` send their honest gradients, so it
doubles as the clean baseline.  The guard appears once per backend
(``byzantine_sgd@dense`` / ``@fused``, DESIGN.md §9): identical filter
decisions from two different pipelines is itself part of the picture.

    PYTHONPATH=src python examples/robust_vs_attacks.py
"""
from repro.core.solver import SolverConfig
from repro.data.problems import make_quadratic_problem
from repro.scenarios import (
    expand_grid,
    run_campaign,
    run_campaign_looped,
    scenario_static,
)

AGGREGATORS = ["mean", "krum", "coordinate_median", "trimmed_mean",
               "geometric_median", "autogm", "centered_clip",
               "bucket2:krum", "byzantine_sgd"]
BACKENDS = ["dense", "fused"]
ATTACKS = ["none", "sign_flip", "random_gaussian", "alie", "alie_update",
           "inner_product", "hidden_shift"]


def main():
    prob = make_quadratic_problem(d=16, sigma=1.0, L=8.0, V=1.0)
    cfg = SolverConfig(m=16, T=2000, eta=0.05, alpha=0.25,
                       aggregator="byzantine_sgd", attack="sign_flip")
    grid = expand_grid([(a, scenario_static(a)) for a in ATTACKS],
                       alphas=[cfg.alpha], seeds=[0])
    result = run_campaign(prob, cfg, grid, AGGREGATORS, backends=BACKENDS)
    col = {e["scenario"]: i for i, e in enumerate(result.entries)}
    variants = sorted(result.stats)

    print("suboptimality f(x̄)−f(x*) after T=2000, m=16, α=0.25\n")
    print(f"{'':22s}" + "".join(f"{a:>16s}" for a in ATTACKS))
    for agg in variants:
        gaps = result.stats[agg].gap_avg
        row = f"{agg:22s}"
        for attack in ATTACKS:
            row += f"{float(gaps[col[attack]]):16.5f}"
        print(row)
    print("\n(μ-scale gaps = converged; ≥0.1 = broken by the attack)")

    _, looped_s = run_campaign_looped(prob, cfg, grid, AGGREGATORS,
                                      backends=BACKENDS)
    cells = len(variants) * len(ATTACKS)
    print(f"\nwall-clock, {cells} runs: "
          f"batched one-jit {result.wall_s:.2f}s "
          f"(+{result.compile_s:.1f}s compile, paid once) vs "
          f"looped eager {looped_s:.2f}s "
          f"→ {looped_s / max(result.wall_s, 1e-9):.0f}x steady-state")


if __name__ == "__main__":
    main()
