"""End-to-end driver: train a ~100M-parameter language model for a few
hundred steps with Byzantine-robust data-parallel aggregation.

Uses the internlm2 family at d_model=512 / 24 layers (~100M params with the
92k vocab), 8 workers of which 2 are sign-flipping Byzantine. On a TPU pod
the identical code path runs the full config across the (data, model) mesh
(see repro.launch.dryrun for the production lowering).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--attack", default="sign_flip")
    ap.add_argument("--guard-backend", default="dp_exact",
                    choices=["dp_exact", "dp_sketch", "dense", "fused"])
    ap.add_argument("--guard-v", type=float, default=0.0,
                    help="explicit Assumption-2.2 V; required (> 0) for "
                         "dense/fused, which have no online auto-V")
    ap.add_argument("--scenario", default=None,
                    choices=["static", "lie_low", "churn", "adaptive",
                             "coalition"])
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/byz_lm_ckpt")
    args = ap.parse_args()

    # ~100M params: d_model=512, 24 layers, vocab 92544
    state, hist = run_training(
        "internlm2-1.8b", reduced=True, d_model=args.d_model,
        workers=args.workers, per_worker_batch=2, seq_len=args.seq_len,
        steps=args.steps, alpha=args.alpha, attack=args.attack,
        aggregator="byzantine_sgd", guard_backend=args.guard_backend,
        guard_v=args.guard_v, scenario=args.scenario, lr=3e-3,
        ckpt_dir=args.ckpt_dir, log_every=10,
    )
    first, last = hist[0], hist[-1]
    print(f"\nloss: {first['loss_good_workers']:.4f} → {last['loss_good_workers']:.4f}")
    print(f"byzantine workers alive at end: {int(last['byz_alive'])}")
    print(f"honest workers ever filtered: {max(int(h['good_filtered']) for h in hist)}")
    print(f"checkpoint written to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
